//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (the suite skips, loudly, when
//! artifacts are absent so `cargo test` stays runnable pre-build).

use std::path::{Path, PathBuf};

use agentsrv::runtime::InferenceEngine;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn engine_loads_all_agents_and_verifies_golden_vectors() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = InferenceEngine::load(&dir).expect("engine load");
    assert_eq!(engine.platform(), "cpu");
    // Every (agent, batch) golden vector must reproduce bit-exact greedy
    // tokens and matching logits norms — proves the Pallas-kernel HLO and
    // the Rust execution path agree with JAX end-to-end.
    let verified = engine.verify_golden().expect("golden vectors");
    // 4 agents x 4 batch variants.
    assert_eq!(verified.len(), 16, "verified: {verified:?}");
}

#[test]
fn batching_pads_and_truncates_correctly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = InferenceEngine::load(&dir).expect("engine load");
    let seq = engine.manifest().seq_len;
    let vocab = engine.manifest().agent("coordinator").unwrap().vocab;

    let row = |s: u64| -> Vec<i32> {
        (0..seq).map(|i| ((s * 31 + i as u64 * 7) % vocab as u64) as i32)
            .collect()
    };

    // Batch of 3 must ride the b4 variant and return exactly 3 outputs.
    let rows = vec![row(1), row(2), row(3)];
    let out = engine.infer("coordinator", &rows).expect("infer");
    assert_eq!(out.executed_batch, 4);
    assert_eq!(out.next_tokens.len(), 3);
    assert_eq!(out.logits.len(), 3 * vocab);

    // Each row's output must be independent of its batch-mates: run each
    // row alone and compare.
    for (i, r) in rows.iter().enumerate() {
        let solo = engine.infer("coordinator", &[r.clone()]).expect("solo");
        assert_eq!(solo.next_tokens[0], out.next_tokens[i],
                   "row {i} differs between batch and solo");
    }

    let stats = engine.stats();
    assert_eq!(stats.requests, 3 + 3);
    assert!(stats.padded_slots >= 1);
}

#[test]
fn engine_rejects_malformed_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = InferenceEngine::load(&dir).expect("engine load");
    let seq = engine.manifest().seq_len;

    // Unknown agent.
    assert!(engine.infer("nope", &[vec![0; seq]]).is_err());
    // Empty batch.
    assert!(engine.infer("coordinator", &[]).is_err());
    // Wrong token count.
    assert!(engine.infer("coordinator", &[vec![0; seq - 1]]).is_err());
    // Token out of vocab.
    assert!(engine.infer("coordinator", &[vec![100_000; seq]]).is_err());
    // Oversized batch.
    let too_many: Vec<Vec<i32>> = (0..64).map(|_| vec![0; seq]).collect();
    assert!(engine.infer("coordinator", &too_many).is_err());
}

#[test]
fn heterogeneous_agents_have_heterogeneous_cost() {
    // The paper's premise: specialists are heavier than the coordinator.
    let Some(dir) = artifacts_dir() else { return };
    let engine = InferenceEngine::load(&dir).expect("engine load");
    let m = engine.manifest();
    let coord = m.agent("coordinator").unwrap();
    let reasoning = m.agent("reasoning").unwrap();
    assert!(reasoning.param_count > 3 * coord.param_count);
    assert!(reasoning.flops(1) > 3 * coord.flops(1));
}
