//! Integration: the full serving stack (router → batcher → governor →
//! PJRT) and the collaborative-reasoning pipeline on top of it.
//!
//! One server is shared across the whole file (engine compilation is the
//! expensive part), exercised by concurrent client threads.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use agentsrv::coordinator::{ReasoningPipeline, TaskKind};
use agentsrv::runtime::Manifest;
use agentsrv::server::{AgentServer, ServerConfig};
use agentsrv::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn prompt(seq: usize, vocab: usize, seed: u64) -> Vec<i32> {
    (0..seq).map(|i| ((seed * 131 + i as u64 * 7 + 3) % vocab as u64) as i32)
        .collect()
}

#[test]
fn serving_stack_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let seq = manifest.seq_len;
    let vocabs: Vec<(String, usize)> = manifest.agents.iter()
        .map(|a| (a.name.clone(), a.vocab)).collect();

    let server = Arc::new(
        AgentServer::start(ServerConfig::new(&dir)).expect("server start"));

    // --- 1. Submission validation happens before queuing. -------------
    assert!(server.submit("nope", vec![0; seq]).is_err());
    assert!(server.submit("coordinator", vec![0; seq - 1]).is_err());
    assert!(server.submit("coordinator", vec![-1; seq]).is_err());

    // --- 2. Concurrent mixed load from client threads. -----------------
    let mut handles = Vec::new();
    for (agent, vocab) in vocabs.clone() {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut answers = Vec::new();
            for s in 0..12u64 {
                let done = server
                    .submit_blocking(&agent, prompt(seq, vocab, s))
                    .expect("request served");
                assert_eq!(done.agent, agent);
                assert!(done.next_token >= 0
                        && (done.next_token as usize) < vocab);
                assert!(done.batch_size >= 1);
                answers.push(done.next_token);
            }
            (agent, answers)
        }));
    }
    let mut all: Vec<(String, Vec<i32>)> = Vec::new();
    for h in handles {
        all.push(h.join().expect("client thread"));
    }

    // Determinism: the same prompt re-submitted yields the same token.
    for (agent, answers) in &all {
        let vocab = vocabs.iter().find(|(n, _)| n == agent).unwrap().1;
        let again = server
            .submit_blocking(agent, prompt(seq, vocab, 0))
            .expect("repeat");
        assert_eq!(again.next_token, answers[0],
                   "{agent} nondeterministic");
    }

    // --- 3. Collaborative reasoning workflows. -------------------------
    let pipeline = ReasoningPipeline::new(&server, vocabs.clone());
    let mut rng = Rng::new(11);
    for i in 0..6u64 {
        let kind = TaskKind::sample(&mut rng);
        let wf = pipeline.run(&server, kind, i).expect("workflow");
        // plan + specialists + aggregate
        assert_eq!(wf.stages.len(), kind.specialists().len() + 2);
        assert_eq!(wf.stages.first().unwrap().agent, "coordinator");
        assert_eq!(wf.stages.last().unwrap().agent, "coordinator");
        assert!(wf.answer() >= 0);
        assert!(wf.total >= wf.stages.iter().map(|s| s.latency).max()
                .unwrap());
    }
    // Workflows are deterministic given (kind, seed).
    let a = pipeline.run(&server, TaskKind::MultiDomain, 99).unwrap();
    let b = pipeline.run(&server, TaskKind::MultiDomain, 99).unwrap();
    assert_eq!(a.answer(), b.answer());

    // --- 4. Stats are coherent. -----------------------------------------
    let server = Arc::try_unwrap(server).ok().expect("sole owner");
    let stats = server.shutdown();
    assert_eq!(stats.total_errors, 0);
    // 4 agents x 12 + 4 determinism repeats + workflow stages.
    assert!(stats.total_completed >= 52, "{}", stats.total_completed);
    assert!(stats.gpu_busy_seconds > 0.0);
    let shares: f64 = stats.per_agent.iter().map(|a| a.gpu_share).sum();
    assert!((shares - 1.0).abs() < 1e-6, "gpu shares sum to {shares}");
    for a in &stats.per_agent {
        assert!(a.completed > 0, "{} served nothing", a.name);
        assert!(a.p50_s > 0.0 && a.p99_s >= a.p50_s,
                "{} quantiles broken", a.name);
        assert!(a.mean_batch >= 1.0);
    }
}
