//! Integration: trace record → replay equivalence and the Eq. 2
//! objective.

use agentsrv::agents::AgentProfile;
use agentsrv::allocator::{AdaptivePolicy, StaticEqualPolicy};
use agentsrv::sim::{SimConfig, Simulator};
use agentsrv::util::TempDir;
use agentsrv::workload::trace::Trace;
use agentsrv::workload::WorkloadGenerator;

#[test]
fn replaying_a_recorded_trace_reproduces_the_generator_run() {
    // Record the paper's Poisson workload...
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 100, 1.0);

    // ...simulate from the generator and from the trace.
    let cfg = SimConfig::paper_poisson();
    let sim = Simulator::new(cfg, AgentProfile::paper_agents());
    let from_gen = sim.run(&mut AdaptivePolicy::default());
    let from_trace = sim.run_trace(&mut AdaptivePolicy::default(), &trace);

    assert_eq!(from_gen.mean_latency(), from_trace.mean_latency());
    assert_eq!(from_gen.total_throughput(), from_trace.total_throughput());
    assert_eq!(from_gen.cost_dollars, from_trace.cost_dollars);
}

#[test]
fn trace_replay_survives_disk_roundtrip() {
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 50, 1.0);

    let dir = TempDir::new("trace").unwrap();
    let path = dir.path().join("workload.csv");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();

    let sim = Simulator::new(SimConfig::paper_poisson(),
                             AgentProfile::paper_agents());
    let a = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
    let b = sim.run_trace(&mut AdaptivePolicy::default(), &loaded);
    assert_eq!(a.mean_latency(), b.mean_latency());
    assert_eq!(a.steps, 50);
}

#[test]
fn eq2_objective_ranks_adaptive_over_round_robin() {
    let sim = Simulator::new(SimConfig::paper(),
                             AgentProfile::paper_agents());
    let adaptive = sim.run(&mut AdaptivePolicy::default());
    let static_eq = sim.run(&mut StaticEqualPolicy);
    let mut rr = agentsrv::allocator::RoundRobinPolicy::default();
    let round_robin = sim.run(&mut rr);

    // With any latency-dominated weighting, adaptive and static crush
    // round-robin under the paper's Eq. 2 (lower = better).
    let (a, b, g) = (1.0, 100.0, 1.0);
    let obj_a = adaptive.objective(a, b, g);
    let obj_s = static_eq.objective(a, b, g);
    let obj_r = round_robin.objective(a, b, g);
    assert!(obj_a < obj_r && obj_s < obj_r,
            "adaptive {obj_a}, static {obj_s}, rr {obj_r}");
    // Throughput-dominated weighting flips static slightly ahead of
    // adaptive (the 3.2% tput sacrifice), but never rescues RR.
    let obj_a2 = adaptive.objective(0.0, 0.0, 1.0);
    let obj_s2 = static_eq.objective(0.0, 0.0, 1.0);
    assert!(obj_s2 <= obj_a2);
}
