//! Integration: trace record → replay equivalence, corpus round-trips
//! through a directory, and the Eq. 2 objective.

use agentsrv::agents::{AgentProfile, AgentRegistry};
use agentsrv::allocator::{AdaptivePolicy, PolicyKind, StaticEqualPolicy};
use agentsrv::sim::batch::{run_sweep, TraceScenario};
use agentsrv::sim::{SimConfig, Simulator};
use agentsrv::util::TempDir;
use agentsrv::workload::trace::{Trace, TraceCorpus};
use agentsrv::workload::WorkloadGenerator;
use agentsrv::Error;

#[test]
fn replaying_a_recorded_trace_reproduces_the_generator_run() {
    // Record the paper's Poisson workload...
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 100, 1.0);

    // ...simulate from the generator and from the trace.
    let cfg = SimConfig::paper_poisson();
    let sim = Simulator::new(cfg, AgentProfile::paper_agents());
    let from_gen = sim.run(&mut AdaptivePolicy::default());
    let from_trace = sim.run_trace(&mut AdaptivePolicy::default(), &trace);

    assert_eq!(from_gen.mean_latency(), from_trace.mean_latency());
    assert_eq!(from_gen.total_throughput(), from_trace.total_throughput());
    assert_eq!(from_gen.cost_dollars, from_trace.cost_dollars);
}

#[test]
fn trace_replay_survives_disk_roundtrip() {
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 50, 1.0);

    let dir = TempDir::new("trace").unwrap();
    let path = dir.path().join("workload.csv");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();

    let sim = Simulator::new(SimConfig::paper_poisson(),
                             AgentProfile::paper_agents());
    let a = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
    let b = sim.run_trace(&mut AdaptivePolicy::default(), &loaded);
    assert_eq!(a.mean_latency(), b.mean_latency());
    assert_eq!(a.steps, 50);
}

#[test]
fn recorded_then_saved_corpus_reloads_bit_equal() {
    let mut corpus = TraceCorpus::new();
    for seed in [1u64, 2, 3] {
        corpus.push(format!("day{seed}"), Trace::paper_poisson(40, seed));
    }
    let dir = TempDir::new("corpus").unwrap();
    corpus.save_dir(dir.path()).unwrap();
    let loaded = TraceCorpus::load_dir(dir.path()).unwrap();
    assert_eq!(corpus, loaded);

    // And the reloaded corpus replays bit-identically to the original:
    // the sweep over the saved-and-reloaded traces matches a direct
    // run_trace of each in-memory recording.
    let cells = TraceScenario::corpus(
        &loaded, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap();
    assert_eq!(cells.len(), 3);
    let runs = run_sweep(&cells, 2);
    for (run, (label, trace)) in runs.iter().zip(corpus.iter()) {
        assert_eq!(run.label, format!("adaptive/{label}"));
        let sim = Simulator::new(SimConfig::paper(),
                                 AgentProfile::paper_agents());
        let want = sim.run_trace(&mut AdaptivePolicy::default(), trace);
        let got = run.result.as_sim().expect("trace cell");
        assert_eq!(got.mean_latency(), want.mean_latency(), "{label}");
        assert_eq!(got.total_throughput(), want.total_throughput());
        assert_eq!(got.cost_dollars, want.cost_dollars);
    }
}

#[test]
fn empty_corpus_directory_yields_an_empty_sweep() {
    let dir = TempDir::new("corpus").unwrap();
    let corpus = TraceCorpus::load_dir(dir.path()).unwrap();
    assert!(corpus.is_empty());
    let cells = TraceScenario::corpus(
        &corpus, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap();
    assert!(cells.is_empty());
    assert!(run_sweep(&cells, 8).is_empty());
}

#[test]
fn foreign_corpus_surfaces_labelled_error_instead_of_panicking() {
    // A trace recorded against a different deployment is well-formed CSV
    // — load_dir accepts it — but its agent columns cannot drive the
    // paper registry; building the sweep must fail with a labelled
    // Error::Trace, not panic.
    let mut gen = WorkloadGenerator::new(
        vec![10.0, 5.0],
        agentsrv::workload::WorkloadKind::Steady,
        agentsrv::workload::ArrivalProcess::Poisson, 1);
    let foreign = Trace::record(
        &mut gen, vec!["alpha".into(), "beta".into()], 5, 1.0);
    let dir = TempDir::new("corpus").unwrap();
    foreign.save(&dir.path().join("foreign.csv")).unwrap();
    let corpus = TraceCorpus::load_dir(dir.path()).unwrap();

    let err = TraceScenario::corpus(
        &corpus, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap_err();
    match err {
        Error::Trace(msg) => assert!(
            msg.contains("foreign") && msg.contains("alpha"),
            "error must name the trace and its columns: {msg}"),
        other => panic!("expected Error::Trace, got {other}"),
    }
}

#[test]
fn malformed_corpus_file_surfaces_labelled_trace_error() {
    let dir = TempDir::new("corpus").unwrap();
    Trace::paper_poisson(10, 1).save(&dir.path().join("good.csv"))
        .unwrap();
    // Three malformed flavors: garbage header, ragged row, bad number.
    for (name, body) in [
        ("garbage.csv", "nonsense\n"),
        ("ragged.csv", "# dt=1\nstep,a\n0,1\n1,2,3\n"),
        ("nan_text.csv", "# dt=1\nstep,a\n0,xyz\n"),
    ] {
        std::fs::write(dir.path().join(name), body).unwrap();
        let err = TraceCorpus::load_dir(dir.path()).unwrap_err();
        match err {
            Error::Trace(msg) => assert!(
                msg.contains(name),
                "error for {name} must name the file: {msg}"),
            other => panic!("{name}: expected Error::Trace, got {other}"),
        }
        std::fs::remove_file(dir.path().join(name)).unwrap();
    }
    // With the malformed files gone, the survivor loads fine.
    assert_eq!(TraceCorpus::load_dir(dir.path()).unwrap().len(), 1);
}

#[test]
fn eq2_objective_ranks_adaptive_over_round_robin() {
    let sim = Simulator::new(SimConfig::paper(),
                             AgentProfile::paper_agents());
    let adaptive = sim.run(&mut AdaptivePolicy::default());
    let static_eq = sim.run(&mut StaticEqualPolicy);
    let mut rr = agentsrv::allocator::RoundRobinPolicy::default();
    let round_robin = sim.run(&mut rr);

    // With any latency-dominated weighting, adaptive and static crush
    // round-robin under the paper's Eq. 2 (lower = better).
    let (a, b, g) = (1.0, 100.0, 1.0);
    let obj_a = adaptive.objective(a, b, g);
    let obj_s = static_eq.objective(a, b, g);
    let obj_r = round_robin.objective(a, b, g);
    assert!(obj_a < obj_r && obj_s < obj_r,
            "adaptive {obj_a}, static {obj_s}, rr {obj_r}");
    // Throughput-dominated weighting flips static slightly ahead of
    // adaptive (the 3.2% tput sacrifice), but never rescues RR.
    let obj_a2 = adaptive.objective(0.0, 0.0, 1.0);
    let obj_s2 = static_eq.objective(0.0, 0.0, 1.0);
    assert!(obj_s2 <= obj_a2);
}
