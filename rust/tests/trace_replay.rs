//! Integration: trace record → replay equivalence, corpus round-trips
//! through a directory, CSV ↔ `.atrb` binary round-trips and
//! cross-engine binary-replay equivalence, and the Eq. 2 objective.

use agentsrv::agents::{AgentProfile, AgentRegistry};
use agentsrv::allocator::{AdaptivePolicy, PolicyKind, StaticEqualPolicy};
use agentsrv::cluster::{ClusterSimulator, Rebalancer};
use agentsrv::server::{ServingConfig, ServingSimulator};
use agentsrv::sim::batch::{run_sweep, TraceScenario};
use agentsrv::sim::{SimConfig, Simulator};
use agentsrv::util::{Rng, TempDir};
use agentsrv::workload::bintrace::{save_trace, trace_to_bytes};
use agentsrv::workload::trace::{Trace, TraceCorpus};
use agentsrv::workload::{BinTrace, BinTraceWriter, BurstEvent,
                         TraceSource, WorkloadGenerator};
use agentsrv::Error;

#[test]
fn replaying_a_recorded_trace_reproduces_the_generator_run() {
    // Record the paper's Poisson workload...
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 100, 1.0);

    // ...simulate from the generator and from the trace.
    let cfg = SimConfig::paper_poisson();
    let sim = Simulator::new(cfg, AgentProfile::paper_agents());
    let from_gen = sim.run(&mut AdaptivePolicy::default());
    let from_trace = sim.run_trace(&mut AdaptivePolicy::default(), &trace);

    assert_eq!(from_gen.mean_latency(), from_trace.mean_latency());
    assert_eq!(from_gen.total_throughput(), from_trace.total_throughput());
    assert_eq!(from_gen.cost_dollars, from_trace.cost_dollars);
}

#[test]
fn trace_replay_survives_disk_roundtrip() {
    let mut gen = WorkloadGenerator::paper_poisson();
    let names: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let trace = Trace::record(&mut gen, names, 50, 1.0);

    let dir = TempDir::new("trace").unwrap();
    let path = dir.path().join("workload.csv");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();

    let sim = Simulator::new(SimConfig::paper_poisson(),
                             AgentProfile::paper_agents());
    let a = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
    let b = sim.run_trace(&mut AdaptivePolicy::default(), &loaded);
    assert_eq!(a.mean_latency(), b.mean_latency());
    assert_eq!(a.steps, 50);
}

#[test]
fn recorded_then_saved_corpus_reloads_bit_equal() {
    let mut corpus = TraceCorpus::new();
    for seed in [1u64, 2, 3] {
        corpus.push(format!("day{seed}"), Trace::paper_poisson(40, seed));
    }
    let dir = TempDir::new("corpus").unwrap();
    corpus.save_dir(dir.path()).unwrap();
    let loaded = TraceCorpus::load_dir(dir.path()).unwrap();
    assert_eq!(corpus, loaded);

    // And the reloaded corpus replays bit-identically to the original:
    // the sweep over the saved-and-reloaded traces matches a direct
    // run_trace of each in-memory recording.
    let cells = TraceScenario::corpus(
        &loaded, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap();
    assert_eq!(cells.len(), 3);
    let runs = run_sweep(&cells, 2);
    for (run, (label, trace)) in runs.iter().zip(corpus.iter()) {
        assert_eq!(run.label, format!("adaptive/{label}"));
        let sim = Simulator::new(SimConfig::paper(),
                                 AgentProfile::paper_agents());
        let want = sim.run_trace(&mut AdaptivePolicy::default(), trace);
        let got = run.result.as_sim().expect("trace cell");
        assert_eq!(got.mean_latency(), want.mean_latency(), "{label}");
        assert_eq!(got.total_throughput(), want.total_throughput());
        assert_eq!(got.cost_dollars, want.cost_dollars);
    }
}

#[test]
fn empty_corpus_directory_yields_an_empty_sweep() {
    let dir = TempDir::new("corpus").unwrap();
    let corpus = TraceCorpus::load_dir(dir.path()).unwrap();
    assert!(corpus.is_empty());
    let cells = TraceScenario::corpus(
        &corpus, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap();
    assert!(cells.is_empty());
    assert!(run_sweep(&cells, 8).is_empty());
}

#[test]
fn foreign_corpus_surfaces_labelled_error_instead_of_panicking() {
    // A trace recorded against a different deployment is well-formed CSV
    // — load_dir accepts it — but its agent columns cannot drive the
    // paper registry; building the sweep must fail with a labelled
    // Error::Trace, not panic.
    let mut gen = WorkloadGenerator::new(
        vec![10.0, 5.0],
        agentsrv::workload::WorkloadKind::Steady,
        agentsrv::workload::ArrivalProcess::Poisson, 1);
    let foreign = Trace::record(
        &mut gen, vec!["alpha".into(), "beta".into()], 5, 1.0);
    let dir = TempDir::new("corpus").unwrap();
    foreign.save(&dir.path().join("foreign.csv")).unwrap();
    let corpus = TraceCorpus::load_dir(dir.path()).unwrap();

    let err = TraceScenario::corpus(
        &corpus, &SimConfig::paper(), &AgentRegistry::paper(),
        &PolicyKind::adaptive()).unwrap_err();
    match err {
        Error::Trace(msg) => assert!(
            msg.contains("foreign") && msg.contains("alpha"),
            "error must name the trace and its columns: {msg}"),
        other => panic!("expected Error::Trace, got {other}"),
    }
}

#[test]
fn malformed_corpus_file_surfaces_labelled_trace_error() {
    let dir = TempDir::new("corpus").unwrap();
    Trace::paper_poisson(10, 1).save(&dir.path().join("good.csv"))
        .unwrap();
    // Three malformed flavors: garbage header, ragged row, bad number.
    for (name, body) in [
        ("garbage.csv", "nonsense\n"),
        ("ragged.csv", "# dt=1\nstep,a\n0,1\n1,2,3\n"),
        ("nan_text.csv", "# dt=1\nstep,a\n0,xyz\n"),
    ] {
        std::fs::write(dir.path().join(name), body).unwrap();
        let err = TraceCorpus::load_dir(dir.path()).unwrap_err();
        match err {
            Error::Trace(msg) => assert!(
                msg.contains(name),
                "error for {name} must name the file: {msg}"),
            other => panic!("{name}: expected Error::Trace, got {other}"),
        }
        std::fs::remove_file(dir.path().join(name)).unwrap();
    }
    // With the malformed files gone, the survivor loads fine.
    assert_eq!(TraceCorpus::load_dir(dir.path()).unwrap().len(), 1);
}

#[test]
fn fuzzed_traces_roundtrip_binary_bit_equal() {
    // Seeded random corpora with idle runs (the sparse/idle encoder
    // paths), dense stretches, and varying shapes: every trace must
    // survive Trace -> binary -> Trace in memory, and the CSV ->
    // binary -> CSV file chain the `trace convert` CLI moves.
    for seed in 1u64..=8 {
        let mut rng = Rng::new(seed);
        let n_agents = 1 + (seed as usize % 4);
        let agents: Vec<String> =
            (0..n_agents).map(|i| format!("a{i}")).collect();
        let dt = 0.25 * seed as f64;
        let steps = 50 + seed * 17;
        let counts: Vec<Vec<f64>> = (0..steps).map(|_| {
            if rng.uniform() < 0.4 {
                vec![0.0; n_agents]
            } else {
                (0..n_agents)
                    .map(|_| (rng.uniform() * 4.0).floor())
                    .collect()
            }
        }).collect();
        let trace = Trace::new(agents, dt, counts).unwrap();

        let bin = BinTrace::from_bytes(trace_to_bytes(&trace).unwrap())
            .unwrap();
        assert_eq!(bin.to_trace().unwrap(), trace, "seed {seed}");

        let dir = TempDir::new("fuzz").unwrap();
        let csv = dir.path().join("t.csv");
        let atrb = dir.path().join("t.atrb");
        trace.save(&csv).unwrap();
        save_trace(&Trace::load(&csv).unwrap(), &atrb).unwrap();
        let back = BinTrace::open(&atrb).unwrap().to_trace().unwrap();
        let csv2 = dir.path().join("t2.csv");
        back.save(&csv2).unwrap();
        assert_eq!(Trace::load(&csv2).unwrap(), trace, "seed {seed}");
    }
}

#[test]
fn fluid_and_cluster_binary_replay_match_csv_replay() {
    let trace = Trace::paper_poisson(120, 7);
    let bin = BinTrace::from_bytes(trace_to_bytes(&trace).unwrap())
        .unwrap();

    // Fluid single-GPU: the binary source (skip-idle and dense paths
    // both) replays bit-identically to the CSV trace.
    let sim = Simulator::new(SimConfig::paper_poisson(),
                             AgentProfile::paper_agents());
    let want = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
    for got in [
        sim.run_source(&mut AdaptivePolicy::default(), &bin),
        sim.run_source_dense(&mut AdaptivePolicy::default(), &bin),
        sim.run_source(&mut AdaptivePolicy::default(), &trace),
    ] {
        assert_eq!(got.mean_latency(), want.mean_latency());
        assert_eq!(got.total_throughput(), want.total_throughput());
        assert_eq!(got.cost_dollars, want.cost_dollars);
    }

    // Cluster: same contract through the multi-GPU engine.
    let cluster = ClusterSimulator::new(
        SimConfig::paper(), AgentRegistry::paper(), 2, 1.0,
        Rebalancer::Static).unwrap();
    let want = cluster.run_source(&trace).unwrap();
    assert_eq!(cluster.run_source(&bin).unwrap(), want);
    assert_eq!(cluster.run_source_dense(&bin).unwrap(), want);
}

#[test]
fn burst_encoded_traces_collapse_bit_exactly_in_fluid_engines() {
    // A hand-built .atrb with all three frame kinds: a dense row, an
    // idle run, and burst steps carrying sub-dt timestamps.
    let agents: Vec<String> = AgentProfile::paper_agents().iter()
        .map(|p| p.name.clone()).collect();
    let dt = 0.5;
    let mut w = BinTraceWriter::new(Vec::new(), &agents, dt).unwrap();
    w.push_row(&[2.0, 0.0, 1.0, 0.0]).unwrap();
    w.push_idle(5).unwrap();
    for step in 6u64..30 {
        let t0 = step as f64 * dt;
        w.push_burst_step(&[
            BurstEvent { agent: (step % 4) as u32, count: 2.0,
                         t_s: t0 + 0.1 },
            BurstEvent { agent: ((step + 1) % 4) as u32, count: 1.0,
                         t_s: t0 + 0.4 },
        ]).unwrap();
    }
    w.push_row(&[0.0, 3.0, 0.0, 1.0]).unwrap();
    let bin = BinTrace::from_bytes(w.finish().unwrap()).unwrap();
    assert_eq!(bin.steps(), 31);

    // The dense collapse sums each burst step's counts.
    let collapsed = bin.to_trace().unwrap();
    let mut row = vec![0.0; 4];
    collapsed.fill_row(6, &mut row);
    assert_eq!(row, [0.0, 0.0, 2.0, 1.0]);

    // Fluid engines consume bursts by summation, so replaying the
    // binary form is bit-identical to replaying its dense collapse.
    let sim = Simulator::new(SimConfig::paper(),
                             AgentProfile::paper_agents());
    let want = sim.run_trace(&mut AdaptivePolicy::default(), &collapsed);
    for got in [
        sim.run_source(&mut AdaptivePolicy::default(), &bin),
        sim.run_source_dense(&mut AdaptivePolicy::default(), &bin),
    ] {
        assert_eq!(got.mean_latency(), want.mean_latency());
        assert_eq!(got.total_throughput(), want.total_throughput());
        assert_eq!(got.cost_dollars, want.cost_dollars);
    }

    let cluster = ClusterSimulator::new(
        SimConfig::paper(), AgentRegistry::paper(), 2, 1.0,
        Rebalancer::Static).unwrap();
    assert_eq!(cluster.run_source(&bin).unwrap(),
               cluster.run_source(&collapsed).unwrap());
}

#[test]
fn serving_replay_matches_across_formats_and_is_deterministic() {
    let mut cfg = ServingConfig::paper();
    cfg.duration_s = 3.0;
    let sim = ServingSimulator::with_registry(cfg,
                                              AgentRegistry::paper());

    // A dense recorded trace replays identically from CSV and binary.
    let trace = Trace::paper_poisson(30, 11);
    let bin = BinTrace::from_bytes(trace_to_bytes(&trace).unwrap())
        .unwrap();
    let want = sim.run_trace(&mut PolicyKind::adaptive(), &trace);
    assert_eq!(sim.run_source(&mut PolicyKind::adaptive(), &bin), want);

    // A live run's burst-timestamped recording replays bit-identically,
    // and deterministically so.
    let (original, recorded) =
        sim.run_recording(&mut PolicyKind::adaptive());
    let a = sim.run_source(&mut PolicyKind::adaptive(), &recorded);
    let b = sim.run_source(&mut PolicyKind::adaptive(), &recorded);
    assert_eq!(a, b, "replay must be deterministic");
    assert_eq!(a, original, "replay must reproduce the live run");
}

#[test]
fn eq2_objective_ranks_adaptive_over_round_robin() {
    let sim = Simulator::new(SimConfig::paper(),
                             AgentProfile::paper_agents());
    let adaptive = sim.run(&mut AdaptivePolicy::default());
    let static_eq = sim.run(&mut StaticEqualPolicy);
    let mut rr = agentsrv::allocator::RoundRobinPolicy::default();
    let round_robin = sim.run(&mut rr);

    // With any latency-dominated weighting, adaptive and static crush
    // round-robin under the paper's Eq. 2 (lower = better).
    let (a, b, g) = (1.0, 100.0, 1.0);
    let obj_a = adaptive.objective(a, b, g);
    let obj_s = static_eq.objective(a, b, g);
    let obj_r = round_robin.objective(a, b, g);
    assert!(obj_a < obj_r && obj_s < obj_r,
            "adaptive {obj_a}, static {obj_s}, rr {obj_r}");
    // Throughput-dominated weighting flips static slightly ahead of
    // adaptive (the 3.2% tput sacrifice), but never rescues RR.
    let obj_a2 = adaptive.objective(0.0, 0.0, 1.0);
    let obj_s2 = static_eq.objective(0.0, 0.0, 1.0);
    assert!(obj_s2 <= obj_a2);
}
