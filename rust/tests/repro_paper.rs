//! The paper's evaluation, asserted end-to-end: every Table II number,
//! the Fig 2 shapes, and the §V.B robustness claims. This is the
//! "reproduction contract" — if these pass, the repo regenerates the
//! paper (see EXPERIMENTS.md for the measured-vs-paper table).

use agentsrv::repro;

#[test]
fn table2_static_equal_row() {
    let rows = repro::table2();
    let r = rows.iter().find(|r| r.policy == "static_equal").unwrap();
    assert!((r.avg_latency_s - 110.3).abs() < 0.5, "{}", r.avg_latency_s);
    assert!((r.total_throughput_rps - 60.0).abs() < 0.3,
            "{}", r.total_throughput_rps);
    assert!((r.cost_dollars - 0.020).abs() < 1e-6);
    // Paper reports 4.2; the deterministic closed form gives ~6 (std over
    // four per-agent means). Same order, same ranking vs round-robin.
    assert!(r.latency_std_s > 2.0 && r.latency_std_s < 10.0,
            "{}", r.latency_std_s);
}

#[test]
fn table2_round_robin_row() {
    let rows = repro::table2();
    let r = rows.iter().find(|r| r.policy == "round_robin").unwrap();
    assert!((r.avg_latency_s - 756.1).abs() < 2.0, "{}", r.avg_latency_s);
    assert!(r.latency_std_s < 1.5, "{}", r.latency_std_s);
    assert!((r.total_throughput_rps - 60.0).abs() < 0.5,
            "{}", r.total_throughput_rps);
    assert!((r.cost_dollars - 0.020).abs() < 1e-6);
}

#[test]
fn table2_adaptive_row() {
    let rows = repro::table2();
    let r = rows.iter().find(|r| r.policy == "adaptive").unwrap();
    assert!((r.avg_latency_s - 111.9).abs() < 0.6, "{}", r.avg_latency_s);
    assert!((r.total_throughput_rps - 58.1).abs() < 0.3,
            "{}", r.total_throughput_rps);
    assert!((r.cost_dollars - 0.020).abs() < 1e-6);
}

#[test]
fn headline_85_percent_latency_reduction() {
    let rows = repro::table2();
    let rr = rows.iter().find(|r| r.policy == "round_robin").unwrap();
    let ad = rows.iter().find(|r| r.policy == "adaptive").unwrap();
    let reduction = 1.0 - ad.avg_latency_s / rr.avg_latency_s;
    // Paper: "85% latency reduction compared to round-robin".
    assert!((reduction - 0.85).abs() < 0.02, "reduction = {reduction}");
}

#[test]
fn fig2a_per_agent_latency_shape() {
    let series = repro::fig2a();
    let adaptive = series.iter().find(|s| s.policy == "adaptive").unwrap();
    // Paper §V.A: reasoning lowest at 91.6s, vision highest at 128.6s.
    assert!((adaptive.values[3] - 91.7).abs() < 0.6,
            "reasoning {}", adaptive.values[3]);
    assert!((adaptive.values[2] - 128.6).abs() < 0.7,
            "vision {}", adaptive.values[2]);
    // Round-robin: near-uniform ~756 s for every agent.
    let rr = series.iter().find(|s| s.policy == "round_robin").unwrap();
    for v in &rr.values {
        assert!((v - 756.0).abs() < 3.0, "{v}");
    }
}

#[test]
fn fig2b_throughput_shape() {
    let series = repro::fig2b();
    let adaptive = series.iter().find(|s| s.policy == "adaptive").unwrap();
    // Paper: "coordinator maintains high throughput (approximately 20
    // rps) despite minimal GPU allocation".
    assert!((adaptive.values[0] - 23.9).abs() < 2.0,
            "coordinator {}", adaptive.values[0]);
    let total: f64 = adaptive.values.iter().sum();
    assert!((total - 58.1).abs() < 0.3);
    // Static equal splits capacity: 25/12.5/15/7.5.
    let st = series.iter().find(|s| s.policy == "static_equal").unwrap();
    for (got, want) in st.values.iter().zip([25.0, 12.5, 15.0, 7.5]) {
        assert!((got - want).abs() < 0.2, "{got} vs {want}");
    }
}

#[test]
fn fig2c_alloc_timeline_matches_algorithm1_fixed_point() {
    let ts = repro::fig2c();
    assert_eq!(ts.len(), 100);
    // Time-averaged allocations match the closed-form Algorithm 1 output
    // (DESIGN.md §1); Poisson noise wiggles per-step values only.
    let expected = [0.2386, 0.2538, 0.2115, 0.2961];
    for (i, want) in expected.iter().enumerate() {
        let series = ts.series(i);
        let mean: f64 = series.iter().sum::<f64>() / series.len() as f64;
        assert!((mean - want).abs() < 0.02, "agent {i}: {mean} vs {want}");
    }
}

#[test]
fn fig2d_cost_performance_clusters() {
    let pts = repro::fig2d();
    for p in &pts {
        // Identical cost across strategies (paper: all $0.020).
        assert!((p.cost_dollars - 0.020).abs() < 1e-6, "{}", p.policy);
    }
    let ad = pts.iter().find(|p| p.policy == "adaptive").unwrap();
    let st = pts.iter().find(|p| p.policy == "static_equal").unwrap();
    let rr = pts.iter().find(|p| p.policy == "round_robin").unwrap();
    assert!((ad.avg_latency_s - st.avg_latency_s).abs() < 5.0);
    assert!(rr.avg_latency_s / st.avg_latency_s > 6.0);
}

#[test]
fn robustness_overload_graceful() {
    let r = repro::overload_experiment(3.0);
    // §V.B: graceful degradation, starvation prevented. (The paper's
    // "24%" figure is not reproducible from its own model — see
    // EXPERIMENTS.md; the defensible claims are degradation boundedness
    // and starvation-freedom.)
    assert!(r.overload_latency_s > r.baseline_latency_s);
    assert!(r.overload_latency_s < 1000.0, "hit estimator cap");
    assert!(r.overload_min_throughput > 0.0);
    assert!((r.overload_min_throughput - r.baseline_min_throughput).abs()
            < 0.2);
}

#[test]
fn robustness_spike_under_100ms() {
    let r = repro::spike_experiment();
    assert!(r.adaptation_ms <= 100.0, "{} ms", r.adaptation_ms);
    assert!(r.post_spike_alloc > r.pre_spike_alloc * 1.3);
}

#[test]
fn robustness_dominance_no_monopoly() {
    let r = repro::dominance_experiment(0.9);
    assert!(r.dominant_gpu_share < 0.55, "{}", r.dominant_gpu_share);
    for (name, _, gpu) in &r.agents[1..] {
        assert!(*gpu > 0.1, "{name} starved");
    }
}

#[test]
fn robustness_allocator_linear_sub_ms() {
    let pts = repro::scaling_experiment(&[4, 256, 4096]);
    for p in &pts {
        // §V.B: "allocation computation consuming under 1 ms".
        assert!(p.ns_per_call < 1_000_000.0,
                "N={}: {} ns", p.n_agents, p.ns_per_call);
    }
}
