//! Property-based tests over the allocator and simulator invariants
//! (in-tree `util::check` harness; see DESIGN.md §2).

use agentsrv::agents::{AgentProfile, AgentRegistry, Priority};
use agentsrv::allocator::{all_policies, policy_by_name, AllocContext,
                          PolicyKind};
use agentsrv::cluster::{ClusterSimulator, MigrationModel,
                        PlacementStrategy, Rebalancer};
use agentsrv::server::{ServingConfig, ServingSimulator};
use agentsrv::serverless::{EconomicsModel, GpuPricing};
use agentsrv::sim::batch::{run_batch, run_sweep, CellResult,
                           ClusterScenario, CostScenario, FaultScenario,
                           Scenario, ServingScenario, SweepCell,
                           TraceScenario, WorkflowScenario};
use agentsrv::sim::fault::{AdmissionControl, FaultConfig, FaultEvent,
                           FaultModel, FaultPlan, RetryPolicy,
                           ServingFaults, ShedPolicy};
use agentsrv::sim::{SimConfig, Simulator};
use agentsrv::util::check::{forall, vec_uniform};
use agentsrv::util::Rng;
use agentsrv::workload::trace::Trace;
use agentsrv::workload::{ArrivalProcess, WorkflowSpec, WorkflowWorkload,
                         WorkloadKind};

/// Random but always-valid agent set: minimums jointly feasible.
fn gen_agents(rng: &mut Rng) -> (Vec<AgentProfile>, Vec<f64>) {
    let n = 1 + rng.below(8) as usize;
    let mut mins = vec_uniform(rng, n, 0.0, 1.0);
    let total: f64 = mins.iter().sum();
    // Scale so Σ min ∈ [0, 1): feasible with headroom.
    let scale = rng.uniform() * 0.95 / total.max(1e-9);
    for m in &mut mins {
        *m *= scale;
    }
    let agents = (0..n).map(|i| AgentProfile {
        name: format!("a{i}"),
        model_mb: 100 + rng.below(4000) as u32,
        base_tput: 1.0 + rng.uniform() * 120.0,
        min_gpu: mins[i],
        priority: match rng.below(3) {
            0 => Priority::High,
            1 => Priority::Medium,
            _ => Priority::Low,
        },
    }).collect();
    let rates = vec_uniform(rng, n, 0.0, 200.0);
    (agents, rates)
}

#[test]
fn prop_every_policy_respects_capacity_and_nonnegativity() {
    forall(0xA110C, 300, |rng| gen_agents(rng), |(agents, rates)| {
        let reg = AgentRegistry::new(agents.clone())
            .map_err(|e| e.to_string())?;
        let queues = vec![0.0; reg.len()];
        for mut policy in all_policies() {
            let mut out = vec![0.0; reg.len()];
            for step in 0..5 {
                let ctx = AllocContext {
                    registry: &reg,
                    arrival_rates: rates,
                    queue_depths: &queues,
                    step,
                    capacity: 1.0,
                };
                policy.allocate(&ctx, &mut out);
                let total: f64 = out.iter().sum();
                if total > 1.0 + 1e-9 {
                    return Err(format!(
                        "{}: Σg = {total} > capacity", policy.name()));
                }
                if out.iter().any(|g| *g < 0.0 || !g.is_finite()) {
                    return Err(format!(
                        "{}: bad fraction in {out:?}", policy.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_monotone_in_arrival_rate() {
    // Raising one agent's arrivals (before floors bind) must not *reduce*
    // its proportional share relative to an unchanged peer.
    forall(0xB0057, 200, |rng| {
        let (agents, rates) = gen_agents(rng);
        let bumped = rng.below(agents.len() as u64) as usize;
        (agents, rates, bumped)
    }, |(agents, rates, bumped)| {
        let reg = AgentRegistry::new(agents.clone())
            .map_err(|e| e.to_string())?;
        let queues = vec![0.0; reg.len()];
        let mut base = vec![0.0; reg.len()];
        let mut more = vec![0.0; reg.len()];
        let mut policy = agentsrv::allocator::AdaptivePolicy::default();
        use agentsrv::allocator::AllocationPolicy;

        let ctx = AllocContext {
            registry: &reg, arrival_rates: rates,
            queue_depths: &queues, step: 0, capacity: 1.0,
        };
        policy.allocate(&ctx, &mut base);

        let mut rates2 = rates.clone();
        rates2[*bumped] = rates2[*bumped] * 2.0 + 1.0;
        let ctx2 = AllocContext {
            registry: &reg, arrival_rates: &rates2,
            queue_depths: &queues, step: 0, capacity: 1.0,
        };
        policy.allocate(&ctx2, &mut more);

        if more[*bumped] + 1e-9 < base[*bumped] {
            return Err(format!(
                "allocation dropped after demand rise: {} -> {}",
                base[*bumped], more[*bumped]));
        }
        Ok(())
    });
}

#[test]
fn prop_simulation_conserves_requests_and_money() {
    forall(0x51A1, 60, |rng| {
        let (agents, rates) = gen_agents(rng);
        let poisson = rng.uniform() < 0.5;
        let seed = rng.next_u64();
        (agents, rates, poisson, seed)
    }, |(agents, rates, poisson, seed)| {
        let cfg = SimConfig {
            steps: 50,
            dt: 1.0,
            capacity: 1.0,
            latency_cap_s: 1000.0,
            pricing: GpuPricing::t4(),
            arrival_rates: rates.clone(),
            workload_kind: WorkloadKind::Steady,
            arrival_process: if *poisson {
                ArrivalProcess::Poisson
            } else {
                ArrivalProcess::Deterministic
            },
            seed: *seed,
            record_timelines: false,
            economics: None,
            faults: None,
            workflow: None,
        };
        let sim = Simulator::new(cfg, agents.clone());
        for mut policy in all_policies() {
            let r = sim.run(policy.as_mut());
            // Conservation: arrived == processed + still queued.
            if r.conservation_error() > 1e-6 {
                return Err(format!(
                    "{}: conservation error {}",
                    r.policy, r.conservation_error()));
            }
            // Cost never exceeds full-GPU-for-the-whole-run.
            let max_cost = GpuPricing::t4().cost(1.0, 50.0);
            if r.cost_dollars > max_cost + 1e-12 {
                return Err(format!(
                    "{}: cost {} > physical max {max_cost}",
                    r.policy, r.cost_dollars));
            }
            // Latencies within [0, cap]; throughput non-negative.
            for a in &r.per_agent {
                if a.latency.max() > 1000.0 + 1e-9
                    || a.latency.min() < 0.0 {
                    return Err(format!(
                        "{}: latency out of bounds", r.policy));
                }
                if a.throughput.min() < 0.0 {
                    return Err(format!(
                        "{}: negative throughput", r.policy));
                }
                if a.utilization.max() > 1.0 + 1e-9 {
                    return Err(format!(
                        "{}: utilization > 1", r.policy));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_throughput_bounded_by_capacity_and_arrivals() {
    forall(0x7097, 80, |rng| gen_agents(rng), |(agents, rates)| {
        let cfg = SimConfig {
            steps: 40,
            dt: 1.0,
            capacity: 1.0,
            latency_cap_s: 1000.0,
            pricing: GpuPricing::t4(),
            arrival_rates: rates.clone(),
            workload_kind: WorkloadKind::Steady,
            arrival_process: ArrivalProcess::Deterministic,
            seed: 1,
            record_timelines: false,
            economics: None,
            faults: None,
            workflow: None,
        };
        let sim = Simulator::new(cfg, agents.clone());
        for mut policy in all_policies() {
            let r = sim.run(policy.as_mut());
            for (i, a) in r.per_agent.iter().enumerate() {
                // Per-agent throughput can never beat full-GPU capacity,
                // nor (cumulatively) the arrivals.
                if a.throughput.max() > agents[i].base_tput + 1e-9 {
                    return Err(format!(
                        "{}: agent {i} tput {} > T_i {}",
                        r.policy, a.throughput.max(), agents[i].base_tput));
                }
                if a.processed_total > a.arrived_total + 1e-9 {
                    return Err(format!(
                        "{}: processed more than arrived", r.policy));
                }
            }
        }
        Ok(())
    });
}

/// `sim::batch` must be a pure speedup: for every built-in policy and
/// both arrival processes, at 1 and at 8 workers, each scenario's
/// headline metrics are bit-identical (`==`, no tolerance) to a
/// sequential `Simulator::run` of the same cell through the `dyn` path.
#[test]
fn prop_batch_is_bit_identical_to_sequential_run() {
    for process in [ArrivalProcess::Deterministic, ArrivalProcess::Poisson] {
        let mut scenarios = Vec::new();
        let mut expected = Vec::new();
        for kind in PolicyKind::all() {
            let mut cfg = SimConfig::paper();
            cfg.arrival_process = process;
            let registry = AgentRegistry::paper();

            let sequential = Simulator::with_registry(
                cfg.clone(), registry.clone());
            let mut reference = policy_by_name(kind.name())
                .expect("built-in policy");
            expected.push(sequential.run(reference.as_mut()));

            scenarios.push(Scenario::new(kind.name(), cfg, registry,
                                         kind));
        }
        for workers in [1usize, 8] {
            let runs = run_batch(&scenarios, workers);
            assert_eq!(runs.len(), expected.len());
            for (got, want) in runs.iter().zip(&expected) {
                assert_eq!(got.result.policy, want.policy);
                assert!(
                    got.result.mean_latency() == want.mean_latency()
                        && got.result.total_throughput()
                            == want.total_throughput()
                        && got.result.cost_dollars == want.cost_dollars,
                    "{} @ {workers} workers ({process:?}): batch \
                     diverged from sequential (latency {} vs {}, tput \
                     {} vs {}, cost {} vs {})",
                    want.policy, got.result.mean_latency(),
                    want.mean_latency(), got.result.total_throughput(),
                    want.total_throughput(), got.result.cost_dollars,
                    want.cost_dollars);
            }
        }
    }
}

/// The same contract holds per-agent, not just in the aggregates.
#[test]
fn prop_batch_matches_sequential_per_agent() {
    let scenarios: Vec<Scenario> = PolicyKind::all().into_iter()
        .map(|p| Scenario::paper(p.name(), p))
        .collect();
    let runs = run_batch(&scenarios, 8);
    for (run, sc) in runs.iter().zip(&scenarios) {
        let mut policy = policy_by_name(sc.policy.name()).unwrap();
        let want = sc.simulator().run(policy.as_mut());
        for (a, b) in run.result.per_agent.iter().zip(&want.per_agent) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.latency.mean(), b.latency.mean(),
                       "{}/{}", run.label, a.name);
            assert_eq!(a.throughput.mean(), b.throughput.mean());
            assert_eq!(a.processed_total, b.processed_total);
            assert_eq!(a.final_queue, b.final_queue);
        }
    }
}

/// Cluster cells through the sweep engine must be a pure speedup: for
/// migration on/off, both arrival processes, and a skewed workload that
/// actually triggers migrations, every cell's full [`ClusterResult`] is
/// bit-identical (`==`, no tolerance) to a sequential
/// `ClusterSimulator::run` of the same cell, at 1, 2, and 8 workers.
#[test]
fn prop_cluster_sweep_is_bit_identical_to_sequential_run() {
    for process in [ArrivalProcess::Deterministic, ArrivalProcess::Poisson] {
        for rebalancer in [
            Rebalancer::Static,
            Rebalancer::HottestAgent(MigrationModel::default()),
        ] {
            let mut cells = Vec::new();
            let mut expected = Vec::new();
            for (shape, kind) in [
                ("steady", WorkloadKind::Steady),
                ("domskew", WorkloadKind::Dominance { agent: 0, share: 0.9 }),
            ] {
                for (gpus, cap) in
                    [(1usize, 1.0), (2, 1.0), (2, 0.6), (4, 1.0)]
                {
                    let mut cfg = SimConfig::paper();
                    cfg.workload_kind = kind.clone();
                    cfg.arrival_process = process;
                    let sequential = ClusterSimulator::new(
                        cfg.clone(), AgentRegistry::paper(), gpus, cap,
                        rebalancer.clone()).unwrap();
                    expected.push(sequential.run().unwrap());
                    cells.push(SweepCell::Cluster(ClusterScenario::new(
                        format!("{shape}/{gpus}gpu/cap{cap}"), cfg,
                        AgentRegistry::paper(), gpus, cap,
                        rebalancer.clone()).unwrap()));
                }
            }
            for workers in [1usize, 2, 8] {
                let runs = run_sweep(&cells, workers);
                assert_eq!(runs.len(), expected.len());
                for (got, want) in runs.iter().zip(&expected) {
                    let cluster = got.result.as_cluster()
                        .expect("cluster cell yields ClusterResult");
                    assert_eq!(
                        cluster, want,
                        "{} @ {workers} workers ({process:?}, \
                         rebalancer {}): sweep diverged from sequential",
                        got.label, rebalancer.name());
                }
            }
        }
    }
}

/// Placement cells hold the same pure-speedup contract across the whole
/// new axis: every [`PlacementStrategy`] × [`Rebalancer`] combination
/// over the paper deployment (under 90 % dominance skew, so the active
/// rebalancers really migrate), plus synthetic large-N registries (64
/// and 256 agents on mixed-capacity devices), each cell's full
/// [`ClusterResult`] bit-identical (`==`, no tolerance) to a sequential
/// `ClusterSimulator::run`, at 1, 2, and 8 workers.
///
/// [`ClusterResult`]: agentsrv::cluster::ClusterResult
#[test]
fn prop_placement_sweep_is_bit_identical_to_sequential_run() {
    let caps = vec![1.0, 0.75, 0.5, 0.25];
    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for strategy in PlacementStrategy::all() {
        for rebalancer in Rebalancer::all() {
            let mut cfg = SimConfig::paper();
            cfg.workload_kind = WorkloadKind::Dominance {
                agent: 0, share: 0.9,
            };
            let sequential = ClusterSimulator::with_policies(
                cfg.clone(), AgentRegistry::paper(), caps.clone(),
                strategy, rebalancer.clone()).unwrap();
            expected.push(sequential.run().unwrap());
            cells.push(SweepCell::Cluster(ClusterScenario::with_policies(
                format!("placement/{}/{}", strategy.name(),
                        rebalancer.name()),
                cfg, AgentRegistry::paper(), caps.clone(), strategy,
                rebalancer).unwrap()));
        }
    }
    // Synthetic large-N registries (the ≥ 64-agent acceptance bar) ride
    // the same contract, under the repack rebalancer so the mid-run
    // re-solve path is covered at scale.
    for n in [64usize, 256] {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = agentsrv::repro::synthetic_arrival_rates(n);
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let registry = agentsrv::repro::synthetic_registry(n);
        let sequential = ClusterSimulator::with_policies(
            cfg.clone(), registry.clone(), caps.clone(),
            PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        expected.push(sequential.run().unwrap());
        cells.push(SweepCell::Cluster(ClusterScenario::with_policies(
            format!("placement/synth{n}/demand/repack"), cfg, registry,
            caps.clone(), PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default())).unwrap()));
    }
    // The rebalancing paths must actually fire inside this grid.
    assert!(expected.iter().any(|r| r.migrations >= 1),
            "no placement cell migrated");
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let cluster = got.result.as_cluster()
                .expect("placement cell yields ClusterResult");
            assert_eq!(cluster, want, "{} @ {workers} workers",
                       got.label);
        }
    }
}

/// Trace-replay cells through the sweep engine match a direct
/// `Simulator::run_trace` of the same recorded stream, for every
/// built-in policy at 1, 2, and 8 workers — aggregates and per-agent
/// series alike.
#[test]
fn prop_trace_sweep_is_bit_identical_to_run_trace() {
    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for seed in [7u64, 42] {
        let trace = Trace::paper_poisson(60, seed);
        for kind in PolicyKind::all() {
            let sequential = Simulator::with_registry(
                SimConfig::paper(), AgentRegistry::paper());
            let mut reference = policy_by_name(kind.name())
                .expect("built-in policy");
            expected.push(
                sequential.run_trace(reference.as_mut(), &trace));
            cells.push(SweepCell::Trace(TraceScenario::new(
                format!("{}/seed{seed}", kind.name()), SimConfig::paper(),
                AgentRegistry::paper(), trace.clone(), kind)));
        }
    }
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let sim = got.result.as_sim()
                .expect("trace cell yields SimResult");
            assert!(
                sim.mean_latency() == want.mean_latency()
                    && sim.total_throughput() == want.total_throughput()
                    && sim.cost_dollars == want.cost_dollars,
                "{} @ {workers} workers: trace sweep diverged (latency \
                 {} vs {}, tput {} vs {}, cost {} vs {})",
                got.label, sim.mean_latency(), want.mean_latency(),
                sim.total_throughput(), want.total_throughput(),
                sim.cost_dollars, want.cost_dollars);
            for (a, b) in sim.per_agent.iter().zip(&want.per_agent) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency.mean(), b.latency.mean(),
                           "{}/{}", got.label, a.name);
                assert_eq!(a.throughput.mean(), b.throughput.mean());
                assert_eq!(a.processed_total, b.processed_total);
                assert_eq!(a.final_queue, b.final_queue);
            }
        }
    }
}

/// `CostScenario` cells through the sweep engine must be a pure
/// speedup: for every built-in policy, over both the Table II all-warm
/// setting and an idle-burst workload with scale-to-zero, every cell is
/// bit-identical (`==`, no tolerance) to a sequential `Simulator::run`
/// of the same config through the `dyn` path, at 1, 2, and 8 workers —
/// aggregates, per-agent series, and the full economics report alike.
#[test]
fn prop_cost_sweep_is_bit_identical_to_sequential_run() {
    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for (setting, cfg, economics) in [
        ("warm", SimConfig::paper(), EconomicsModel::paper_all_warm()),
        ("s2z", agentsrv::repro::idle_burst_config(100, 7),
         EconomicsModel::with_idle_timeout(5.0)),
        // 0.3 s quantum does not divide the 1 s step, so quantum
        // rounding actually changes the billed amounts here.
        ("s2z-quantum", agentsrv::repro::idle_burst_config(100, 9), {
            let mut e = EconomicsModel::with_idle_timeout(5.0);
            e.pricing.billing_quantum_s = 0.3;
            e
        }),
    ] {
        for kind in PolicyKind::all() {
            let mut seq_cfg = cfg.clone();
            seq_cfg.economics = Some(economics.clone());
            let sequential = Simulator::with_registry(
                seq_cfg, AgentRegistry::paper());
            let mut reference = policy_by_name(kind.name())
                .expect("built-in policy");
            expected.push(sequential.run(reference.as_mut()));

            cells.push(SweepCell::Cost(CostScenario::new(
                format!("cost/{}/{setting}", kind.name()), cfg.clone(),
                AgentRegistry::paper(), economics.clone(), kind)));
        }
    }
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let sim = got.result.as_sim()
                .expect("cost cell yields SimResult");
            assert!(
                sim.mean_latency() == want.mean_latency()
                    && sim.total_throughput() == want.total_throughput()
                    && sim.cost_dollars == want.cost_dollars,
                "{} @ {workers} workers: cost sweep diverged (latency \
                 {} vs {}, tput {} vs {}, cost {} vs {})",
                got.label, sim.mean_latency(), want.mean_latency(),
                sim.total_throughput(), want.total_throughput(),
                sim.cost_dollars, want.cost_dollars);
            assert_eq!(sim.economics, want.economics,
                       "{} @ {workers} workers", got.label);
            assert!(want.economics.is_some(),
                    "{}: economics must be on", got.label);
            for (a, b) in sim.per_agent.iter().zip(&want.per_agent) {
                assert_eq!(a.latency.mean(), b.latency.mean(),
                           "{}/{}", got.label, a.name);
                assert_eq!(a.processed_total, b.processed_total);
                assert_eq!(a.final_queue, b.final_queue);
            }
        }
    }
}

/// Economics-enabled cluster cells hold the same contract: with
/// scale-to-zero and cold starts active on a multi-GPU cluster, the
/// full [`ClusterResult`] (economics report included — the struct
/// derives `PartialEq`) is bit-identical to a sequential
/// `ClusterSimulator::run` at 1, 2, and 8 workers.
#[test]
fn prop_economics_cluster_sweep_is_bit_identical_to_sequential_run() {
    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for economics in [
        EconomicsModel::paper_all_warm(),
        EconomicsModel::with_idle_timeout(5.0),
    ] {
        for (gpus, cap) in [(1usize, 1.0), (2, 1.0), (4, 1.0)] {
            let mut cfg = agentsrv::repro::idle_burst_config(100, 11);
            cfg.economics = Some(economics.clone());
            let sequential = ClusterSimulator::new(
                cfg.clone(), AgentRegistry::paper(), gpus, cap,
                Rebalancer::Static).unwrap();
            expected.push(sequential.run().unwrap());
            cells.push(SweepCell::Cluster(ClusterScenario::new(
                format!("econ-cluster/{gpus}gpu/warm{}",
                        economics.idle_timeout_s), cfg,
                AgentRegistry::paper(), gpus, cap,
                Rebalancer::Static).unwrap()));
        }
    }
    // The scale-to-zero cells must actually exercise the lifecycle.
    assert!(expected.iter().any(|r| r.economics.as_ref()
            .is_some_and(|e| e.total_cold_starts() > 0)),
            "no cluster cell cold-started");
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let cluster = got.result.as_cluster()
                .expect("cluster cell yields ClusterResult");
            assert_eq!(cluster, want, "{} @ {workers} workers",
                       got.label);
        }
    }
}

/// The headline economics claim, end to end: under the paper's all-warm
/// settings every full-GPU policy reproduces Table II's cost row
/// ($0.020 per 100 s — cost cannot separate the policies), and a finite
/// scale-to-zero timeout breaks that tie.
#[test]
fn prop_economics_experiment_reproduces_table2_cost_row() {
    let rows = agentsrv::repro::economics_experiment(100);
    assert_eq!(rows.len(), PolicyKind::all().len());
    for row in &rows {
        assert!((row.paper_warm_cost - 0.020).abs() < 1e-6,
                "{}: paper all-warm cost {}", row.policy,
                row.paper_warm_cost);
    }
    let costs: Vec<f64> = rows.iter().map(|r| r.burst_s2z_cost).collect();
    let spread = costs.iter().cloned().fold(f64::MIN, f64::max)
        - costs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 1e-4,
            "scale-to-zero should break the cost tie: {costs:?}");
}

/// Serving-layer cells through the sweep engine must be deterministic:
/// the *full* [`ServingResult`] — latency histograms, per-agent stats,
/// allocation trajectory, makespan — is bit-identical (`==`, no
/// tolerance) between a direct `ServingSimulator` run with fresh
/// buffers and `run_sweep` at 1, 2, and 8 workers, for every built-in
/// policy, across window/batch variants and recorded-trace inputs
/// alike.
///
/// [`ServingResult`]: agentsrv::server::ServingResult
#[test]
fn prop_serving_sweep_is_bit_identical_to_direct_runs() {
    let trace = Trace::paper_poisson(5, 42);
    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for kind in PolicyKind::all() {
        for (variant, max_batch, window_s) in
            [("b8w100", 8usize, 0.1), ("b1w50", 1, 0.05)]
        {
            let mut cfg = ServingConfig::paper();
            cfg.duration_s = 3.0;
            cfg.max_batch = max_batch;
            cfg.alloc_window_s = window_s;
            let sim = ServingSimulator::with_registry(
                cfg.clone(), AgentRegistry::paper());
            let mut reference = policy_by_name(kind.name())
                .expect("built-in policy");
            expected.push(sim.run(reference.as_mut()));
            cells.push(SweepCell::Serving(ServingScenario::new(
                format!("serving/{}/{variant}", kind.name()), cfg,
                AgentRegistry::paper(), kind.clone())));
        }
        // One recorded-trace serving cell per policy, sharing the
        // recording.
        let cfg = ServingConfig::paper();
        let sim = ServingSimulator::with_registry(
            cfg.clone(), AgentRegistry::paper());
        let mut reference = policy_by_name(kind.name())
            .expect("built-in policy");
        expected.push(sim.run_trace(reference.as_mut(), &trace));
        cells.push(SweepCell::Serving(ServingScenario::from_trace(
            format!("serving/{}/trace", kind.name()), cfg,
            AgentRegistry::paper(), trace.clone(), kind)));
    }
    // The cells must actually exercise the queue path.
    assert!(expected.iter().all(|r| r.total_completed > 0));
    assert!(expected.iter().all(|r| r.windows > 0));
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let serving = got.result.as_serving()
                .expect("serving cell yields ServingResult");
            assert_eq!(serving, want, "{} @ {workers} workers",
                       got.label);
        }
    }
}

/// Fault cells through the sweep engine hold the pure-speedup contract
/// across all three shells: single-GPU cells under a seeded spot plan
/// (and the empty-plan control), cluster cells under a 2-GPU spot plan
/// with the repack throttle armed for every rebalancer, and serving
/// cells with retry + every shed policy — each bit-identical (`==`, no
/// tolerance, `ResilienceReport` included) to a sequential run of the
/// same cell, at 1, 2, and 8 workers.
#[test]
fn prop_fault_sweep_is_bit_identical_to_sequential_run() {
    enum Want {
        Sim(agentsrv::sim::SimResult),
        Cluster(agentsrv::cluster::ClusterResult),
        Serving(agentsrv::server::ServingResult),
    }

    let mut cells = Vec::new();
    let mut expected = Vec::new();

    // Single-GPU: every policy × {seeded spot plan, empty-plan control}.
    for kind in PolicyKind::all() {
        for (tag, plan) in [
            ("spot", FaultModel::spot(0.01, 13).generate(1, 100.0)),
            ("none", FaultPlan::empty()),
        ] {
            let sc = FaultScenario::single(
                format!("fault/single/{}/{tag}", kind.name()),
                SimConfig::paper(), AgentRegistry::paper(), kind.clone(),
                FaultConfig::new(plan));
            let mut reference = policy_by_name(kind.name())
                .expect("built-in policy");
            expected.push(Want::Sim(sc.as_single().unwrap().simulator()
                                    .run(reference.as_mut())));
            cells.push(SweepCell::Fault(sc));
        }
    }
    // Cluster: every rebalancer recovering from the same 2-GPU spot
    // plan, single-repack moves throttled to half the deployment.
    for rebalancer in Rebalancer::all() {
        let sc = FaultScenario::cluster(
            format!("fault/cluster/{}", rebalancer.name()),
            SimConfig::paper(), AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing, rebalancer,
            FaultConfig::new(FaultModel::spot(0.02, 7).generate(2, 100.0))
                .with_repack_throttle(0.5)).unwrap();
        expected.push(Want::Cluster(sc.as_cluster_scenario().unwrap()
                                    .simulator().run().unwrap()));
        cells.push(SweepCell::Fault(sc));
    }
    // Serving: bounded retry over a mid-run eviction, plus every shed
    // policy under a bounded queue.
    for shed in ShedPolicy::all() {
        let name = shed.name();
        let mut cfg = ServingConfig::paper();
        cfg.duration_s = 2.0;
        let faults = ServingFaults::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 0.3, gpu: 0, duration: 0.02 },
        ])).with_retry(RetryPolicy::bounded())
           .with_admission(AdmissionControl::new(48, shed));
        let sc = FaultScenario::serving(
            format!("fault/serving/{name}"), cfg, AgentRegistry::paper(),
            PolicyKind::adaptive(), faults);
        let mut reference = policy_by_name("adaptive")
            .expect("built-in policy");
        expected.push(Want::Serving(sc.as_serving_scenario().unwrap()
                                    .simulator().run(reference.as_mut())));
        cells.push(SweepCell::Fault(sc));
    }

    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            match want {
                Want::Sim(w) => {
                    let s = got.result.as_sim().unwrap();
                    assert!(s.mean_latency() == w.mean_latency()
                            && s.total_throughput() == w.total_throughput()
                            && s.cost_dollars == w.cost_dollars,
                            "{} @ {workers} workers", got.label);
                    assert_eq!(s.resilience, w.resilience,
                               "{} @ {workers} workers", got.label);
                }
                Want::Cluster(w) => assert_eq!(
                    got.result.as_cluster().unwrap(), w,
                    "{} @ {workers} workers", got.label),
                Want::Serving(w) => assert_eq!(
                    got.result.as_serving().unwrap(), w,
                    "{} @ {workers} workers", got.label),
            }
        }
    }
}

/// The fault layer is zero-cost when disabled: a `FaultScenario` with
/// an empty plan yields the same numbers as the equivalent plain cell —
/// for every policy on the fluid shell (metrics, per-agent series) and
/// for the serving shell (full `ServingResult` equality) — at 1, 2,
/// and 8 workers.
#[test]
fn prop_zero_fault_cells_match_plain_cells() {
    let mut cells = Vec::new();
    for kind in PolicyKind::all() {
        cells.push(SweepCell::Single(Scenario::paper(
            format!("plain/{}", kind.name()), kind.clone())));
        cells.push(SweepCell::Fault(FaultScenario::single(
            format!("fault/{}", kind.name()), SimConfig::paper(),
            AgentRegistry::paper(), kind,
            FaultConfig::new(FaultPlan::empty()))));
    }
    let mut cfg = ServingConfig::paper();
    cfg.duration_s = 2.0;
    cells.push(SweepCell::Serving(ServingScenario::new(
        "plain/serving", cfg.clone(), AgentRegistry::paper(),
        PolicyKind::adaptive())));
    cells.push(SweepCell::Fault(FaultScenario::serving(
        "fault/serving", cfg, AgentRegistry::paper(),
        PolicyKind::adaptive(), ServingFaults::new(FaultPlan::empty()))));

    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), cells.len());
        for pair in runs.chunks(2) {
            let (plain, faulted) = (&pair[0], &pair[1]);
            if let (Some(p), Some(f)) =
                (plain.result.as_sim(), faulted.result.as_sim())
            {
                assert!(p.mean_latency() == f.mean_latency()
                        && p.total_throughput() == f.total_throughput()
                        && p.cost_dollars == f.cost_dollars,
                        "{} vs {} @ {workers} workers",
                        plain.label, faulted.label);
                for (a, b) in p.per_agent.iter().zip(&f.per_agent) {
                    assert_eq!(a.processed_total, b.processed_total);
                    assert_eq!(a.final_queue, b.final_queue);
                }
                assert!(f.resilience.is_none(),
                        "{}: inert faults must cost nothing",
                        faulted.label);
            } else {
                let p = plain.result.as_serving().unwrap();
                let f = faulted.result.as_serving().unwrap();
                assert_eq!(p, f, "{} vs {} @ {workers} workers",
                           plain.label, faulted.label);
                assert!(f.resilience.is_none());
            }
        }
    }
}

/// The serving simulator drives the same `ServingCore` as the threaded
/// `AgentServer`; at queue granularity the governor's compute-time
/// shares must still track the allocation, so the high-priority
/// reasoning agent is served strictly faster under the adaptive policy
/// than under static-equal.
#[test]
fn prop_serving_layer_preserves_allocation_semantics() {
    let mut cfg = ServingConfig::paper();
    cfg.duration_s = 5.0;
    let sim =
        ServingSimulator::with_registry(cfg, AgentRegistry::paper());
    let adaptive = sim.run(&mut PolicyKind::adaptive());
    let stat = sim.run(&mut PolicyKind::static_equal());
    assert!(adaptive.mean_latency_s[3] < stat.mean_latency_s[3],
            "reasoning: adaptive {} vs static {}",
            adaptive.mean_latency_s[3], stat.mean_latency_s[3]);
    // Work is conserved either way: every request is served once.
    assert_eq!(adaptive.total_completed, stat.total_completed);
    // GPU shares partition the busy time.
    for r in [&adaptive, &stat] {
        let shares: f64 = r.per_agent.iter().map(|a| a.gpu_share).sum();
        assert!((shares - 1.0).abs() < 1e-6, "{shares}");
    }
}

/// A mixed grid — single-GPU, cluster, trace, cost, serving, fault, and
/// workflow cells interleaved — runs through one pool with cell order
/// preserved and every kind bit-identical to its sequential twin at
/// every worker count.
#[test]
fn prop_mixed_sweep_is_bit_identical_per_cell_kind() {
    let trace = Trace::paper_poisson(50, 42);

    let mut cells = Vec::new();
    for kind in PolicyKind::all() {
        cells.push(SweepCell::Single(
            Scenario::paper(format!("single/{}", kind.name()),
                            kind.clone())));
        cells.push(SweepCell::Trace(TraceScenario::new(
            format!("trace/{}", kind.name()), SimConfig::paper(),
            AgentRegistry::paper(), trace.clone(), kind.clone())));
        cells.push(SweepCell::Cost(CostScenario::new(
            format!("cost/{}", kind.name()),
            agentsrv::repro::idle_burst_config(100, 42),
            AgentRegistry::paper(),
            EconomicsModel::with_idle_timeout(5.0), kind.clone())));
        let mut serving_cfg = ServingConfig::paper();
        serving_cfg.duration_s = 2.0;
        cells.push(SweepCell::Serving(ServingScenario::new(
            format!("serving/{}", kind.name()), serving_cfg,
            AgentRegistry::paper(), kind)));
    }
    for (gpus, rebalancer) in [
        (2usize, Rebalancer::Static),
        (2, Rebalancer::HottestAgent(MigrationModel::default())),
        (4, Rebalancer::Static),
    ] {
        cells.push(SweepCell::Cluster(ClusterScenario::new(
            format!("cluster/{gpus}gpu"), SimConfig::paper(),
            AgentRegistry::paper(), gpus, 1.0, rebalancer).unwrap()));
    }
    cells.push(SweepCell::Cluster(ClusterScenario::with_policies(
        "cluster/hetero/1+0.5".to_string(), SimConfig::paper(),
        AgentRegistry::paper(), vec![1.0, 0.5],
        PlacementStrategy::HeadroomDecreasing,
        Rebalancer::Static).unwrap()));
    // One fault cell per shell rides the same mixed pool.
    cells.push(SweepCell::Fault(FaultScenario::single(
        "fault/single/adaptive", SimConfig::paper(),
        AgentRegistry::paper(), PolicyKind::adaptive(),
        FaultConfig::new(FaultModel::spot(0.01, 42).generate(1, 100.0)))));
    cells.push(SweepCell::Fault(FaultScenario::cluster(
        "fault/cluster/repack", SimConfig::paper(), AgentRegistry::paper(),
        vec![1.2, 1.2], PlacementStrategy::HeadroomDecreasing,
        Rebalancer::Repack(MigrationModel::default()),
        FaultConfig::new(FaultModel::spot(0.01, 7).generate(2, 100.0))
            .with_repack_throttle(0.5)).unwrap()));
    let mut fault_serving_cfg = ServingConfig::paper();
    fault_serving_cfg.duration_s = 2.0;
    cells.push(SweepCell::Fault(FaultScenario::serving(
        "fault/serving/shed", fault_serving_cfg, AgentRegistry::paper(),
        PolicyKind::adaptive(),
        ServingFaults::new(FaultPlan::empty()).with_admission(
            AdmissionControl::new(64, ShedPolicy::DropByPriority)))));
    // One workflow cell per shell rides the same mixed pool — the
    // single-GPU one under the spec-weighted critical-path policy, so
    // the sweep must preserve the weights, not rebuild by name.
    cells.push(SweepCell::Workflow(WorkflowScenario::single(
        "workflow/single/critical_path", SimConfig::paper(),
        AgentRegistry::paper(),
        PolicyKind::critical_path_for(&WorkflowSpec::paper(), 4),
        WorkflowWorkload::paper()).unwrap()));
    cells.push(SweepCell::Workflow(WorkflowScenario::cluster(
        "workflow/cluster/colocate", SimConfig::paper(),
        AgentRegistry::paper(), vec![1.2, 1.2],
        PlacementStrategy::WorkflowColocate, Rebalancer::Static,
        WorkflowWorkload::paper()).unwrap()));
    let mut wf_serving_cfg = ServingConfig::paper();
    wf_serving_cfg.duration_s = 2.0;
    cells.push(SweepCell::Workflow(WorkflowScenario::serving(
        "workflow/serving/adaptive", wf_serving_cfg,
        AgentRegistry::paper(), PolicyKind::adaptive(),
        WorkflowWorkload::paper()).unwrap()));

    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), cells.len());
        for (run, cell) in runs.iter().zip(&cells) {
            assert_eq!(run.label, cell.label(), "order at {workers}");
            match cell {
                SweepCell::Single(sc) => {
                    let mut policy = policy_by_name(sc.policy.name())
                        .expect("built-in policy");
                    let want = sc.simulator().run(policy.as_mut());
                    let got = run.result.as_sim().unwrap();
                    assert!(got.mean_latency() == want.mean_latency()
                            && got.cost_dollars == want.cost_dollars,
                            "{} @ {workers}", run.label);
                }
                SweepCell::Cluster(sc) => {
                    let want = sc.simulator().run().unwrap();
                    let got = run.result.as_cluster().unwrap();
                    assert_eq!(got, &want, "{} @ {workers}", run.label);
                }
                SweepCell::Trace(sc) => {
                    let mut policy = policy_by_name(sc.policy.name())
                        .expect("built-in policy");
                    let want = sc.simulator()
                        .run_trace(policy.as_mut(), sc.trace());
                    let got = run.result.as_sim().unwrap();
                    assert!(got.mean_latency() == want.mean_latency()
                            && got.cost_dollars == want.cost_dollars,
                            "{} @ {workers}", run.label);
                }
                SweepCell::Cost(sc) => {
                    let mut policy = policy_by_name(sc.policy.name())
                        .expect("built-in policy");
                    let want = sc.simulator().run(policy.as_mut());
                    let got = run.result.as_sim().unwrap();
                    assert!(got.mean_latency() == want.mean_latency()
                            && got.cost_dollars == want.cost_dollars,
                            "{} @ {workers}", run.label);
                    assert_eq!(got.economics, want.economics,
                               "{} @ {workers}", run.label);
                }
                SweepCell::Serving(sc) => {
                    let mut policy = policy_by_name(sc.policy.name())
                        .expect("built-in policy");
                    let want = match sc.trace() {
                        Some(t) => sc.simulator()
                            .run_trace(policy.as_mut(), t),
                        None => sc.simulator().run(policy.as_mut()),
                    };
                    let got = run.result.as_serving().unwrap();
                    assert_eq!(got, &want, "{} @ {workers}", run.label);
                }
                SweepCell::Fault(sc) => {
                    if let Some(inner) = sc.as_cluster_scenario() {
                        let want = inner.simulator().run().unwrap();
                        assert_eq!(run.result.as_cluster().unwrap(), &want,
                                   "{} @ {workers}", run.label);
                    } else if let Some(inner) = sc.as_serving_scenario() {
                        let mut policy =
                            policy_by_name(inner.policy.name())
                                .expect("built-in policy");
                        let want = inner.simulator().run(policy.as_mut());
                        assert_eq!(run.result.as_serving().unwrap(), &want,
                                   "{} @ {workers}", run.label);
                    } else {
                        let inner = sc.as_single().unwrap();
                        let mut policy =
                            policy_by_name(inner.policy.name())
                                .expect("built-in policy");
                        let want = inner.simulator().run(policy.as_mut());
                        let got = run.result.as_sim().unwrap();
                        assert!(got.mean_latency() == want.mean_latency()
                                && got.cost_dollars == want.cost_dollars,
                                "{} @ {workers}", run.label);
                        assert_eq!(got.resilience, want.resilience,
                                   "{} @ {workers}", run.label);
                    }
                }
                SweepCell::Workflow(sc) => {
                    // The sequential twin clones the stored policy —
                    // rebuilding by name would flatten the spec-weighted
                    // critical-path policy back to its unweighted form.
                    if let Some(inner) = sc.as_cluster_scenario() {
                        let want = inner.simulator().run().unwrap();
                        assert_eq!(run.result.as_cluster().unwrap(), &want,
                                   "{} @ {workers}", run.label);
                        assert!(want.workflow.is_some(),
                                "{}: workflow stats must surface",
                                run.label);
                    } else if let Some(inner) = sc.as_serving_scenario() {
                        let mut policy = inner.policy.clone();
                        let want = inner.simulator().run(&mut policy);
                        assert_eq!(run.result.as_serving().unwrap(), &want,
                                   "{} @ {workers}", run.label);
                        assert!(want.workflow.is_some(),
                                "{}: workflow stats must surface",
                                run.label);
                    } else {
                        let inner = sc.as_single().unwrap();
                        let mut policy = inner.policy.clone();
                        let want = inner.simulator().run(&mut policy);
                        let got = run.result.as_sim().unwrap();
                        assert!(got.mean_latency() == want.mean_latency()
                                && got.cost_dollars == want.cost_dollars,
                                "{} @ {workers}", run.label);
                        assert_eq!(got.workflow, want.workflow,
                                   "{} @ {workers}", run.label);
                        assert!(want.workflow.is_some(),
                                "{}: workflow stats must surface",
                                run.label);
                    }
                }
            }
        }
    }
}

/// Every cell of the real `repro::workflow_grid` — spec shape × policy
/// × placement × seed across all three shells — is bit-identical
/// (full result types, workflow stats included) to a sequential run of
/// the same cell at 1, 2, and 8 workers, and every shell actually
/// completed workflow instances.
#[test]
fn prop_workflow_sweep_is_bit_identical_to_sequential_run() {
    let cells = agentsrv::repro::workflow_grid(20, &[1, 2]);
    assert!(!cells.is_empty());
    let mut expected = Vec::with_capacity(cells.len());
    for cell in &cells {
        let SweepCell::Workflow(sc) = cell else {
            panic!("workflow grid contains only workflow cells");
        };
        // Clone the stored policy: rebuilding by name would flatten the
        // spec-weighted critical-path cells back to unweighted form.
        if let Some(inner) = sc.as_cluster_scenario() {
            expected.push(CellResult::Cluster(
                inner.simulator().run().unwrap()));
        } else if let Some(inner) = sc.as_serving_scenario() {
            let mut policy = inner.policy.clone();
            expected.push(CellResult::Serving(
                inner.simulator().run(&mut policy)));
        } else {
            let inner = sc.as_single().unwrap();
            let mut policy = inner.policy.clone();
            expected.push(CellResult::Sim(
                inner.simulator().run(&mut policy)));
        }
    }
    // Every shell surfaces end-to-end stats with real completions.
    assert!(expected.iter().all(|r| r.workflow().is_some()));
    assert!(expected.iter()
            .any(|r| r.workflow().is_some_and(|w| w.completed > 0)),
            "no workflow cell completed an instance");
    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            match want {
                CellResult::Sim(w) => {
                    let s = got.result.as_sim().unwrap();
                    assert!(s.mean_latency() == w.mean_latency()
                            && s.total_throughput() == w.total_throughput()
                            && s.cost_dollars == w.cost_dollars,
                            "{} @ {workers} workers", got.label);
                    assert_eq!(s.workflow, w.workflow,
                               "{} @ {workers} workers", got.label);
                }
                CellResult::Cluster(w) => assert_eq!(
                    got.result.as_cluster().unwrap(), w,
                    "{} @ {workers} workers", got.label),
                CellResult::Serving(w) => assert_eq!(
                    got.result.as_serving().unwrap(), w,
                    "{} @ {workers} workers", got.label),
            }
        }
    }
}

/// A sparse fluid deployment: `n` agents, all floors zero (the
/// per-agent settle precondition), only `hot` receiving traffic via a
/// mid-run burst window — the shape the active-set tier compresses.
fn sparse_fluid(n: usize, hot: &[usize], steps: u64, seed: u64,
                process: ArrivalProcess) -> (SimConfig, AgentRegistry) {
    let profiles: Vec<AgentProfile> = (0..n).map(|i| AgentProfile {
        name: format!("a{i}"),
        model_mb: 600,
        base_tput: 30.0 + (i % 4) as f64 * 15.0,
        min_gpu: 0.0,
        priority: match i % 3 {
            0 => Priority::High,
            1 => Priority::Medium,
            _ => Priority::Low,
        },
    }).collect();
    let mut rates = vec![0.0; n];
    for (j, &i) in hot.iter().enumerate() {
        rates[i] = 25.0 + j as f64 * 10.0;
    }
    let mut cfg = SimConfig::paper();
    cfg.steps = steps;
    cfg.arrival_rates = rates;
    cfg.workload_kind = WorkloadKind::Burst {
        agents: hot.to_vec(),
        start: steps * 2 / 5,
        end: steps * 3 / 5,
    };
    cfg.arrival_process = process;
    cfg.seed = seed;
    (cfg, AgentRegistry::new(profiles).unwrap())
}

/// Every built-in policy on a sparse-burst fluid deployment: the
/// default `run` (the active-set tier when the policy is eligible, the
/// documented dense fallback otherwise) and `run_skip_idle` are both
/// bit-identical (`==`, no tolerance) to `run_dense`, for both arrival
/// processes — aggregates and per-agent series alike. The
/// globally-coupled policies must actually be on the fallback: their
/// fixed-point claims are pinned false here, so for them `run` *is*
/// the dense loop rather than a sparse approximation of it.
#[test]
fn prop_active_set_run_is_bit_identical_to_dense_for_every_policy() {
    use agentsrv::allocator::AllocationPolicy;
    let hot = [2usize, 9];
    // The dense-fallback contract, pinned: round-robin's rotating
    // pointer and static-equal's unconditional capacity/n grants
    // disclaim the whole-sim fixed point, which also gates the
    // active-set tier — so neither policy ever settles an agent.
    assert!(!PolicyKind::round_robin().idle_fixed_point(12));
    assert!(!PolicyKind::static_equal().idle_fixed_point(12));
    assert!(PolicyKind::adaptive().idle_fixed_point(12));
    for process in
        [ArrivalProcess::Deterministic, ArrivalProcess::Poisson]
    {
        let (cfg, registry) = sparse_fluid(12, &hot, 50, 17, process);
        for kind in PolicyKind::all() {
            let sim = Simulator::with_registry(cfg.clone(),
                                               registry.clone());
            let mut active = kind.clone();
            let mut skip = kind.clone();
            let mut dense = kind;
            let a = sim.run(&mut active);
            let s = sim.run_skip_idle(&mut skip);
            let d = sim.run_dense(&mut dense);
            for (got, tier) in [(&a, "active-set"), (&s, "skip-idle")] {
                assert!(
                    got.mean_latency() == d.mean_latency()
                        && got.total_throughput() == d.total_throughput()
                        && got.cost_dollars == d.cost_dollars,
                    "{} ({process:?}, {tier}): diverged from dense \
                     (latency {} vs {}, tput {} vs {}, cost {} vs {})",
                    d.policy, got.mean_latency(), d.mean_latency(),
                    got.total_throughput(), d.total_throughput(),
                    got.cost_dollars, d.cost_dollars);
                for (x, y) in got.per_agent.iter().zip(&d.per_agent) {
                    assert_eq!(x.latency.mean(), y.latency.mean(),
                               "{}/{} ({tier})", d.policy, y.name);
                    assert_eq!(x.throughput.mean(), y.throughput.mean());
                    assert_eq!(x.processed_total, y.processed_total);
                    assert_eq!(x.final_queue, y.final_queue);
                }
            }
            // The cell is genuinely sparse: cold agents never process,
            // the hot minority carries all the traffic.
            assert_eq!(d.per_agent[0].processed_total, 0.0,
                       "{}: cold agent processed work", d.policy);
            assert!(hot.iter()
                        .any(|&i| d.per_agent[i].processed_total > 0.0),
                    "{}: no hot agent processed anything", d.policy);
        }
    }
}

/// Transitions that activate a previously-quiescent agent mid-window
/// hold the same contract end to end: fault cells whose capacity drop
/// and cold-agent stall land inside the pre-burst idle stretch (plus
/// an eviction inside the burst), and economics cells whose
/// scale-to-zero teardown/cold-start cycle wakes idle agents at the
/// burst onset — each bit-identical to `run_dense` of the same cell,
/// through `run_sweep` at 1, 2, and 8 workers, `ResilienceReport` and
/// economics report included.
#[test]
fn prop_midwindow_activations_match_dense_at_every_worker_count() {
    let hot = [1usize, 6];
    let (cfg, registry) =
        sparse_fluid(8, &hot, 50, 23, ArrivalProcess::Poisson);

    let mut cells = Vec::new();
    let mut expected = Vec::new();
    for kind in PolicyKind::all() {
        // Fault cell: events straddle the idle window and the burst.
        let plan = FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 5.0, frac: 0.5, duration: 3.0 },
            FaultEvent::AgentStall {
                t: 8.0, agent: 0, factor: 4.0, duration: 6.0,
            },
            FaultEvent::GpuEviction { t: 22.0, gpu: 0, duration: 1.0 },
        ]);
        let sc = FaultScenario::single(
            format!("active/fault/{}", kind.name()), cfg.clone(),
            registry.clone(), kind.clone(), FaultConfig::new(plan));
        let mut reference = policy_by_name(kind.name())
            .expect("built-in policy");
        let want = sc.as_single().unwrap().simulator()
            .run_dense(reference.as_mut());
        assert!(want.resilience.is_some(),
                "{}: faults must surface", kind.name());
        expected.push(want);
        cells.push(SweepCell::Fault(sc));

        // Economics cell: idle-burst workload under scale-to-zero, so
        // quiescent agents are torn down and cold-start back mid-run.
        let econ_cfg = agentsrv::repro::idle_burst_config(100, 23);
        let economics = EconomicsModel::with_idle_timeout(5.0);
        let cost = CostScenario::new(
            format!("active/econ/{}", kind.name()), econ_cfg,
            AgentRegistry::paper(), economics, kind.clone());
        let mut reference = policy_by_name(kind.name())
            .expect("built-in policy");
        let want = cost.simulator().run_dense(reference.as_mut());
        assert!(want.economics.is_some(),
                "{}: economics must surface", kind.name());
        expected.push(want);
        cells.push(SweepCell::Cost(cost));
    }
    // At least one economics cell must exercise the actual wake-up.
    assert!(expected.iter().any(|r| r.economics.as_ref()
            .is_some_and(|e| e.total_cold_starts() > 0)),
            "no cell cold-started a quiescent agent");

    for workers in [1usize, 2, 8] {
        let runs = run_sweep(&cells, workers);
        assert_eq!(runs.len(), expected.len());
        for (got, want) in runs.iter().zip(&expected) {
            let sim = got.result.as_sim()
                .expect("fluid cell yields SimResult");
            assert!(
                sim.mean_latency() == want.mean_latency()
                    && sim.total_throughput() == want.total_throughput()
                    && sim.cost_dollars == want.cost_dollars,
                "{} @ {workers} workers: diverged from run_dense \
                 (latency {} vs {}, tput {} vs {}, cost {} vs {})",
                got.label, sim.mean_latency(), want.mean_latency(),
                sim.total_throughput(), want.total_throughput(),
                sim.cost_dollars, want.cost_dollars);
            assert_eq!(sim.resilience, want.resilience,
                       "{} @ {workers} workers", got.label);
            assert_eq!(sim.economics, want.economics,
                       "{} @ {workers} workers", got.label);
            for (a, b) in sim.per_agent.iter().zip(&want.per_agent) {
                assert_eq!(a.latency.mean(), b.latency.mean(),
                           "{}/{} @ {workers}", got.label, a.name);
                assert_eq!(a.processed_total, b.processed_total);
                assert_eq!(a.final_queue, b.final_queue);
            }
        }
    }
}

#[test]
fn prop_round_robin_grants_everything_to_one_agent() {
    forall(0x22B, 100, |rng| gen_agents(rng), |(agents, rates)| {
        let reg = AgentRegistry::new(agents.clone())
            .map_err(|e| e.to_string())?;
        let queues = vec![0.0; reg.len()];
        let mut policy =
            agentsrv::allocator::RoundRobinPolicy::default();
        use agentsrv::allocator::AllocationPolicy;
        let mut out = vec![0.0; reg.len()];
        for step in 0..10 {
            let ctx = AllocContext {
                registry: &reg, arrival_rates: rates,
                queue_depths: &queues, step, capacity: 1.0,
            };
            policy.allocate(&ctx, &mut out);
            let holders =
                out.iter().filter(|g| **g > 0.0).count();
            if holders != 1 {
                return Err(format!("{holders} holders at step {step}"));
            }
            let idx = out.iter().position(|g| *g > 0.0).unwrap();
            if idx != (step as usize) % reg.len() {
                return Err(format!("wrong rotation at step {step}"));
            }
        }
        Ok(())
    });
}
