//! Multi-threaded scenario-sweep engine.
//!
//! A [`Scenario`] is one (config × registry × policy) cell of an
//! evaluation grid; [`run_batch`] fans a slice of them across
//! `std::thread::scope` workers. Each worker owns one [`SimArena`] (the
//! per-step buffer set is reused across its runs instead of re-allocated)
//! and pulls work from a shared atomic cursor, so load imbalance between
//! cheap and expensive scenarios self-corrects. Policies are
//! [`PolicyKind`], statically dispatched in the step loop.
//!
//! Results come back in scenario order regardless of worker count, and
//! every run is bit-identical to a sequential [`Simulator::run`] of the
//! same cell (each scenario owns its seed and a fresh policy clone; the
//! property suite asserts this for every policy and arrival process).
//!
//! The Table II repro, the §V.C sweeps, the §V.B robustness grid, and the
//! `sweep_scaling` bench all drive their grids through here.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::PolicyKind;
use crate::sim::{SimArena, SimConfig, SimResult, Simulator};

/// One cell of a sweep grid: a labelled simulation to run.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Grid coordinates for reports (e.g. `"adaptive/overload3x/seed42"`).
    pub label: String,
    /// Policy evaluated in this cell (cloned fresh for the run).
    pub policy: PolicyKind,
    sim: Simulator,
}

impl Scenario {
    /// Build from a validated registry. The simulator is constructed once
    /// here, so running the scenario clones nothing but the policy.
    pub fn new(label: impl Into<String>, cfg: SimConfig,
               registry: AgentRegistry, policy: PolicyKind) -> Scenario {
        Scenario {
            label: label.into(),
            policy,
            sim: Simulator::with_registry(cfg, registry),
        }
    }

    /// Build from raw profiles (panics on invalid profiles, like
    /// [`Simulator::new`]).
    pub fn from_profiles(label: impl Into<String>, cfg: SimConfig,
                         agents: Vec<AgentProfile>, policy: PolicyKind)
                         -> Scenario {
        Scenario {
            label: label.into(),
            policy,
            sim: Simulator::new(cfg, agents),
        }
    }

    /// The paper's §IV deployment under `policy`.
    pub fn paper(label: impl Into<String>, policy: PolicyKind) -> Scenario {
        Scenario::new(label, SimConfig::paper(), AgentRegistry::paper(),
                      policy)
    }

    /// The simulator this scenario runs (for sequential baselines).
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }

    /// Run this one scenario through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut SimArena) -> SimResult {
        let mut policy = self.policy.clone();
        self.sim.run_with_arena(&mut policy, arena)
    }
}

/// One completed cell: the scenario's label plus its full result.
#[derive(Debug, Clone)]
pub struct BatchRun {
    /// Label copied from the [`Scenario`].
    pub label: String,
    /// The simulation result for that cell.
    pub result: SimResult,
}

/// Worker count matched to the machine (≥ 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run every scenario, fanned across `workers` OS threads.
///
/// `workers` is clamped to `[1, scenarios.len()]`. Results are returned
/// in scenario order. Panics if a worker panics (a scenario itself
/// panicking, e.g. on a mismatched config, propagates).
pub fn run_batch(scenarios: &[Scenario], workers: usize) -> Vec<BatchRun> {
    if scenarios.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, scenarios.len());
    let next = AtomicUsize::new(0);

    let mut indexed: Vec<(usize, SimResult)> =
        Vec::with_capacity(scenarios.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                scope.spawn(move || {
                    let mut arena = SimArena::new();
                    let mut done: Vec<(usize, SimResult)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(scenario) = scenarios.get(i) else {
                            break;
                        };
                        done.push((i, scenario.run_with_arena(&mut arena)));
                    }
                    done
                })
            })
            .collect();
        for handle in handles {
            indexed.extend(handle.join().expect("batch worker panicked"));
        }
    });

    indexed.sort_by_key(|(i, _)| *i);
    indexed.into_iter()
        .map(|(i, result)| BatchRun {
            label: scenarios[i].label.clone(),
            result,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_grid() -> Vec<Scenario> {
        PolicyKind::all().into_iter()
            .map(|p| Scenario::paper(p.name(), p))
            .collect()
    }

    #[test]
    fn empty_batch_returns_nothing() {
        assert!(run_batch(&[], 4).is_empty());
    }

    #[test]
    fn results_come_back_in_scenario_order() {
        let grid = paper_grid();
        for workers in [1usize, 2, 7, 64] {
            let runs = run_batch(&grid, workers);
            assert_eq!(runs.len(), grid.len());
            for (run, sc) in runs.iter().zip(&grid) {
                assert_eq!(run.label, sc.label);
                assert_eq!(run.result.policy, sc.policy.name());
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let grid = paper_grid();
        let one = run_batch(&grid, 1);
        let many = run_batch(&grid, 8);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.result.mean_latency(), b.result.mean_latency(),
                       "{}", a.label);
            assert_eq!(a.result.total_throughput(),
                       b.result.total_throughput());
            assert_eq!(a.result.cost_dollars, b.result.cost_dollars);
        }
    }

    #[test]
    fn batch_matches_direct_simulator_run() {
        let grid = paper_grid();
        let runs = run_batch(&grid, default_workers());
        for (run, sc) in runs.iter().zip(&grid) {
            let mut policy = sc.policy.clone();
            let direct = sc.simulator().run(&mut policy);
            assert_eq!(run.result.mean_latency(), direct.mean_latency(),
                       "{}", run.label);
            assert_eq!(run.result.cost_dollars, direct.cost_dollars);
        }
    }
}
