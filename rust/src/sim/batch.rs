//! Multi-threaded scenario-sweep engine over heterogeneous cells.
//!
//! A [`SweepCell`] is one cell of an evaluation grid — a single-GPU
//! [`Scenario`] (config × registry × policy), a [`ClusterScenario`]
//! (config × registry × per-GPU capacities × placement strategy ×
//! rebalancer), a
//! [`TraceScenario`] (a recorded [`Trace`] replayed under a policy), a
//! [`CostScenario`] (a scenario with a serverless [`EconomicsModel`]
//! enabled — pricing × scale-to-zero timeout × cold-start
//! distribution), a [`ServingScenario`] (the serving-layer queue
//! path — per-request FIFO queues, windowed allocator re-runs, stride
//! picks, dynamic batching — replayed in virtual time through the same
//! [`ServingCore`](crate::server::ServingCore) the threaded server
//! drives), a [`FaultScenario`] (any of those engines run under a
//! deterministic fault plan — the robustness axes `repro::fault_grid`
//! sweeps), or a [`WorkflowScenario`] (any engine driven by a
//! workflow-DAG workload — stage-coupled arrival injection in the
//! fluid engines, native DAG execution in the serving engine).
//! [`run_sweep`] fans a slice of them across
//! `std::thread::scope` workers; [`run_batch`] remains the
//! single-GPU-only entry point over plain [`Scenario`]s. Both share one
//! worker pool implementation: each worker owns one [`SweepArena`] (a
//! [`SimArena`] plus a [`ClusterArena`] plus a [`ServingArena`], so
//! every cell kind reuses its per-step/per-event buffer set instead of
//! re-allocating it; result-owned state is fresh per run) and pulls
//! work from a shared atomic cursor, so load imbalance between cheap
//! and expensive cells self-corrects. Policies are [`PolicyKind`],
//! statically dispatched in the step loop.
//!
//! Results come back in cell order regardless of worker count, and every
//! run is bit-identical to its sequential twin — [`Simulator::run`],
//! [`ClusterSimulator::run`], or [`Simulator::run_trace`] of the same
//! cell (each cell owns its seed and a fresh policy clone; the property
//! suite asserts this for every cell kind at 1/2/8 workers).
//!
//! The Table II repro, the §V.C sweeps, the §V.B robustness grid (now
//! including its cluster and trace-corpus axes), and the `sweep_scaling`
//! bench all drive their grids through here.
//!
//! The [`ScenarioBuilder`] is the one front door onto all of it — a
//! label × [`SimConfig`] × registry seed plus chainable axes, emitting
//! whichever [`SweepCell`] kind the axes call for:
//!
//! ```text
//!   ScenarioBuilder::new(label, SimConfig, registry)
//!       .policy(..)      .capacities(..)  .placement(..)
//!       .rebalancer(..)  .economics(..)   .faults(..)
//!       .workflow(..)    .trace(..)       .serving(..)
//!          |
//!          v  build() picks the cell kind from the axes set
//!   SweepCell::{Single, Cluster, Trace, Cost, Serving, Fault, Workflow}
//!          |
//!          v  run_sweep(cells, workers)
//!   SweepRun { label, CellResult }     — cell order preserved,
//!                                        bit-identical at any
//!                                        worker count
//!
//!   workflow lane: .workflow(spec × rate) reroutes the same grid
//!   through stage-coupled arrival injection (fluid single-GPU and
//!   cluster engines) or native DAG execution in virtual time (the
//!   serving engine), surfacing end-to-end WorkflowStats on every
//!   result; the DAG-aware critical_path policy and the
//!   workflow-colocate placement strategy race the standard axes
//!   through the same cells.
//! ```
//!
//! [`Trace`]: crate::workload::trace::Trace
//!
//! [`ClusterSimulator::run`]: crate::cluster::ClusterSimulator::run
//!
//! [`Simulator::run_trace`]: crate::sim::Simulator::run_trace

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::PolicyKind;
use crate::cluster::{ClusterArena, ClusterResult, ClusterSimulator,
                     MigrationModel, PlacementStrategy, Rebalancer};
use crate::error::{Error, Result};
use crate::server::{ServingArena, ServingConfig, ServingResult,
                    ServingSimulator};
use crate::serverless::{EconomicsModel, EconomicsReport};
use crate::sim::fault::{FaultConfig, ServingFaults};
use crate::sim::{SimArena, SimConfig, SimResult, Simulator};
use crate::workload::trace::{Trace, TraceCorpus};
use crate::workload::{BinTrace, WorkflowWorkload};

/// One single-GPU cell of a sweep grid: a labelled simulation to run.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Grid coordinates for reports (e.g. `"adaptive/overload3x/seed42"`).
    pub label: String,
    /// Policy evaluated in this cell (cloned fresh for the run).
    pub policy: PolicyKind,
    sim: Simulator,
}

impl Scenario {
    /// Build from a validated registry. The simulator is constructed once
    /// here, so running the scenario clones nothing but the policy.
    pub fn new(label: impl Into<String>, cfg: SimConfig,
               registry: AgentRegistry, policy: PolicyKind) -> Scenario {
        Scenario {
            label: label.into(),
            policy,
            sim: Simulator::with_registry(cfg, registry),
        }
    }

    /// Build from raw profiles (panics on invalid profiles, like
    /// [`Simulator::new`]).
    pub fn from_profiles(label: impl Into<String>, cfg: SimConfig,
                         agents: Vec<AgentProfile>, policy: PolicyKind)
                         -> Scenario {
        Scenario {
            label: label.into(),
            policy,
            sim: Simulator::new(cfg, agents),
        }
    }

    /// The paper's §IV deployment under `policy`.
    pub fn paper(label: impl Into<String>, policy: PolicyKind) -> Scenario {
        Scenario::new(label, SimConfig::paper(), AgentRegistry::paper(),
                      policy)
    }

    /// The simulator this scenario runs (for sequential baselines).
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }

    /// Run this one scenario through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut SimArena) -> SimResult {
        let mut policy = self.policy.clone();
        self.sim.run_with_arena(&mut policy, arena)
    }
}

/// One multi-GPU cell of a sweep grid: a labelled cluster simulation
/// (placement, per-GPU Algorithm 1, optional migration model).
#[derive(Debug, Clone)]
pub struct ClusterScenario {
    /// Grid coordinates for reports (e.g. `"cluster/2gpu/cap1/mig"`).
    pub label: String,
    sim: ClusterSimulator,
}

impl ClusterScenario {
    /// Build a uniform cluster cell; errors when the agents cannot be
    /// placed on the cluster (same validation as
    /// [`ClusterSimulator::new`]). Thin wrapper over the one
    /// [`ClusterSimulator::builder`] path.
    pub fn new(label: impl Into<String>, cfg: SimConfig,
               registry: AgentRegistry, n_gpus: usize,
               capacity_per_gpu: f64, rebalancer: Rebalancer)
               -> Result<ClusterScenario> {
        Ok(ClusterScenario {
            label: label.into(),
            sim: ClusterSimulator::new(cfg, registry, n_gpus,
                                       capacity_per_gpu, rebalancer)?,
        })
    }

    /// Build a cell with an explicit [`PlacementStrategy`] ×
    /// [`Rebalancer`] over per-GPU capacities (mixed capacities are the
    /// §VI heterogeneous devices; same validation as
    /// [`ClusterSimulator::with_policies`]) — the placement-grid axes.
    pub fn with_policies(label: impl Into<String>, cfg: SimConfig,
                         registry: AgentRegistry, capacities: Vec<f64>,
                         strategy: PlacementStrategy,
                         rebalancer: Rebalancer)
                         -> Result<ClusterScenario> {
        Ok(ClusterScenario {
            label: label.into(),
            sim: ClusterSimulator::with_policies(
                cfg, registry, capacities, strategy, rebalancer)?,
        })
    }

    /// The cluster simulator this cell runs (for sequential baselines).
    pub fn simulator(&self) -> &ClusterSimulator {
        &self.sim
    }

    /// Run this one cell through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut ClusterArena) -> ClusterResult {
        self.sim.run_with_arena(arena)
            .expect("placement validated at construction")
    }
}

/// One trace-replay cell of a sweep grid: a recorded arrival [`Trace`]
/// replayed bit-exactly under a policy.
#[derive(Debug, Clone)]
pub struct TraceScenario {
    /// Grid coordinates for reports (e.g. `"adaptive/trace/seed42"`).
    pub label: String,
    /// Policy evaluated in this cell (cloned fresh for the run).
    pub policy: PolicyKind,
    sim: Simulator,
    /// Shared, not copied: a whole grid of policies replaying one
    /// recording holds one buffer.
    trace: Arc<Trace>,
}

impl TraceScenario {
    /// Build from a validated registry. Accepts an owned [`Trace`] or an
    /// `Arc<Trace>` (pass `Arc::clone`s to share one recording across
    /// many cells). Panics when the trace's agent columns do not match
    /// the registry's agents — name for name, in order — since a
    /// reordered or foreign trace would replay silently wrong.
    pub fn new(label: impl Into<String>, cfg: SimConfig,
               registry: AgentRegistry, trace: impl Into<Arc<Trace>>,
               policy: PolicyKind) -> TraceScenario {
        let trace = trace.into();
        if let Some(msg) = trace_columns_mismatch(&trace, &registry) {
            panic!("{msg}");
        }
        TraceScenario {
            label: label.into(),
            policy,
            sim: Simulator::with_registry(cfg, registry),
            trace,
        }
    }

    /// Every trace of a [`TraceCorpus`] as sweep cells under one policy,
    /// labelled `"<policy>/<trace-label>"`. An empty corpus (e.g. loaded
    /// from an empty directory) yields an empty sweep. A trace whose
    /// agent columns do not match the registry — a recording from a
    /// different deployment is well-formed CSV, so directory loading
    /// cannot catch it — surfaces as an [`Error::Trace`] naming the
    /// offending trace, not a panic.
    pub fn corpus(corpus: &TraceCorpus, cfg: &SimConfig,
                  registry: &AgentRegistry, policy: &PolicyKind)
                  -> Result<Vec<SweepCell>> {
        corpus.iter()
            .map(|(label, trace)| {
                if let Some(msg) = trace_columns_mismatch(trace, registry)
                {
                    return Err(Error::Trace(format!("{label}: {msg}")));
                }
                Ok(SweepCell::Trace(TraceScenario::new(
                    format!("{}/{label}", policy.name()), cfg.clone(),
                    registry.clone(), trace.clone(), policy.clone())))
            })
            .collect()
    }

    /// The simulator this cell replays through (for sequential baselines).
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }

    /// The recorded trace this cell replays.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Run this one cell through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut SimArena) -> SimResult {
        let mut policy = self.policy.clone();
        self.sim.run_trace_with_arena(&mut policy, &self.trace, arena)
    }
}

/// One serverless-economics cell of a sweep grid: a single-GPU scenario
/// with an [`EconomicsModel`] enabled, so the run bills per agent,
/// scales idle agents to zero, and pays sampled cold starts on wake.
/// The grid axes live in the model itself — pricing × idle timeout ×
/// cold-start distribution — crossed with the policy, which is what
/// `repro::cost_grid` sweeps.
#[derive(Debug, Clone)]
pub struct CostScenario {
    /// Grid coordinates for reports
    /// (e.g. `"cost/adaptive/t4/idle30/platform/seed42"`).
    pub label: String,
    /// Policy evaluated in this cell (cloned fresh for the run).
    pub policy: PolicyKind,
    sim: Simulator,
}

impl CostScenario {
    /// Build from a validated registry; `economics` overrides whatever
    /// the config carried, so a `CostScenario` always runs with the
    /// economics layer on.
    pub fn new(label: impl Into<String>, mut cfg: SimConfig,
               registry: AgentRegistry, economics: EconomicsModel,
               policy: PolicyKind) -> CostScenario {
        cfg.economics = Some(economics);
        CostScenario {
            label: label.into(),
            policy,
            sim: Simulator::with_registry(cfg, registry),
        }
    }

    /// The simulator this cell runs (for sequential baselines).
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }

    /// The economics model this cell runs under.
    pub fn economics(&self) -> &EconomicsModel {
        self.sim.config().economics.as_ref()
            .expect("CostScenario always carries an economics model")
    }

    /// Run this one cell through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut SimArena) -> SimResult {
        let mut policy = self.policy.clone();
        self.sim.run_with_arena(&mut policy, arena)
    }
}

/// One serving-layer cell of a sweep grid: the `server::` queue path —
/// per-request FIFO queues, windowed allocator re-runs, stride-scheduled
/// batch picks — replayed deterministically in virtual time through the
/// same [`ServingCore`](crate::server::ServingCore) the threaded
/// [`AgentServer`](crate::server::AgentServer) drives. Inputs are either
/// a generated workload kind (the config's shape/process/seed) or a
/// recorded [`Trace`].
#[derive(Debug, Clone)]
pub struct ServingScenario {
    /// Grid coordinates for reports
    /// (e.g. `"serving/adaptive/w50ms/b8/steady/seed42"`).
    pub label: String,
    /// Policy evaluated in this cell (cloned fresh for the run).
    pub policy: PolicyKind,
    sim: ServingSimulator,
    /// Recorded input, when this cell replays a trace instead of the
    /// config's generator. Shared, not copied, across a grid.
    trace: Option<Arc<Trace>>,
    /// Recorded binary input ([`BinTrace`]), when this cell replays a
    /// zero-copy binary trace — burst frames inject their recorded
    /// timestamps verbatim. Shared, not copied, across a grid.
    bin: Option<Arc<BinTrace>>,
}

impl ServingScenario {
    /// Build a generator-driven serving cell from a validated registry.
    pub fn new(label: impl Into<String>, cfg: ServingConfig,
               registry: AgentRegistry, policy: PolicyKind)
               -> ServingScenario {
        ServingScenario {
            label: label.into(),
            policy,
            sim: ServingSimulator::with_registry(cfg, registry),
            trace: None,
            bin: None,
        }
    }

    /// Build a trace-replay serving cell. Accepts an owned [`Trace`] or
    /// an `Arc<Trace>`; panics when the trace's agent columns do not
    /// match the registry's agents (same rule as [`TraceScenario`]).
    pub fn from_trace(label: impl Into<String>, cfg: ServingConfig,
                      registry: AgentRegistry,
                      trace: impl Into<Arc<Trace>>, policy: PolicyKind)
                      -> ServingScenario {
        let trace = trace.into();
        if let Some(msg) = trace_columns_mismatch(&trace, &registry) {
            panic!("{msg}");
        }
        ServingScenario {
            label: label.into(),
            policy,
            sim: ServingSimulator::with_registry(cfg, registry),
            trace: Some(trace),
            bin: None,
        }
    }

    /// Build a binary-trace replay serving cell (e.g. a recording
    /// dumped by [`ServingSimulator::run_recording`] or
    /// [`AgentServer::dump_trace`](crate::server::AgentServer::dump_trace)).
    /// Panics when the trace's agent columns do not match the
    /// registry's agents (same rule as [`ServingScenario::from_trace`]).
    pub fn from_bintrace(label: impl Into<String>, cfg: ServingConfig,
                         registry: AgentRegistry,
                         bin: impl Into<Arc<BinTrace>>,
                         policy: PolicyKind) -> ServingScenario {
        let bin = bin.into();
        let names: Vec<&str> = registry.profiles().iter()
            .map(|p| p.name.as_str()).collect();
        let cols: Vec<&str> = bin.agents().iter()
            .map(String::as_str).collect();
        if cols != names {
            panic!("trace agent columns {cols:?} do not match the \
                    registry's agents {names:?}");
        }
        ServingScenario {
            label: label.into(),
            policy,
            sim: ServingSimulator::with_registry(cfg, registry),
            trace: None,
            bin: Some(bin),
        }
    }

    /// The serving simulator this cell runs (for sequential baselines).
    pub fn simulator(&self) -> &ServingSimulator {
        &self.sim
    }

    /// The recorded trace this cell replays, when it is a trace cell.
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_deref()
    }

    /// The binary trace this cell replays, when it is a binary-replay
    /// cell.
    pub fn bintrace(&self) -> Option<&BinTrace> {
        self.bin.as_deref()
    }

    /// Run this one cell through a caller-owned arena.
    pub fn run_with_arena(&self, arena: &mut ServingArena)
                          -> ServingResult {
        let mut policy = self.policy.clone();
        if let Some(bin) = &self.bin {
            return self.sim.run_source_with_arena(&mut policy,
                                                  bin.as_ref(), arena);
        }
        match &self.trace {
            Some(trace) => {
                self.sim.run_trace_with_arena(&mut policy, trace, arena)
            }
            None => self.sim.run_with_arena(&mut policy, arena),
        }
    }
}

/// One fault-injection cell of a sweep grid: a single-GPU, cluster, or
/// serving-layer scenario run under a deterministic fault plan — the
/// §V robustness axes (eviction rate × recovery policy × shed policy ×
/// allocator × seed) that `repro::fault_grid` sweeps. The wrapper
/// injects the fault config into the inner scenario's config at
/// construction, so a `FaultScenario` always runs with the fault layer
/// armed (an *empty* plan is the control cell: bit-identical to the
/// equivalent plain scenario).
#[derive(Debug, Clone)]
pub struct FaultScenario {
    inner: FaultInner,
}

#[derive(Debug, Clone)]
enum FaultInner {
    Single(Scenario),
    Cluster(ClusterScenario),
    Serving(ServingScenario),
}

impl FaultScenario {
    /// Build a single-GPU fault cell; `faults` overrides whatever the
    /// config carried.
    pub fn single(label: impl Into<String>, mut cfg: SimConfig,
                  registry: AgentRegistry, policy: PolicyKind,
                  faults: FaultConfig) -> FaultScenario {
        cfg.faults = Some(faults);
        FaultScenario {
            inner: FaultInner::Single(Scenario::new(label, cfg, registry,
                                                    policy)),
        }
    }

    /// Build a cluster fault cell (explicit placement strategy ×
    /// rebalancer, same validation as
    /// [`ClusterSimulator::with_policies`]); `faults` overrides
    /// whatever the config carried.
    ///
    /// [`ClusterSimulator::with_policies`]:
    ///     crate::cluster::ClusterSimulator::with_policies
    pub fn cluster(label: impl Into<String>, mut cfg: SimConfig,
                   registry: AgentRegistry, capacities: Vec<f64>,
                   strategy: PlacementStrategy, rebalancer: Rebalancer,
                   faults: FaultConfig) -> Result<FaultScenario> {
        cfg.faults = Some(faults);
        Ok(FaultScenario {
            inner: FaultInner::Cluster(ClusterScenario::with_policies(
                label, cfg, registry, capacities, strategy, rebalancer)?),
        })
    }

    /// Build a serving-layer fault cell (transient dispatch failures
    /// absorbed by retry, plus optional admission control); `faults`
    /// overrides whatever the config carried.
    pub fn serving(label: impl Into<String>, mut cfg: ServingConfig,
                   registry: AgentRegistry, policy: PolicyKind,
                   faults: ServingFaults) -> FaultScenario {
        cfg.faults = Some(faults);
        FaultScenario {
            inner: FaultInner::Serving(ServingScenario::new(label, cfg,
                                                            registry,
                                                            policy)),
        }
    }

    /// The cell's grid label.
    pub fn label(&self) -> &str {
        match &self.inner {
            FaultInner::Single(s) => &s.label,
            FaultInner::Cluster(s) => &s.label,
            FaultInner::Serving(s) => &s.label,
        }
    }

    /// The inner single-GPU scenario, when this is a single-GPU fault
    /// cell (for sequential baselines).
    pub fn as_single(&self) -> Option<&Scenario> {
        match &self.inner {
            FaultInner::Single(s) => Some(s),
            _ => None,
        }
    }

    /// The inner cluster scenario, when this is a cluster fault cell.
    pub fn as_cluster_scenario(&self) -> Option<&ClusterScenario> {
        match &self.inner {
            FaultInner::Cluster(s) => Some(s),
            _ => None,
        }
    }

    /// The inner serving scenario, when this is a serving fault cell.
    pub fn as_serving_scenario(&self) -> Option<&ServingScenario> {
        match &self.inner {
            FaultInner::Serving(s) => Some(s),
            _ => None,
        }
    }

    /// Run this one cell through a caller-owned worker arena.
    pub fn run_with_arena(&self, arena: &mut SweepArena) -> CellResult {
        match &self.inner {
            FaultInner::Single(s) =>
                CellResult::Sim(s.run_with_arena(&mut arena.sim)),
            FaultInner::Cluster(s) =>
                CellResult::Cluster(s.run_with_arena(&mut arena.cluster)),
            FaultInner::Serving(s) =>
                CellResult::Serving(s.run_with_arena(&mut arena.serving)),
        }
    }
}

/// One workflow-DAG cell of a sweep grid: a single-GPU, cluster, or
/// serving-layer scenario driven by a [`WorkflowWorkload`] instead of
/// independent per-agent arrival streams — the workflow-grid axes
/// (spec shape × policy × placement × seed) that `repro::workflow_grid`
/// sweeps. The wrapper injects the workload into the inner scenario's
/// config at construction, so a `WorkflowScenario` always surfaces
/// end-to-end [`WorkflowStats`](crate::workload::WorkflowStats) on its
/// result.
#[derive(Debug, Clone)]
pub struct WorkflowScenario {
    inner: WorkflowInner,
}

#[derive(Debug, Clone)]
enum WorkflowInner {
    Single(Scenario),
    Cluster(ClusterScenario),
    Serving(ServingScenario),
}

impl WorkflowScenario {
    /// Build a single-GPU workflow cell; `workflow` overrides whatever
    /// the config carried. Errors when the spec references agents
    /// beyond the registry.
    pub fn single(label: impl Into<String>, mut cfg: SimConfig,
                  registry: AgentRegistry, policy: PolicyKind,
                  workflow: WorkflowWorkload) -> Result<WorkflowScenario> {
        workflow.spec.validate_for(registry.len())?;
        cfg.workflow = Some(workflow);
        Ok(WorkflowScenario {
            inner: WorkflowInner::Single(Scenario::new(label, cfg,
                                                       registry, policy)),
        })
    }

    /// Build a cluster workflow cell (explicit placement strategy ×
    /// rebalancer — [`PlacementStrategy::WorkflowColocate`] reads the
    /// spec's participant mask); `workflow` overrides whatever the
    /// config carried. Errors on an unplaceable cluster or an
    /// out-of-range spec.
    pub fn cluster(label: impl Into<String>, mut cfg: SimConfig,
                   registry: AgentRegistry, capacities: Vec<f64>,
                   strategy: PlacementStrategy, rebalancer: Rebalancer,
                   workflow: WorkflowWorkload) -> Result<WorkflowScenario> {
        cfg.workflow = Some(workflow);
        Ok(WorkflowScenario {
            inner: WorkflowInner::Cluster(ClusterScenario::with_policies(
                label, cfg, registry, capacities, strategy, rebalancer)?),
        })
    }

    /// Build a serving-layer workflow cell (native DAG execution in
    /// virtual time); `workflow` overrides whatever the config carried.
    /// Errors when the spec references agents beyond the registry.
    pub fn serving(label: impl Into<String>, mut cfg: ServingConfig,
                   registry: AgentRegistry, policy: PolicyKind,
                   workflow: WorkflowWorkload) -> Result<WorkflowScenario> {
        workflow.spec.validate_for(registry.len())?;
        cfg.workflow = Some(workflow);
        Ok(WorkflowScenario {
            inner: WorkflowInner::Serving(ServingScenario::new(
                label, cfg, registry, policy)),
        })
    }

    /// The cell's grid label.
    pub fn label(&self) -> &str {
        match &self.inner {
            WorkflowInner::Single(s) => &s.label,
            WorkflowInner::Cluster(s) => &s.label,
            WorkflowInner::Serving(s) => &s.label,
        }
    }

    /// The inner single-GPU scenario, when this is a single-GPU
    /// workflow cell (for sequential baselines).
    pub fn as_single(&self) -> Option<&Scenario> {
        match &self.inner {
            WorkflowInner::Single(s) => Some(s),
            _ => None,
        }
    }

    /// The inner cluster scenario, when this is a cluster workflow cell.
    pub fn as_cluster_scenario(&self) -> Option<&ClusterScenario> {
        match &self.inner {
            WorkflowInner::Cluster(s) => Some(s),
            _ => None,
        }
    }

    /// The inner serving scenario, when this is a serving workflow cell.
    pub fn as_serving_scenario(&self) -> Option<&ServingScenario> {
        match &self.inner {
            WorkflowInner::Serving(s) => Some(s),
            _ => None,
        }
    }

    /// Run this one cell through a caller-owned worker arena.
    pub fn run_with_arena(&self, arena: &mut SweepArena) -> CellResult {
        match &self.inner {
            WorkflowInner::Single(s) =>
                CellResult::Sim(s.run_with_arena(&mut arena.sim)),
            WorkflowInner::Cluster(s) =>
                CellResult::Cluster(s.run_with_arena(&mut arena.cluster)),
            WorkflowInner::Serving(s) =>
                CellResult::Serving(s.run_with_arena(&mut arena.serving)),
        }
    }
}

/// The one matching rule for replaying a trace over a registry: the
/// agent columns must equal the registry's agents, name for name, in
/// order (a reordered or foreign recording would replay silently
/// wrong). Returns the failure description, or `None` when they match.
fn trace_columns_mismatch(trace: &Trace, registry: &AgentRegistry)
                          -> Option<String> {
    let names: Vec<&str> = registry.profiles().iter()
        .map(|p| p.name.as_str()).collect();
    let cols: Vec<&str> = trace.agents.iter()
        .map(String::as_str).collect();
    (cols != names).then(|| format!(
        "trace agent columns {cols:?} do not match the registry's \
         agents {names:?}"))
}

/// One cell of a heterogeneous sweep grid.
#[derive(Debug, Clone)]
pub enum SweepCell {
    /// Single-GPU generator-driven cell.
    Single(Scenario),
    /// Multi-GPU cluster cell.
    Cluster(ClusterScenario),
    /// Recorded-trace replay cell.
    Trace(TraceScenario),
    /// Serverless-economics cell (pricing × scale-to-zero × cold start).
    Cost(CostScenario),
    /// Serving-layer queue-path cell (virtual-time `ServingCore` run).
    Serving(ServingScenario),
    /// Fault-injection cell (any engine, run under a fault plan).
    Fault(FaultScenario),
    /// Workflow-DAG cell (any engine, driven by a workflow workload).
    Workflow(WorkflowScenario),
}

impl SweepCell {
    /// The cell's grid label.
    pub fn label(&self) -> &str {
        match self {
            SweepCell::Single(s) => &s.label,
            SweepCell::Cluster(s) => &s.label,
            SweepCell::Trace(s) => &s.label,
            SweepCell::Cost(s) => &s.label,
            SweepCell::Serving(s) => &s.label,
            SweepCell::Fault(s) => s.label(),
            SweepCell::Workflow(s) => s.label(),
        }
    }

    /// Run this cell through a caller-owned worker arena.
    pub fn run_with_arena(&self, arena: &mut SweepArena) -> CellResult {
        match self {
            SweepCell::Single(s) =>
                CellResult::Sim(s.run_with_arena(&mut arena.sim)),
            SweepCell::Cluster(s) =>
                CellResult::Cluster(s.run_with_arena(&mut arena.cluster)),
            SweepCell::Trace(s) =>
                CellResult::Sim(s.run_with_arena(&mut arena.sim)),
            SweepCell::Cost(s) =>
                CellResult::Sim(s.run_with_arena(&mut arena.sim)),
            SweepCell::Serving(s) =>
                CellResult::Serving(s.run_with_arena(&mut arena.serving)),
            SweepCell::Fault(s) => s.run_with_arena(arena),
            SweepCell::Workflow(s) => s.run_with_arena(arena),
        }
    }
}

/// The full result of one sweep cell, tagged by kind. Single-GPU and
/// trace-replay cells produce a [`SimResult`]; cluster cells a
/// [`ClusterResult`]; serving-layer cells a [`ServingResult`].
#[derive(Debug, Clone)]
pub enum CellResult {
    /// Single-GPU simulation result (generator-driven or trace replay).
    Sim(SimResult),
    /// Multi-GPU cluster result.
    Cluster(ClusterResult),
    /// Serving-layer queue-path result.
    Serving(ServingResult),
}

impl CellResult {
    /// Mean of per-agent mean latencies (s), whatever the cell kind.
    pub fn mean_latency(&self) -> f64 {
        match self {
            CellResult::Sim(r) => r.mean_latency(),
            CellResult::Cluster(r) => r.mean_latency(),
            CellResult::Serving(r) => r.mean_latency(),
        }
    }

    /// Aggregate throughput (rps), whatever the cell kind.
    pub fn total_throughput(&self) -> f64 {
        match self {
            CellResult::Sim(r) => r.total_throughput(),
            CellResult::Cluster(r) => r.total_throughput(),
            CellResult::Serving(r) => r.total_throughput(),
        }
    }

    /// Total billed cost ($), whatever the cell kind. Serving-layer
    /// cells carry no billing meter and report 0.
    pub fn cost_dollars(&self) -> f64 {
        match self {
            CellResult::Sim(r) => r.cost_dollars,
            CellResult::Cluster(r) => r.cost_dollars,
            CellResult::Serving(_) => 0.0,
        }
    }

    /// The per-agent economics breakdown, when the cell's config enabled
    /// an [`EconomicsModel`] — always present for [`SweepCell::Cost`]
    /// cells, whatever the kind otherwise.
    pub fn economics(&self) -> Option<&EconomicsReport> {
        match self {
            CellResult::Sim(r) => r.economics.as_ref(),
            CellResult::Cluster(r) => r.economics.as_ref(),
            CellResult::Serving(_) => None,
        }
    }

    /// End-to-end workflow stats, when the cell's config carried a
    /// [`WorkflowWorkload`] — always present for
    /// [`SweepCell::Workflow`] cells, whatever the kind otherwise.
    pub fn workflow(&self) -> Option<&crate::workload::WorkflowStats> {
        match self {
            CellResult::Sim(r) => r.workflow.as_ref(),
            CellResult::Cluster(r) => r.workflow.as_ref(),
            CellResult::Serving(r) => r.workflow.as_ref(),
        }
    }

    /// The single-GPU result, if this was a single-GPU or trace cell.
    pub fn as_sim(&self) -> Option<&SimResult> {
        match self {
            CellResult::Sim(r) => Some(r),
            _ => None,
        }
    }

    /// The cluster result, if this was a cluster cell.
    pub fn as_cluster(&self) -> Option<&ClusterResult> {
        match self {
            CellResult::Cluster(r) => Some(r),
            _ => None,
        }
    }

    /// The serving-layer result, if this was a serving cell.
    pub fn as_serving(&self) -> Option<&ServingResult> {
        match self {
            CellResult::Serving(r) => Some(r),
            _ => None,
        }
    }
}

/// The one front door for building sweep cells: seed it with a label ×
/// [`SimConfig`] × registry, chain the axes the cell needs, and
/// [`ScenarioBuilder::build`] emits the matching [`SweepCell`] kind.
///
/// Axis precedence (most specific engine wins):
///
/// 1. [`ScenarioBuilder::serving`] routes through the serving engine —
///    with [`ScenarioBuilder::workflow`] that is a workflow cell, with
///    [`ScenarioBuilder::serving_faults`] a serving fault cell, with
///    [`ScenarioBuilder::trace`] a trace-replay serving cell, else a
///    plain serving cell.
/// 2. Otherwise [`ScenarioBuilder::workflow`] emits a fluid workflow
///    cell — cluster-backed when [`ScenarioBuilder::capacities`] set a
///    cluster axis, single-GPU otherwise.
/// 3. Otherwise a cluster axis emits a cluster cell (a fault cell when
///    [`ScenarioBuilder::faults`] is set).
/// 4. Otherwise [`ScenarioBuilder::trace`] emits a trace cell,
///    [`ScenarioBuilder::economics`] a cost cell,
///    [`ScenarioBuilder::faults`] a single-GPU fault cell, and the bare
///    seed a plain single-GPU cell.
///
/// Economics and fluid fault layers compose with the other axes by
/// injection into the cell's config; incompatible combinations (a trace
/// replay with a workflow or a cluster axis) return [`Error::Config`].
/// The per-kind constructors ([`Scenario::new`],
/// [`ClusterScenario::with_policies`], [`WorkflowScenario::single`],
/// ...) stay available as thin wrappers over the same validation — the
/// builder is sugar, not a second code path.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    label: String,
    cfg: SimConfig,
    registry: AgentRegistry,
    policy: PolicyKind,
    capacities: Option<Vec<f64>>,
    placement: PlacementStrategy,
    rebalancer: Rebalancer,
    economics: Option<EconomicsModel>,
    faults: Option<FaultConfig>,
    workflow: Option<WorkflowWorkload>,
    trace: Option<Arc<Trace>>,
    bintrace: Option<Arc<BinTrace>>,
    serving: Option<ServingConfig>,
    serving_faults: Option<ServingFaults>,
}

impl ScenarioBuilder {
    /// Seed a builder: every cell kind starts from a label, a fluid
    /// config, and a validated registry. The policy defaults to the
    /// paper's Algorithm 1 ([`PolicyKind::adaptive`]).
    pub fn new(label: impl Into<String>, cfg: SimConfig,
               registry: AgentRegistry) -> ScenarioBuilder {
        ScenarioBuilder {
            label: label.into(),
            cfg,
            registry,
            policy: PolicyKind::adaptive(),
            capacities: None,
            placement: PlacementStrategy::default(),
            rebalancer: Rebalancer::Static,
            economics: None,
            faults: None,
            workflow: None,
            trace: None,
            bintrace: None,
            serving: None,
            serving_faults: None,
        }
    }

    /// Policy evaluated in this cell.
    pub fn policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    /// Per-GPU capacities: sets the cluster axis (the fluid engine
    /// becomes [`ClusterSimulator`]).
    pub fn capacities(mut self, capacities: Vec<f64>) -> Self {
        self.capacities = Some(capacities);
        self
    }

    /// Uniform cluster shorthand: `n_gpus` devices of
    /// `capacity_per_gpu` each.
    pub fn gpus(self, n_gpus: usize, capacity_per_gpu: f64) -> Self {
        self.capacities(vec![capacity_per_gpu; n_gpus])
    }

    /// Placement strategy for the cluster axis.
    pub fn placement(mut self, strategy: PlacementStrategy) -> Self {
        self.placement = strategy;
        self
    }

    /// Rebalancer for the cluster axis.
    pub fn rebalancer(mut self, rebalancer: Rebalancer) -> Self {
        self.rebalancer = rebalancer;
        self
    }

    /// Serverless economics layer (billing, scale-to-zero, cold starts).
    pub fn economics(mut self, model: EconomicsModel) -> Self {
        self.economics = Some(model);
        self
    }

    /// Fluid-engine fault plan (GPU evictions, degradations).
    pub fn faults(mut self, faults: FaultConfig) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Workflow-DAG workload: replaces the per-agent arrival streams
    /// with stage-coupled instances.
    pub fn workflow(mut self, workflow: WorkflowWorkload) -> Self {
        self.workflow = Some(workflow);
        self
    }

    /// Recorded arrival trace to replay instead of the config's
    /// generator.
    pub fn trace(mut self, trace: impl Into<Arc<Trace>>) -> Self {
        self.trace = Some(trace.into());
        self
    }

    /// Recorded *binary* trace ([`BinTrace`]) to replay instead of the
    /// config's generator — e.g. a live-recorded serving timeline with
    /// burst microstructure. Requires [`ScenarioBuilder::serving`]
    /// routing (burst timestamps only have meaning on the queue path).
    pub fn bintrace(mut self, bin: impl Into<Arc<BinTrace>>) -> Self {
        self.bintrace = Some(bin.into());
        self
    }

    /// Route through the serving-layer engine under `cfg` (the fluid
    /// config's arrival axes are superseded by the serving config's).
    pub fn serving(mut self, cfg: ServingConfig) -> Self {
        self.serving = Some(cfg);
        self
    }

    /// Serving-layer fault injection (transient dispatch failures,
    /// admission control); implies [`ScenarioBuilder::serving`] routing
    /// only when a serving config was given.
    pub fn serving_faults(mut self, faults: ServingFaults) -> Self {
        self.serving_faults = Some(faults);
        self
    }

    /// Emit the [`SweepCell`] the chained axes describe.
    pub fn build(self) -> Result<SweepCell> {
        let ScenarioBuilder {
            label, mut cfg, registry, policy, capacities, placement,
            rebalancer, economics, faults, workflow, trace, bintrace,
            serving, serving_faults,
        } = self;

        if bintrace.is_some() && trace.is_some() {
            return Err(Error::Config(
                "one replay input per cell; drop .trace() or \
                 .bintrace()".into()));
        }
        if let Some(scfg) = serving {
            if capacities.is_some() {
                return Err(Error::Config(
                    "serving cells run the single-GPU queue path; \
                     drop .capacities() or .serving()".into()));
            }
            if let Some(wf) = workflow {
                if trace.is_some() || bintrace.is_some() {
                    return Err(Error::Config(
                        "a workflow workload replaces the arrival \
                         stream; it cannot replay a trace".into()));
                }
                let mut scfg = scfg;
                let carried = scfg.faults.take();
                scfg.faults = serving_faults.or(carried);
                return Ok(SweepCell::Workflow(WorkflowScenario::serving(
                    label, scfg, registry, policy, wf)?));
            }
            if let Some(sf) = serving_faults {
                if trace.is_some() || bintrace.is_some() {
                    return Err(Error::Config(
                        "serving fault cells draw from the generator; \
                         drop .serving_faults() or the replay input"
                            .into()));
                }
                return Ok(SweepCell::Fault(FaultScenario::serving(
                    label, scfg, registry, policy, sf)));
            }
            if let Some(b) = bintrace {
                return Ok(SweepCell::Serving(
                    ServingScenario::from_bintrace(label, scfg, registry,
                                                   b, policy)));
            }
            return Ok(match trace {
                Some(t) => SweepCell::Serving(ServingScenario::from_trace(
                    label, scfg, registry, t, policy)),
                None => SweepCell::Serving(ServingScenario::new(
                    label, scfg, registry, policy)),
            });
        }
        if serving_faults.is_some() {
            return Err(Error::Config(
                "serving_faults needs a .serving() config".into()));
        }
        if bintrace.is_some() {
            return Err(Error::Config(
                "binary traces replay through the serving queue path \
                 (burst timestamps have no fluid meaning); add \
                 .serving() or convert to a CSV trace".into()));
        }

        cfg.economics = economics.or(cfg.economics.take());
        if let Some(wf) = workflow {
            if trace.is_some() {
                return Err(Error::Config(
                    "a workflow workload replaces the arrival stream; \
                     it cannot replay a trace".into()));
            }
            cfg.faults = faults.or(cfg.faults.take());
            return Ok(SweepCell::Workflow(match capacities {
                Some(caps) => WorkflowScenario::cluster(
                    label, cfg, registry, caps, placement, rebalancer,
                    wf)?,
                None => WorkflowScenario::single(label, cfg, registry,
                                                 policy, wf)?,
            }));
        }
        if let Some(caps) = capacities {
            if trace.is_some() {
                return Err(Error::Config(
                    "trace replay is a single-GPU path; drop \
                     .capacities() or .trace()".into()));
            }
            return Ok(match faults {
                Some(f) => SweepCell::Fault(FaultScenario::cluster(
                    label, cfg, registry, caps, placement, rebalancer,
                    f)?),
                None => SweepCell::Cluster(ClusterScenario::with_policies(
                    label, cfg, registry, caps, placement, rebalancer)?),
            });
        }
        if let Some(t) = trace {
            cfg.faults = faults.or(cfg.faults.take());
            return Ok(SweepCell::Trace(TraceScenario::new(
                label, cfg, registry, t, policy)));
        }
        if let Some(econ) = cfg.economics.take() {
            cfg.faults = faults.or(cfg.faults.take());
            return Ok(SweepCell::Cost(CostScenario::new(
                label, cfg, registry, econ, policy)));
        }
        if let Some(f) = faults {
            return Ok(SweepCell::Fault(FaultScenario::single(
                label, cfg, registry, policy, f)));
        }
        Ok(SweepCell::Single(Scenario::new(label, cfg, registry, policy)))
    }
}

/// One completed single-GPU cell: the scenario's label plus its result.
#[derive(Debug, Clone)]
pub struct BatchRun {
    /// Label copied from the [`Scenario`].
    pub label: String,
    /// The simulation result for that cell.
    pub result: SimResult,
}

/// One completed sweep cell: the cell's label plus its tagged result.
#[derive(Debug, Clone)]
pub struct SweepRun {
    /// Label copied from the [`SweepCell`].
    pub label: String,
    /// The tagged result for that cell.
    pub result: CellResult,
}

/// Per-worker buffer set: one arena per cell kind, so a single worker
/// replays any mix of cells allocation-free after warm-up.
#[derive(Debug, Clone, Default)]
pub struct SweepArena {
    /// Buffers for single-GPU and trace-replay cells.
    pub sim: SimArena,
    /// Buffers for cluster cells.
    pub cluster: ClusterArena,
    /// Buffers for serving-layer cells.
    pub serving: ServingArena,
}

impl SweepArena {
    /// Empty arenas; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        SweepArena::default()
    }
}

/// Worker count matched to the machine (≥ 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The shared worker pool: fan `items` across `workers` OS threads, each
/// owning one [`SweepArena`], pulling indices from an atomic cursor.
/// Results come back in item order. Panics if a worker panics (an item
/// itself panicking, e.g. on a mismatched config, propagates).
fn run_pool<T, R, F>(items: &[T], workers: usize, run: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T, &mut SweepArena) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, items.len());
    let next = AtomicUsize::new(0);

    let mut indexed: Vec<(usize, R)> = Vec::with_capacity(items.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let run = &run;
                scope.spawn(move || {
                    let mut arena = SweepArena::new();
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(item) = items.get(i) else {
                            break;
                        };
                        done.push((i, run(item, &mut arena)));
                    }
                    done
                })
            })
            .collect();
        for handle in handles {
            indexed.extend(handle.join().expect("sweep worker panicked"));
        }
    });

    indexed.sort_by_key(|(i, _)| *i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Run every single-GPU scenario, fanned across `workers` OS threads.
///
/// `workers` is clamped to `[1, scenarios.len()]`. Results are returned
/// in scenario order.
pub fn run_batch(scenarios: &[Scenario], workers: usize) -> Vec<BatchRun> {
    run_pool(scenarios, workers,
             |sc: &Scenario, arena: &mut SweepArena| {
                 sc.run_with_arena(&mut arena.sim)
             })
        .into_iter()
        .zip(scenarios)
        .map(|(result, sc)| BatchRun { label: sc.label.clone(), result })
        .collect()
}

/// Run every cell of a heterogeneous grid — single-GPU, cluster, and
/// trace-replay cells mixed freely — through one worker pool.
///
/// `workers` is clamped to `[1, cells.len()]`. Results are returned in
/// cell order, each tagged with its kind via [`CellResult`].
pub fn run_sweep(cells: &[SweepCell], workers: usize) -> Vec<SweepRun> {
    run_pool(cells, workers,
             |cell: &SweepCell, arena: &mut SweepArena| {
                 cell.run_with_arena(arena)
             })
        .into_iter()
        .zip(cells)
        .map(|(result, cell)| SweepRun {
            label: cell.label().to_string(),
            result,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fault::{AdmissionControl, FaultModel, FaultPlan,
                            ShedPolicy};
    use crate::workload::WorkflowSpec;

    fn paper_grid() -> Vec<Scenario> {
        PolicyKind::all().into_iter()
            .map(|p| Scenario::paper(p.name(), p))
            .collect()
    }

    fn serving_cfg() -> ServingConfig {
        let mut cfg = ServingConfig::paper();
        cfg.duration_s = 2.0; // keep the test cell small
        cfg
    }

    fn mixed_grid() -> Vec<SweepCell> {
        vec![
            SweepCell::Single(Scenario::paper("single/adaptive",
                                              PolicyKind::adaptive())),
            SweepCell::Cluster(ClusterScenario::new(
                "cluster/2gpu", SimConfig::paper(), AgentRegistry::paper(),
                2, 1.0, Rebalancer::Static).unwrap()),
            SweepCell::Trace(TraceScenario::new(
                "trace/adaptive", SimConfig::paper(),
                AgentRegistry::paper(), Trace::paper_poisson(40, 7),
                PolicyKind::adaptive())),
            SweepCell::Single(Scenario::paper("single/static",
                                              PolicyKind::static_equal())),
            SweepCell::Cluster(ClusterScenario::with_policies(
                "cluster/hetero", SimConfig::paper(),
                AgentRegistry::paper(), vec![1.0, 0.5],
                PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Static).unwrap()),
            SweepCell::Cluster(ClusterScenario::new(
                "cluster/4gpu", SimConfig::paper(), AgentRegistry::paper(),
                4, 1.0,
                Rebalancer::HottestAgent(MigrationModel::default()))
                .unwrap()),
            SweepCell::Cluster(ClusterScenario::with_policies(
                "cluster/spread/repack", SimConfig::paper(),
                AgentRegistry::paper(), vec![1.0, 0.75, 0.5, 0.25],
                PlacementStrategy::PrioritySpread,
                Rebalancer::Repack(MigrationModel::default())).unwrap()),
            SweepCell::Cost(CostScenario::new(
                "cost/adaptive/idle5", SimConfig::paper(),
                AgentRegistry::paper(),
                EconomicsModel::with_idle_timeout(5.0),
                PolicyKind::adaptive())),
            SweepCell::Serving(ServingScenario::new(
                "serving/adaptive", serving_cfg(), AgentRegistry::paper(),
                PolicyKind::adaptive())),
            SweepCell::Serving(ServingScenario::from_trace(
                "serving/static/trace", serving_cfg(),
                AgentRegistry::paper(), Trace::paper_poisson(2, 7),
                PolicyKind::static_equal())),
            SweepCell::Fault(FaultScenario::single(
                "fault/single/adaptive", SimConfig::paper(),
                AgentRegistry::paper(), PolicyKind::adaptive(),
                FaultConfig::new(
                    FaultModel::spot(0.01, 42).generate(1, 100.0)))),
            SweepCell::Fault(FaultScenario::cluster(
                "fault/cluster/repack", SimConfig::paper(),
                AgentRegistry::paper(), vec![1.2, 1.2],
                PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Repack(MigrationModel::default()),
                FaultConfig::new(
                    FaultModel::spot(0.01, 7).generate(2, 100.0))
                    .with_repack_throttle(0.5)).unwrap()),
            SweepCell::Fault(FaultScenario::serving(
                "fault/serving/shed", serving_cfg(),
                AgentRegistry::paper(), PolicyKind::adaptive(),
                ServingFaults::new(FaultPlan::empty()).with_admission(
                    AdmissionControl::new(64,
                                          ShedPolicy::DropByPriority)))),
            SweepCell::Workflow(WorkflowScenario::single(
                "workflow/single/critical_path", SimConfig::paper(),
                AgentRegistry::paper(),
                PolicyKind::critical_path_for(&WorkflowSpec::paper(), 4),
                WorkflowWorkload::paper()).unwrap()),
            SweepCell::Workflow(WorkflowScenario::cluster(
                "workflow/cluster/colocate", SimConfig::paper(),
                AgentRegistry::paper(), vec![1.2, 1.2],
                PlacementStrategy::WorkflowColocate, Rebalancer::Static,
                WorkflowWorkload::paper()).unwrap()),
            SweepCell::Workflow(WorkflowScenario::serving(
                "workflow/serving/adaptive", serving_cfg(),
                AgentRegistry::paper(), PolicyKind::adaptive(),
                WorkflowWorkload::paper()).unwrap()),
        ]
    }

    #[test]
    fn empty_batch_returns_nothing() {
        assert!(run_batch(&[], 4).is_empty());
        assert!(run_sweep(&[], 4).is_empty());
    }

    #[test]
    fn results_come_back_in_scenario_order() {
        let grid = paper_grid();
        for workers in [1usize, 2, 7, 64] {
            let runs = run_batch(&grid, workers);
            assert_eq!(runs.len(), grid.len());
            for (run, sc) in runs.iter().zip(&grid) {
                assert_eq!(run.label, sc.label);
                assert_eq!(run.result.policy, sc.policy.name());
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let grid = paper_grid();
        let one = run_batch(&grid, 1);
        let many = run_batch(&grid, 8);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.result.mean_latency(), b.result.mean_latency(),
                       "{}", a.label);
            assert_eq!(a.result.total_throughput(),
                       b.result.total_throughput());
            assert_eq!(a.result.cost_dollars, b.result.cost_dollars);
        }
    }

    #[test]
    fn batch_matches_direct_simulator_run() {
        let grid = paper_grid();
        let runs = run_batch(&grid, default_workers());
        for (run, sc) in runs.iter().zip(&grid) {
            let mut policy = sc.policy.clone();
            let direct = sc.simulator().run(&mut policy);
            assert_eq!(run.result.mean_latency(), direct.mean_latency(),
                       "{}", run.label);
            assert_eq!(run.result.cost_dollars, direct.cost_dollars);
        }
    }

    #[test]
    fn mixed_sweep_returns_cells_in_order_with_matching_kinds() {
        let cells = mixed_grid();
        for workers in [1usize, 3, 16] {
            let runs = run_sweep(&cells, workers);
            assert_eq!(runs.len(), cells.len());
            for (run, cell) in runs.iter().zip(&cells) {
                assert_eq!(run.label, cell.label());
                match cell {
                    SweepCell::Cluster(_) =>
                        assert!(run.result.as_cluster().is_some(),
                                "{}", run.label),
                    SweepCell::Single(_) | SweepCell::Trace(_) =>
                        assert!(run.result.as_sim().is_some(),
                                "{}", run.label),
                    SweepCell::Cost(_) => {
                        assert!(run.result.as_sim().is_some(),
                                "{}", run.label);
                        assert!(run.result.economics().is_some(),
                                "{}: cost cell must carry its report",
                                run.label);
                    }
                    SweepCell::Serving(_) =>
                        assert!(run.result.as_serving().is_some(),
                                "{}", run.label),
                    SweepCell::Fault(f) => {
                        let ok = if f.as_cluster_scenario().is_some() {
                            run.result.as_cluster().is_some()
                        } else if f.as_serving_scenario().is_some() {
                            run.result.as_serving().is_some()
                        } else {
                            run.result.as_sim().is_some()
                        };
                        assert!(ok, "{}", run.label);
                    }
                    SweepCell::Workflow(w) => {
                        let ok = if w.as_cluster_scenario().is_some() {
                            run.result.as_cluster().is_some()
                        } else if w.as_serving_scenario().is_some() {
                            run.result.as_serving().is_some()
                        } else {
                            run.result.as_sim().is_some()
                        };
                        assert!(ok, "{}", run.label);
                        assert!(run.result.workflow().is_some(),
                                "{}: workflow cell must carry its stats",
                                run.label);
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_sweep_worker_count_does_not_change_results() {
        let cells = mixed_grid();
        let one = run_sweep(&cells, 1);
        let many = run_sweep(&cells, 8);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.result.mean_latency(), b.result.mean_latency(),
                       "{}", a.label);
            assert_eq!(a.result.total_throughput(),
                       b.result.total_throughput(), "{}", a.label);
            assert_eq!(a.result.cost_dollars(), b.result.cost_dollars(),
                       "{}", a.label);
        }
    }

    #[test]
    fn sweep_cells_match_their_sequential_twins() {
        let cells = mixed_grid();
        let runs = run_sweep(&cells, default_workers());
        for (run, cell) in runs.iter().zip(&cells) {
            match cell {
                SweepCell::Single(sc) => {
                    let mut policy = sc.policy.clone();
                    let want = sc.simulator().run(&mut policy);
                    let got = run.result.as_sim().unwrap();
                    assert_eq!(got.mean_latency(), want.mean_latency(),
                               "{}", run.label);
                    assert_eq!(got.cost_dollars, want.cost_dollars);
                }
                SweepCell::Cluster(sc) => {
                    let want = sc.simulator().run().unwrap();
                    let got = run.result.as_cluster().unwrap();
                    assert_eq!(got, &want, "{}", run.label);
                }
                SweepCell::Trace(sc) => {
                    let mut policy = sc.policy.clone();
                    let want = sc.simulator()
                        .run_trace(&mut policy, sc.trace());
                    let got = run.result.as_sim().unwrap();
                    assert_eq!(got.mean_latency(), want.mean_latency(),
                               "{}", run.label);
                    assert_eq!(got.cost_dollars, want.cost_dollars);
                }
                SweepCell::Cost(sc) => {
                    let mut policy = sc.policy.clone();
                    let want = sc.simulator().run(&mut policy);
                    let got = run.result.as_sim().unwrap();
                    assert_eq!(got.mean_latency(), want.mean_latency(),
                               "{}", run.label);
                    assert_eq!(got.cost_dollars, want.cost_dollars);
                    assert_eq!(got.economics, want.economics,
                               "{}", run.label);
                }
                SweepCell::Serving(sc) => {
                    let mut policy = sc.policy.clone();
                    let want = match sc.trace() {
                        Some(t) => sc.simulator()
                            .run_trace(&mut policy, t),
                        None => sc.simulator().run(&mut policy),
                    };
                    let got = run.result.as_serving().unwrap();
                    assert_eq!(got, &want, "{}", run.label);
                }
                SweepCell::Fault(sc) => {
                    if let Some(s) = sc.as_single() {
                        let mut policy = s.policy.clone();
                        let want = s.simulator().run(&mut policy);
                        let got = run.result.as_sim().unwrap();
                        assert_eq!(got.mean_latency(),
                                   want.mean_latency(), "{}", run.label);
                        assert_eq!(got.resilience, want.resilience,
                                   "{}", run.label);
                    } else if let Some(s) = sc.as_cluster_scenario() {
                        let want = s.simulator().run().unwrap();
                        let got = run.result.as_cluster().unwrap();
                        assert_eq!(got, &want, "{}", run.label);
                    } else if let Some(s) = sc.as_serving_scenario() {
                        let mut policy = s.policy.clone();
                        let want = s.simulator().run(&mut policy);
                        let got = run.result.as_serving().unwrap();
                        assert_eq!(got, &want, "{}", run.label);
                    }
                }
                SweepCell::Workflow(sc) => {
                    if let Some(s) = sc.as_single() {
                        let mut policy = s.policy.clone();
                        let want = s.simulator().run(&mut policy);
                        let got = run.result.as_sim().unwrap();
                        assert_eq!(got.mean_latency(),
                                   want.mean_latency(), "{}", run.label);
                        assert_eq!(got.workflow, want.workflow,
                                   "{}", run.label);
                    } else if let Some(s) = sc.as_cluster_scenario() {
                        let want = s.simulator().run().unwrap();
                        let got = run.result.as_cluster().unwrap();
                        assert_eq!(got, &want, "{}", run.label);
                    } else if let Some(s) = sc.as_serving_scenario() {
                        let mut policy = s.policy.clone();
                        let want = s.simulator().run(&mut policy);
                        let got = run.result.as_serving().unwrap();
                        assert_eq!(got, &want, "{}", run.label);
                    }
                }
            }
        }
    }

    #[test]
    fn zero_fault_cells_are_bit_identical_to_plain_cells() {
        // The control cells of the robustness grid: a FaultScenario
        // with an empty plan must reproduce the plain scenario exactly.
        let cells = vec![
            SweepCell::Single(Scenario::paper("control",
                                              PolicyKind::adaptive())),
            SweepCell::Fault(FaultScenario::single(
                "control", SimConfig::paper(), AgentRegistry::paper(),
                PolicyKind::adaptive(),
                FaultConfig::new(FaultPlan::empty()))),
            SweepCell::Serving(ServingScenario::new(
                "control/serving", serving_cfg(), AgentRegistry::paper(),
                PolicyKind::adaptive())),
            SweepCell::Fault(FaultScenario::serving(
                "control/serving", serving_cfg(), AgentRegistry::paper(),
                PolicyKind::adaptive(),
                ServingFaults::new(FaultPlan::empty()))),
        ];
        let runs = run_sweep(&cells, 2);
        let a = runs[0].result.as_sim().unwrap();
        let b = runs[1].result.as_sim().unwrap();
        assert_eq!(a.mean_latency(), b.mean_latency());
        assert_eq!(a.cost_dollars, b.cost_dollars);
        assert_eq!(a.agent_latencies(), b.agent_latencies());
        assert_eq!(a.agent_throughputs(), b.agent_throughputs());
        assert!(b.resilience.is_none(), "empty plan must stay inert");
        // Serving results derive PartialEq: full-struct equality.
        assert_eq!(runs[2].result.as_serving(),
                   runs[3].result.as_serving());
    }

    #[test]
    #[should_panic(expected = "trace agent columns")]
    fn trace_cell_rejects_reordered_agent_columns() {
        let mut trace = Trace::paper_poisson(10, 1);
        trace.agents.swap(0, 2); // columns no longer match the registry
        TraceScenario::new("bad", SimConfig::paper(),
                           AgentRegistry::paper(), trace,
                           PolicyKind::adaptive());
    }

    #[test]
    fn shared_trace_is_not_deep_copied_per_cell() {
        let trace = Arc::new(Trace::paper_poisson(10, 1));
        let cells: Vec<SweepCell> = PolicyKind::all().into_iter()
            .map(|p| SweepCell::Trace(TraceScenario::new(
                p.name(), SimConfig::paper(), AgentRegistry::paper(),
                Arc::clone(&trace), p)))
            .collect();
        // One recording buffer, shared by every policy's cell.
        assert_eq!(Arc::strong_count(&trace), 1 + cells.len());
        let runs = run_sweep(&cells, 2);
        assert!(runs.iter().all(|r| r.result.as_sim().is_some()));
    }

    #[test]
    fn corpus_cells_carry_policy_and_trace_labels() {
        let mut corpus = TraceCorpus::new();
        corpus.push("day1", Trace::paper_poisson(10, 1));
        corpus.push("day2", Trace::paper_poisson(10, 2));
        let cells = TraceScenario::corpus(
            &corpus, &SimConfig::paper(), &AgentRegistry::paper(),
            &PolicyKind::adaptive()).unwrap();
        let labels: Vec<&str> = cells.iter().map(SweepCell::label).collect();
        assert_eq!(labels, vec!["adaptive/day1", "adaptive/day2"]);
        let runs = run_sweep(&cells, 2);
        assert!(runs.iter().all(|r| r.result.as_sim()
                .is_some_and(|s| s.steps == 10)));
    }

    /// Full-result equality across cell-result kinds (SimResult derives
    /// no PartialEq, so its comparable fields are checked one by one).
    fn assert_cell_results_match(a: &CellResult, b: &CellResult,
                                 label: &str) {
        match (a, b) {
            (CellResult::Sim(x), CellResult::Sim(y)) => {
                assert_eq!(x.mean_latency(), y.mean_latency(), "{label}");
                assert_eq!(x.agent_latencies(), y.agent_latencies(),
                           "{label}");
                assert_eq!(x.agent_throughputs(), y.agent_throughputs(),
                           "{label}");
                assert_eq!(x.cost_dollars, y.cost_dollars, "{label}");
                assert_eq!(x.economics, y.economics, "{label}");
                assert_eq!(x.resilience, y.resilience, "{label}");
                assert_eq!(x.workflow, y.workflow, "{label}");
            }
            (CellResult::Cluster(x), CellResult::Cluster(y)) =>
                assert_eq!(x, y, "{label}"),
            (CellResult::Serving(x), CellResult::Serving(y)) =>
                assert_eq!(x, y, "{label}"),
            _ => panic!("{label}: cell-result kinds differ"),
        }
    }

    #[test]
    fn builder_cells_are_bit_identical_to_constructor_cells() {
        let reg = AgentRegistry::paper;
        let cfg = SimConfig::paper;
        let trace = Arc::new(Trace::paper_poisson(40, 7));
        let serving_trace = Arc::new(Trace::paper_poisson(2, 7));
        let plan = || FaultConfig::new(
            FaultModel::spot(0.01, 42).generate(1, 100.0));
        let sfaults = || ServingFaults::new(FaultPlan::empty())
            .with_admission(AdmissionControl::new(
                64, ShedPolicy::DropByPriority));

        // One builder cell per kind, paired with its constructor twin.
        let built: Vec<SweepCell> = vec![
            ScenarioBuilder::new("single", cfg(), reg())
                .policy(PolicyKind::static_equal()).build().unwrap(),
            ScenarioBuilder::new("cluster", cfg(), reg())
                .gpus(2, 1.0).build().unwrap(),
            ScenarioBuilder::new("cluster/spread", cfg(), reg())
                .capacities(vec![1.0, 0.5])
                .placement(PlacementStrategy::PrioritySpread)
                .rebalancer(Rebalancer::Repack(MigrationModel::default()))
                .build().unwrap(),
            ScenarioBuilder::new("trace", cfg(), reg())
                .trace(Arc::clone(&trace)).build().unwrap(),
            ScenarioBuilder::new("cost", cfg(), reg())
                .economics(EconomicsModel::with_idle_timeout(5.0))
                .build().unwrap(),
            ScenarioBuilder::new("serving", cfg(), reg())
                .serving(serving_cfg()).build().unwrap(),
            ScenarioBuilder::new("serving/trace", cfg(), reg())
                .serving(serving_cfg()).trace(Arc::clone(&serving_trace))
                .build().unwrap(),
            ScenarioBuilder::new("fault", cfg(), reg())
                .faults(plan()).build().unwrap(),
            ScenarioBuilder::new("fault/cluster", cfg(), reg())
                .capacities(vec![1.2, 1.2]).faults(plan())
                .build().unwrap(),
            ScenarioBuilder::new("fault/serving", cfg(), reg())
                .serving(serving_cfg()).serving_faults(sfaults())
                .build().unwrap(),
            ScenarioBuilder::new("workflow", cfg(), reg())
                .policy(PolicyKind::critical_path_for(
                    &WorkflowSpec::paper(), 4))
                .workflow(WorkflowWorkload::paper()).build().unwrap(),
            ScenarioBuilder::new("workflow/cluster", cfg(), reg())
                .capacities(vec![1.2, 1.2])
                .placement(PlacementStrategy::WorkflowColocate)
                .workflow(WorkflowWorkload::paper()).build().unwrap(),
            ScenarioBuilder::new("workflow/serving", cfg(), reg())
                .serving(serving_cfg())
                .workflow(WorkflowWorkload::paper()).build().unwrap(),
        ];
        let constructed: Vec<SweepCell> = vec![
            SweepCell::Single(Scenario::new(
                "single", cfg(), reg(), PolicyKind::static_equal())),
            SweepCell::Cluster(ClusterScenario::new(
                "cluster", cfg(), reg(), 2, 1.0,
                Rebalancer::Static).unwrap()),
            SweepCell::Cluster(ClusterScenario::with_policies(
                "cluster/spread", cfg(), reg(), vec![1.0, 0.5],
                PlacementStrategy::PrioritySpread,
                Rebalancer::Repack(MigrationModel::default())).unwrap()),
            SweepCell::Trace(TraceScenario::new(
                "trace", cfg(), reg(), Arc::clone(&trace),
                PolicyKind::adaptive())),
            SweepCell::Cost(CostScenario::new(
                "cost", cfg(), reg(),
                EconomicsModel::with_idle_timeout(5.0),
                PolicyKind::adaptive())),
            SweepCell::Serving(ServingScenario::new(
                "serving", serving_cfg(), reg(), PolicyKind::adaptive())),
            SweepCell::Serving(ServingScenario::from_trace(
                "serving/trace", serving_cfg(), reg(),
                Arc::clone(&serving_trace), PolicyKind::adaptive())),
            SweepCell::Fault(FaultScenario::single(
                "fault", cfg(), reg(), PolicyKind::adaptive(), plan())),
            SweepCell::Fault(FaultScenario::cluster(
                "fault/cluster", cfg(), reg(), vec![1.2, 1.2],
                PlacementStrategy::default(), Rebalancer::Static,
                plan()).unwrap()),
            SweepCell::Fault(FaultScenario::serving(
                "fault/serving", serving_cfg(), reg(),
                PolicyKind::adaptive(), sfaults())),
            SweepCell::Workflow(WorkflowScenario::single(
                "workflow", cfg(), reg(),
                PolicyKind::critical_path_for(&WorkflowSpec::paper(), 4),
                WorkflowWorkload::paper()).unwrap()),
            SweepCell::Workflow(WorkflowScenario::cluster(
                "workflow/cluster", cfg(), reg(), vec![1.2, 1.2],
                PlacementStrategy::WorkflowColocate, Rebalancer::Static,
                WorkflowWorkload::paper()).unwrap()),
            SweepCell::Workflow(WorkflowScenario::serving(
                "workflow/serving", serving_cfg(), reg(),
                PolicyKind::adaptive(),
                WorkflowWorkload::paper()).unwrap()),
        ];
        assert_eq!(built.len(), constructed.len());
        let a = run_sweep(&built, 2);
        let b = run_sweep(&constructed, 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_cell_results_match(&x.result, &y.result, &x.label);
        }
    }

    #[test]
    fn builder_routes_each_axis_set_to_the_right_cell_kind() {
        let cell = ScenarioBuilder::new(
            "w", SimConfig::paper(), AgentRegistry::paper())
            .workflow(WorkflowWorkload::paper()).build().unwrap();
        assert!(matches!(cell, SweepCell::Workflow(_)));
        let cell = ScenarioBuilder::new(
            "f+c", SimConfig::paper(), AgentRegistry::paper())
            .gpus(2, 1.0)
            .faults(FaultConfig::new(FaultPlan::empty())).build().unwrap();
        assert!(matches!(cell, SweepCell::Fault(_)));
        let cell = ScenarioBuilder::new(
            "bare", SimConfig::paper(), AgentRegistry::paper())
            .build().unwrap();
        assert!(matches!(cell, SweepCell::Single(_)));
    }

    #[test]
    fn builder_rejects_incompatible_axis_combinations() {
        let mk = || ScenarioBuilder::new(
            "bad", SimConfig::paper(), AgentRegistry::paper());
        assert!(mk().trace(Trace::paper_poisson(10, 1))
                .workflow(WorkflowWorkload::paper()).build().is_err());
        assert!(mk().trace(Trace::paper_poisson(10, 1))
                .gpus(2, 1.0).build().is_err());
        assert!(mk().serving(serving_cfg()).gpus(2, 1.0).build().is_err());
        assert!(mk().serving_faults(ServingFaults::new(FaultPlan::empty()))
                .build().is_err());
    }
}
