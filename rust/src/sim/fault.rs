//! Deterministic fault injection and graceful degradation (§II.B's
//! "capacity constraints in serverless environments", made measurable).
//!
//! The serverless setting makes failure the common case — spot
//! preemption evicts devices mid-run, stragglers stall agents, and
//! overload arrives faster than capacity — yet a simulator without fault
//! machinery only ever measures a world where hardware never breaks.
//! This module supplies the missing half as *pure data*: a
//! [`FaultPlan`] is a seeded, pre-sorted list of clock-driven
//! [`FaultEvent`]s, generated once before a run (optionally via
//! [`FaultModel::spot`]) so the same `(seed, config)` pair always yields
//! the same faults regardless of worker count or engine.
//!
//! Consumption is split across the three engines:
//!
//! * the fluid engine (`sim::engine`) consumes [`FaultEvent::CapacityDrop`]
//!   and whole-device [`FaultEvent::GpuEviction`] as capacity outages and
//!   [`FaultEvent::AgentStall`] as service-rate divisors;
//! * the cluster engine (`cluster::ClusterSimulator`) marks evicted
//!   devices offline and recovers through the `Rebalancer::Repack`
//!   placement layer under a **repack throttle**
//!   ([`FaultConfig::repack_max_move_fraction`]) so the failure response
//!   is itself bounded, optionally paying a serverless cold-start rewarm
//!   ([`FaultConfig::rewarm`]) per migrated agent;
//! * the serving layer (`ServingCore` + both shells) gains the
//!   degradation half: bounded [`RetryPolicy`] retry-with-backoff for
//!   failed batches and [`AdmissionControl`] load shedding
//!   ([`ShedPolicy`]) so overload sheds instead of queueing unboundedly.
//!
//! Every engine surfaces a [`ResilienceReport`] on its result — `None`
//! whenever no faults are configured, and the disabled path is
//! bit-exact: no float op, RNG draw, or allocation differs from a run
//! without the fault layer compiled in.

use crate::serverless::ColdStartModel;
use crate::util::Rng;

/// Seed perturbation for the fault-plan generator, so fault timing never
/// shares a stream with workload arrivals or cold-start jitter.
const FAULT_SEED_XOR: u64 = 0xFA17;

/// One scheduled fault. Times are seconds on the run's virtual clock; an
/// event is active during `[t, t + duration)`.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Device `gpu` is evicted (spot preemption) at `t` and returns
    /// `duration` seconds later. The fluid engine treats any eviction as
    /// a whole-capacity outage (it models a single device); the cluster
    /// engine marks exactly that device offline.
    GpuEviction {
        /// Eviction time (s).
        t: f64,
        /// Evicted device index.
        gpu: usize,
        /// Outage length (s).
        duration: f64,
    },
    /// Agent `agent`'s service rate is divided by `factor` (≥ 1) during
    /// the window — a straggling replica. The cluster engine treats a
    /// stalled agent as forfeiting its allocation for the window; the
    /// serving simulator fails the agent's batch dispatches transiently.
    AgentStall {
        /// Stall onset (s).
        t: f64,
        /// Stalled agent id.
        agent: usize,
        /// Service-rate divisor (values below 1 are clamped to 1).
        factor: f64,
        /// Stall length (s).
        duration: f64,
    },
    /// Total capacity is scaled by `1 − frac` during the window — the
    /// provider reclaiming a slice of the device pool.
    CapacityDrop {
        /// Drop onset (s).
        t: f64,
        /// Fraction of capacity lost, in [0, 1].
        frac: f64,
        /// Drop length (s).
        duration: f64,
    },
}

impl FaultEvent {
    /// Event start time (s).
    pub fn start(&self) -> f64 {
        match self {
            FaultEvent::GpuEviction { t, .. }
            | FaultEvent::AgentStall { t, .. }
            | FaultEvent::CapacityDrop { t, .. } => *t,
        }
    }

    /// Event end time (s).
    pub fn end(&self) -> f64 {
        let d = match self {
            FaultEvent::GpuEviction { duration, .. }
            | FaultEvent::AgentStall { duration, .. }
            | FaultEvent::CapacityDrop { duration, .. } => *duration,
        };
        self.start() + d
    }

    /// Whether the event window contains `now`.
    pub fn active_at(&self, now: f64) -> bool {
        now >= self.start() && now < self.end()
    }
}

/// A reproducible fault schedule: events sorted by start time.
///
/// Plans are pure data — build one by hand for targeted tests or sample
/// one from a [`FaultModel`]; either way the run consumes it read-only,
/// so sweep cells stay bit-identical at any worker count.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Scheduled events, ascending by [`FaultEvent::start`].
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan, sorting events by start time (stable, so equal-time
    /// events keep their construction order).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| {
            a.start()
                .partial_cmp(&b.start())
                .expect("fault event times are finite")
        });
        FaultPlan { events }
    }

    /// The empty plan (injects nothing).
    pub fn empty() -> Self {
        FaultPlan { events: Vec::new() }
    }

    /// True when the plan schedules no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Seeded generator of spot-eviction schedules.
///
/// Inter-eviction gaps are exponential with rate
/// [`FaultModel::eviction_rate`] (a Poisson process — the standard spot
/// preemption model), the victim device is uniform over the fleet, and
/// outage lengths are exponential with mean [`FaultModel::mean_outage_s`].
/// All draws come from a dedicated `Rng::new(seed ^ 0xFA17)` stream so
/// fault timing never perturbs workload randomness.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultModel {
    /// Expected evictions per second across the whole fleet.
    pub eviction_rate: f64,
    /// Mean outage length in seconds.
    pub mean_outage_s: f64,
    /// Generator seed (perturbed internally; safe to share with the run
    /// seed).
    pub seed: u64,
}

impl FaultModel {
    /// A spot-preemption model: `rate` evictions per second fleet-wide,
    /// 20 s mean outage (the short-notice reclaim-and-return cycle of
    /// preemptible capacity).
    pub fn spot(rate: f64, mtbf_seed: u64) -> Self {
        FaultModel { eviction_rate: rate, mean_outage_s: 20.0, seed: mtbf_seed }
    }

    /// Sample an eviction schedule over `[0, horizon_s)` for a fleet of
    /// `n_gpus` devices. Same model ⇒ identical plan.
    pub fn generate(&self, n_gpus: usize, horizon_s: f64) -> FaultPlan {
        let mut events = Vec::new();
        if self.eviction_rate > 0.0 && n_gpus > 0 && horizon_s > 0.0 {
            let mut rng = Rng::new(self.seed ^ FAULT_SEED_XOR);
            let mut t = rng.exponential(self.eviction_rate);
            while t < horizon_s {
                let gpu = rng.below(n_gpus as u64) as usize;
                let duration =
                    rng.exponential(1.0 / self.mean_outage_s.max(1e-9));
                events.push(FaultEvent::GpuEviction { t, gpu, duration });
                t += rng.exponential(self.eviction_rate);
            }
        }
        FaultPlan::new(events)
    }
}

/// Bounded retry-with-backoff for failed serving batches.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Total execution attempts per batch (1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry (s).
    pub backoff_s: f64,
    /// Multiplier applied per subsequent retry (exponential backoff).
    pub backoff_multiplier: f64,
}

impl RetryPolicy {
    /// No retries — a failed batch fails permanently (the pre-fault-layer
    /// behaviour, and the `ServingCore` default).
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, backoff_s: 0.0, backoff_multiplier: 1.0 }
    }

    /// The default bounded policy: up to 3 attempts, 10 ms initial
    /// backoff, doubling.
    pub fn bounded() -> Self {
        RetryPolicy { max_attempts: 3, backoff_s: 0.01, backoff_multiplier: 2.0 }
    }

    /// True when this policy ever retries.
    pub fn retries(&self) -> bool {
        self.max_attempts > 1
    }

    /// Backoff to wait after failed attempt number `attempt` (0-based).
    pub fn backoff_for(&self, attempt: u32) -> f64 {
        self.backoff_s * self.backoff_multiplier.powi(attempt.min(30) as i32)
    }
}

/// Which queued request an overloaded server sheds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Drop the incoming request (tail drop).
    DropNewest,
    /// Drop from the lowest-priority agent with queued work; the
    /// incoming request is only shed when nothing lower-priority is
    /// queued, so `High` work is never shed before all lower tiers.
    DropByPriority,
    /// Expire queued requests older than the admission deadline, then
    /// tail-drop if nothing expired.
    DeadlineAware,
}

impl ShedPolicy {
    /// Stable label for sweep-cell names and CSV columns.
    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::DropNewest => "newest",
            ShedPolicy::DropByPriority => "priority",
            ShedPolicy::DeadlineAware => "deadline",
        }
    }

    /// All policies, in sweep order.
    pub fn all() -> Vec<ShedPolicy> {
        vec![ShedPolicy::DropNewest, ShedPolicy::DropByPriority,
             ShedPolicy::DeadlineAware]
    }
}

/// Admission control for the serving layer: a total queue bound plus the
/// shed policy applied when an arrival would exceed it.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionControl {
    /// Maximum requests queued across all agents before shedding starts.
    pub max_queued: usize,
    /// What to shed once the bound is hit.
    pub policy: ShedPolicy,
    /// [`ShedPolicy::DeadlineAware`] only: queued age (s) beyond which a
    /// request is considered expired.
    pub deadline_s: f64,
}

impl AdmissionControl {
    /// Admission control with a 1 s expiry deadline.
    pub fn new(max_queued: usize, policy: ShedPolicy) -> Self {
        AdmissionControl { max_queued, policy, deadline_s: 1.0 }
    }
}

/// Fault configuration for the fluid and cluster engines
/// (`SimConfig::faults`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// The fault schedule.
    pub plan: FaultPlan,
    /// Repack throttle: the largest fraction of agents one recovery
    /// repack may move (cluster engine, `Rebalancer::Repack` only). A
    /// recovery step moves at most `⌊fraction · n_agents⌋` agents; the
    /// remainder wait for later steps, so the failure response is itself
    /// bounded. Fractions below `1/n_agents` disable recovery entirely.
    pub repack_max_move_fraction: f64,
    /// Serverless rewarm: when set, every recovery-migrated agent pays a
    /// sampled cold start (model load on the new device) on top of the
    /// migration transfer stall. Draws come from the run's dedicated
    /// fault RNG stream, never the workload stream.
    pub rewarm: Option<ColdStartModel>,
}

impl FaultConfig {
    /// Faults with an unthrottled repack and no rewarm cost.
    pub fn new(plan: FaultPlan) -> Self {
        FaultConfig { plan, repack_max_move_fraction: 1.0, rewarm: None }
    }

    /// Bound the fraction of agents one recovery repack may move.
    pub fn with_repack_throttle(mut self, fraction: f64) -> Self {
        self.repack_max_move_fraction = fraction.clamp(0.0, 1.0);
        self
    }

    /// Charge a sampled serverless cold start per recovery migration.
    pub fn with_rewarm(mut self, model: ColdStartModel) -> Self {
        self.rewarm = Some(model);
        self
    }

    /// True when this configuration cannot affect a run (empty plan) —
    /// the engines then skip every fault hook and report no
    /// [`ResilienceReport`].
    pub fn is_inert(&self) -> bool {
        self.plan.is_empty()
    }
}

/// Fault configuration for the serving layer (`ServingConfig::faults`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingFaults {
    /// The fault schedule (stalls fail the stalled agent's dispatches
    /// transiently; evictions fail every dispatch in the window).
    pub plan: FaultPlan,
    /// Retry-with-backoff applied to failed batches.
    pub retry: RetryPolicy,
    /// Optional admission control / load shedding.
    pub admission: Option<AdmissionControl>,
}

impl ServingFaults {
    /// Faults with the default bounded retry and no admission control.
    pub fn new(plan: FaultPlan) -> Self {
        ServingFaults { plan, retry: RetryPolicy::bounded(), admission: None }
    }

    /// Replace the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Enable admission control.
    pub fn with_admission(mut self, admission: AdmissionControl) -> Self {
        self.admission = Some(admission);
        self
    }

    /// True when this configuration cannot affect a run: no events to
    /// fail anything (retry then never triggers) and no admission bound.
    pub fn is_inert(&self) -> bool {
        self.plan.is_empty() && self.admission.is_none()
    }

    /// Whether an execution attempt for `agent` dispatched at `now`
    /// fails transiently: the agent is inside a stall window, or any
    /// device is evicted.
    ///
    /// This scans the whole plan and is fine for spot checks; the
    /// serving hot loop drives a [`ServingFaultCursor`] instead, which
    /// answers the same question in O(active events) per call for
    /// monotone `now`.
    pub fn fails_at(&self, now: f64, agent: usize) -> bool {
        self.plan.events.iter().any(|e| {
            e.active_at(now) && Self::event_fails(e, agent)
        })
    }

    fn event_fails(e: &FaultEvent, agent: usize) -> bool {
        match e {
            FaultEvent::AgentStall { agent: a, .. } => *a == agent,
            FaultEvent::GpuEviction { .. } => true,
            FaultEvent::CapacityDrop { .. } => false,
        }
    }
}

/// Monotone-time cursor over a [`ServingFaults`] plan: the serving
/// engines call [`ServingFaultCursor::fails_at`] with non-decreasing
/// `now`, so instead of rescanning every event per dispatch the cursor
/// admits events as their start passes and retires them as they expire —
/// O(total events) over a whole run, O(currently active) per query.
/// Answers are identical to [`ServingFaults::fails_at`].
#[derive(Debug)]
pub(crate) struct ServingFaultCursor<'a> {
    plan: &'a FaultPlan,
    next_event: usize,
    /// Indices of admitted-and-not-expired events, in plan order.
    active: Vec<usize>,
}

impl<'a> ServingFaultCursor<'a> {
    pub(crate) fn new(faults: &'a ServingFaults) -> Self {
        ServingFaultCursor {
            plan: &faults.plan,
            next_event: 0,
            active: Vec::new(),
        }
    }

    /// [`ServingFaults::fails_at`] for monotone `now`.
    pub(crate) fn fails_at(&mut self, now: f64, agent: usize) -> bool {
        let plan = self.plan;
        self.active.retain(|i| plan.events[*i].active_at(now));
        while let Some(e) = self.plan.events.get(self.next_event) {
            if e.start() > now {
                break;
            }
            if e.active_at(now) {
                self.active.push(self.next_event);
            }
            self.next_event += 1;
        }
        self.active.iter().any(
            |i| ServingFaults::event_fails(&self.plan.events[*i], agent))
    }
}

/// Smallest step index `s >= from` with `s·dt >= t`, using the exact
/// comparisons the per-step trackers use (`now = step as f64 * dt`), so
/// a skip bounded by the returned step admits events on precisely the
/// tick the dense loop would have.
fn first_step_at_or_after(t: f64, dt: f64, from: u64) -> u64 {
    let mut s = if t <= from as f64 * dt {
        from
    } else {
        ((t / dt).floor() as u64).max(from)
    };
    while (s as f64) * dt < t {
        s += 1;
    }
    while s > from && ((s - 1) as f64) * dt >= t {
        s -= 1;
    }
    s
}

/// Resilience metrics for one run. `None` on results whenever no faults
/// were configured; fields that an engine does not measure are 0.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResilienceReport {
    /// Time (s) the run spent degraded: fluid engine — steps with any
    /// active fault; cluster — steps with an agent on an offline device;
    /// serving — GPU-seconds burned by failed attempts plus backoff.
    pub recovery_time_s: f64,
    /// Fraction of offered requests shed by admission control.
    pub shed_fraction: f64,
    /// Degradation actions taken: retried batches (serving) or recovery
    /// migrations (cluster).
    pub retried: u64,
    /// Completed requests per second over the whole run, faults included.
    pub goodput: f64,
    /// How disruptive the run's failure response was: the largest agent
    /// fraction one recovery repack moved (cluster — bounded by
    /// [`FaultConfig::repack_max_move_fraction`]), the peak fraction of
    /// agents simultaneously stalled (fluid), or the fraction of offered
    /// requests that failed permanently (serving).
    pub disruption: f64,
}

/// Per-run fault bookkeeping for the fluid engine. Follows the
/// `EconInstruments` pattern: constructed from the optional config, and
/// every hook is a no-op returning its input untouched when no fault can
/// fire — the disabled path is bit-exact.
///
/// The tracker is a sorted event cursor: [`FaultTracker::capacity_at`]
/// must be called with non-decreasing `step` (the engine's loop order).
/// Events are admitted to the `active` set as their start time passes
/// and retired as they expire, preserving *plan order* inside the set —
/// the order the old full-plan rescan applied overlapping
/// `CapacityDrop` multiplications and `AgentStall` divisions in, so
/// results stay bit-identical while each step costs O(active events)
/// instead of O(all events).
#[derive(Debug)]
pub(crate) struct FaultTracker<'a> {
    cfg: Option<&'a FaultConfig>,
    degraded_s: f64,
    max_stalled_fraction: f64,
    next_event: usize,
    /// Admitted-and-unexpired event indices, ascending (= plan order).
    active: Vec<usize>,
}

impl<'a> FaultTracker<'a> {
    /// Build the tracker; inert configs are dropped outright.
    pub(crate) fn new(cfg: Option<&'a FaultConfig>) -> Self {
        FaultTracker {
            cfg: cfg.filter(|f| !f.is_inert()),
            degraded_s: 0.0,
            max_stalled_fraction: 0.0,
            next_event: 0,
            active: Vec::new(),
        }
    }

    /// Whether any fault can fire this run.
    pub(crate) fn is_active(&self) -> bool {
        self.cfg.is_some()
    }

    /// Effective total capacity at step `step`: evictions zero it,
    /// capacity drops scale it. Also accrues degraded time and the peak
    /// stalled-agent fraction. Returns `base` untouched when inactive.
    /// Steps must be non-decreasing across calls (cursor contract).
    pub(crate) fn capacity_at(&mut self, step: u64, dt: f64, base: f64,
                              n_agents: usize) -> f64 {
        let Some(f) = self.cfg else { return base };
        let now = step as f64 * dt;
        let events = &f.plan.events;
        self.active.retain(|i| events[*i].active_at(now));
        while let Some(e) = events.get(self.next_event) {
            if e.start() > now {
                break;
            }
            if e.active_at(now) {
                self.active.push(self.next_event);
            }
            self.next_event += 1;
        }
        let mut scale = 1.0;
        let mut stalled = 0usize;
        for i in &self.active {
            match &events[*i] {
                FaultEvent::GpuEviction { .. } => scale = 0.0,
                FaultEvent::CapacityDrop { frac, .. } => {
                    scale *= (1.0 - frac).max(0.0);
                }
                FaultEvent::AgentStall { agent, .. } => {
                    if *agent < n_agents {
                        stalled += 1;
                    }
                }
            }
        }
        if scale < 1.0 || stalled > 0 {
            self.degraded_s += dt;
        }
        if n_agents > 0 {
            let frac = (stalled as f64 / n_agents as f64).min(1.0);
            if frac > self.max_stalled_fraction {
                self.max_stalled_fraction = frac;
            }
        }
        base * scale
    }

    /// Service rate for `agent` at step `step` after stall divisors.
    /// Returns `rate` untouched when inactive. Must be called for the
    /// same `step` as the preceding [`FaultTracker::capacity_at`] (the
    /// active set is maintained there).
    pub(crate) fn degrade_rate(&self, step: u64, dt: f64, agent: usize,
                               rate: f64) -> f64 {
        let Some(f) = self.cfg else { return rate };
        let now = step as f64 * dt;
        let mut r = rate;
        for i in &self.active {
            if let FaultEvent::AgentStall { agent: a, factor, .. } =
                &f.plan.events[*i]
            {
                if *a == agent && f.plan.events[*i].active_at(now) {
                    r /= factor.max(1.0);
                }
            }
        }
        r
    }

    /// Skip-idle contract: `Some(until)` promises that for every step
    /// `s` in `[step, until)`, [`FaultTracker::capacity_at`] would
    /// return `base` untouched and accrue nothing, and
    /// [`FaultTracker::degrade_rate`] would return its input — i.e. the
    /// fault layer is provably quiet over the window. `None` means the
    /// current step may be (or is about to become) faulted; the engine
    /// then steps densely, which also retires expired events.
    pub(crate) fn idle_until(&self, step: u64, dt: f64) -> Option<u64> {
        let Some(f) = self.cfg else { return Some(u64::MAX) };
        if !self.active.is_empty() {
            return None;
        }
        match f.plan.events.get(self.next_event) {
            None => Some(u64::MAX),
            Some(e) => {
                let due = first_step_at_or_after(e.start(), dt, step);
                if due > step { Some(due) } else { None }
            }
        }
    }

    /// Fold the run's bookkeeping into a report; `None` when inactive.
    pub(crate) fn finish(self, goodput: f64) -> Option<ResilienceReport> {
        self.cfg.map(|_| ResilienceReport {
            recovery_time_s: self.degraded_s,
            shed_fraction: 0.0,
            retried: 0,
            goodput,
            disruption: self.max_stalled_fraction,
        })
    }
}

/// Per-run fault bookkeeping for the cluster engine: device offline
/// windows, throttled recovery accounting, and the rewarm RNG stream.
/// Inert configs are dropped at construction; every hook then no-ops.
#[derive(Debug)]
pub(crate) struct ClusterFaultTracker<'a> {
    cfg: Option<&'a FaultConfig>,
    rng: Rng,
    offline_until: Vec<f64>,
    caps_scratch: Vec<f64>,
    next_event: usize,
    recovery_moves: u64,
    degraded_s: f64,
    max_move_fraction: f64,
}

impl<'a> ClusterFaultTracker<'a> {
    /// Build the tracker for a fleet of `n_gpus` devices.
    pub(crate) fn new(cfg: Option<&'a FaultConfig>, n_gpus: usize,
                      seed: u64) -> Self {
        let cfg = cfg.filter(|f| !f.is_inert());
        ClusterFaultTracker {
            cfg,
            rng: Rng::new(seed ^ FAULT_SEED_XOR),
            offline_until: if cfg.is_some() {
                vec![0.0; n_gpus]
            } else {
                Vec::new()
            },
            caps_scratch: Vec::new(),
            next_event: 0,
            recovery_moves: 0,
            degraded_s: 0.0,
            max_move_fraction: 0.0,
        }
    }

    /// Whether any fault can fire this run.
    pub(crate) fn is_active(&self) -> bool {
        self.cfg.is_some()
    }

    /// Apply events due at `now`: evictions mark their device offline
    /// through the outage window; agent stalls extend `stalled_until`
    /// (the agent forfeits its allocation, same as a migration stall).
    /// Capacity drops are a fluid-engine concern and are ignored here.
    pub(crate) fn advance(&mut self, now: f64, stalled_until: &mut [f64]) {
        let Some(f) = self.cfg else { return };
        while let Some(e) = f.plan.events.get(self.next_event) {
            if e.start() > now {
                break;
            }
            match e {
                FaultEvent::GpuEviction { gpu, .. } => {
                    if *gpu < self.offline_until.len() {
                        let end = e.end();
                        if end > self.offline_until[*gpu] {
                            self.offline_until[*gpu] = end;
                        }
                    }
                }
                FaultEvent::AgentStall { agent, .. } => {
                    if *agent < stalled_until.len() {
                        let end = e.end();
                        if end > stalled_until[*agent] {
                            stalled_until[*agent] = end;
                        }
                    }
                }
                FaultEvent::CapacityDrop { .. } => {}
            }
            self.next_event += 1;
        }
    }

    /// Whether device `gpu` is offline at `now`.
    pub(crate) fn gpu_offline(&self, gpu: usize, now: f64) -> bool {
        self.cfg.is_some() && now < self.offline_until[gpu]
    }

    /// Whether any device is offline at `now`.
    pub(crate) fn any_offline(&self, now: f64) -> bool {
        self.cfg.is_some() && self.offline_until.iter().any(|t| now < *t)
    }

    /// Device capacities with offline devices zeroed — the view a
    /// recovery repack must place against. Only valid while active.
    pub(crate) fn effective_caps(&mut self, caps: &[f64], now: f64)
                                 -> &[f64] {
        self.caps_scratch.clear();
        self.caps_scratch.extend(caps.iter().enumerate().map(|(g, c)| {
            if now < self.offline_until[g] { 0.0 } else { *c }
        }));
        &self.caps_scratch
    }

    /// Largest number of agents one recovery repack may move under the
    /// configured throttle (0 disables recovery).
    pub(crate) fn max_moves(&self, n_agents: usize) -> usize {
        match self.cfg {
            Some(f) => {
                (f.repack_max_move_fraction * n_agents as f64 + 1e-9).floor()
                    as usize
            }
            None => 0,
        }
    }

    /// Sampled rewarm cold start (s) for a recovery-migrated agent; 0
    /// when no rewarm model is configured (and then draws nothing).
    pub(crate) fn rewarm_s(&mut self, model_mb: u32) -> f64 {
        match self.cfg.and_then(|f| f.rewarm.as_ref()) {
            Some(m) => m.sample(model_mb, &mut self.rng),
            None => 0.0,
        }
    }

    /// Record one recovery repack that moved `moves` agents.
    pub(crate) fn note_recovery(&mut self, moves: usize, n_agents: usize) {
        self.recovery_moves += moves as u64;
        let frac = moves as f64 / n_agents.max(1) as f64;
        if frac > self.max_move_fraction {
            self.max_move_fraction = frac;
        }
    }

    /// Accrue one step of degraded time (an agent sat on an offline
    /// device this step).
    pub(crate) fn note_degraded(&mut self, dt: f64) {
        self.degraded_s += dt;
    }

    /// Skip-idle contract (cluster half): `Some(until)` promises that
    /// for every step `s` in `[step, until)` no device is offline at
    /// `s·dt` and [`ClusterFaultTracker::advance`] would admit no event
    /// — the fault layer is provably quiet over the window. Agent-stall
    /// windows already admitted live in the engine-owned
    /// `stalled_until` buffer, which the engine checks separately.
    pub(crate) fn quiet_until(&self, step: u64, dt: f64) -> Option<u64> {
        let Some(f) = self.cfg else { return Some(u64::MAX) };
        let now = step as f64 * dt;
        if self.offline_until.iter().any(|t| now < *t) {
            return None;
        }
        match f.plan.events.get(self.next_event) {
            None => Some(u64::MAX),
            Some(e) => {
                let due = first_step_at_or_after(e.start(), dt, step);
                if due > step { Some(due) } else { None }
            }
        }
    }

    /// Fold the run's bookkeeping into a report; `None` when inactive.
    pub(crate) fn finish(self, goodput: f64) -> Option<ResilienceReport> {
        self.cfg.map(|_| ResilienceReport {
            recovery_time_s: self.degraded_s,
            shed_fraction: 0.0,
            retried: self.recovery_moves,
            goodput,
            disruption: self.max_move_fraction,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_generates_identical_plans() {
        let m = FaultModel::spot(0.02, 42);
        let a = m.generate(4, 500.0);
        let b = FaultModel::spot(0.02, 42).generate(4, 500.0);
        assert!(!a.is_empty(), "rate 0.02 over 500 s should evict");
        assert_eq!(a, b);
        // A different seed gives a different schedule.
        let c = FaultModel::spot(0.02, 43).generate(4, 500.0);
        assert_ne!(a, c);
    }

    #[test]
    fn zero_rate_or_empty_fleet_generates_nothing() {
        assert!(FaultModel::spot(0.0, 1).generate(4, 100.0).is_empty());
        assert!(FaultModel::spot(0.5, 1).generate(0, 100.0).is_empty());
        assert!(FaultModel::spot(0.5, 1).generate(4, 0.0).is_empty());
    }

    #[test]
    fn plans_are_sorted_and_bounded_by_horizon() {
        let plan = FaultModel::spot(0.05, 7).generate(3, 400.0);
        let starts: Vec<f64> =
            plan.events.iter().map(FaultEvent::start).collect();
        let mut sorted = starts.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(starts, sorted);
        assert!(starts.iter().all(|t| (0.0..400.0).contains(t)));
        for e in &plan.events {
            match e {
                FaultEvent::GpuEviction { gpu, duration, .. } => {
                    assert!(*gpu < 3);
                    assert!(*duration > 0.0);
                }
                other => panic!("spot model only evicts, got {other:?}"),
            }
        }
    }

    #[test]
    fn plan_constructor_sorts_events() {
        let plan = FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 9.0, frac: 0.5, duration: 2.0 },
            FaultEvent::AgentStall {
                t: 1.0, agent: 0, factor: 2.0, duration: 3.0,
            },
        ]);
        assert_eq!(plan.events[0].start(), 1.0);
        assert_eq!(plan.events[1].start(), 9.0);
        assert!(plan.events[0].active_at(1.0));
        assert!(!plan.events[0].active_at(4.0));
        assert_eq!(plan.events[1].end(), 11.0);
    }

    #[test]
    fn retry_policy_backoff_grows_exponentially() {
        let r = RetryPolicy::bounded();
        assert!(r.retries());
        assert!((r.backoff_for(0) - 0.01).abs() < 1e-12);
        assert!((r.backoff_for(1) - 0.02).abs() < 1e-12);
        assert!((r.backoff_for(2) - 0.04).abs() < 1e-12);
        assert!(!RetryPolicy::none().retries());
    }

    #[test]
    fn inertness_rules() {
        assert!(FaultConfig::new(FaultPlan::empty()).is_inert());
        let plan = FaultPlan::new(vec![FaultEvent::GpuEviction {
            t: 1.0, gpu: 0, duration: 5.0,
        }]);
        assert!(!FaultConfig::new(plan.clone()).is_inert());
        assert!(ServingFaults::new(FaultPlan::empty()).is_inert());
        // An admission bound alone makes the serving config live.
        assert!(!ServingFaults::new(FaultPlan::empty())
            .with_admission(AdmissionControl::new(8, ShedPolicy::DropNewest))
            .is_inert());
        assert!(!ServingFaults::new(plan).is_inert());
    }

    #[test]
    fn serving_faults_fail_the_right_dispatches() {
        let f = ServingFaults::new(FaultPlan::new(vec![
            FaultEvent::AgentStall {
                t: 1.0, agent: 2, factor: 4.0, duration: 2.0,
            },
            FaultEvent::GpuEviction { t: 10.0, gpu: 0, duration: 1.0 },
        ]));
        assert!(f.fails_at(1.5, 2));
        assert!(!f.fails_at(1.5, 0)); // stall is agent-scoped
        assert!(!f.fails_at(3.5, 2)); // window over
        assert!(f.fails_at(10.5, 0)); // eviction fails everyone
        assert!(f.fails_at(10.5, 3));
    }

    #[test]
    fn tracker_is_inert_without_faults() {
        let mut t = FaultTracker::new(None);
        assert!(!t.is_active());
        assert_eq!(t.capacity_at(5, 1.0, 1.0, 4), 1.0);
        assert_eq!(t.degrade_rate(5, 1.0, 0, 80.0), 80.0);
        assert!(t.finish(1.0).is_none());
        let empty = FaultConfig::new(FaultPlan::empty());
        assert!(!FaultTracker::new(Some(&empty)).is_active());
    }

    #[test]
    fn tracker_applies_drops_evictions_and_stalls() {
        let cfg = FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 2.0, frac: 0.5, duration: 2.0 },
            FaultEvent::GpuEviction { t: 6.0, gpu: 0, duration: 1.0 },
            FaultEvent::AgentStall {
                t: 8.0, agent: 1, factor: 4.0, duration: 1.0,
            },
        ]));
        let mut t = FaultTracker::new(Some(&cfg));
        assert!(t.is_active());
        assert_eq!(t.capacity_at(0, 1.0, 1.0, 4), 1.0);
        assert!((t.capacity_at(2, 1.0, 1.0, 4) - 0.5).abs() < 1e-12);
        assert_eq!(t.capacity_at(6, 1.0, 1.0, 4), 0.0);
        assert_eq!(t.capacity_at(8, 1.0, 1.0, 4), 1.0); // stall ≠ capacity
        assert!((t.degrade_rate(8, 1.0, 1, 80.0) - 20.0).abs() < 1e-12);
        assert_eq!(t.degrade_rate(8, 1.0, 0, 80.0), 80.0);
        let report = t.finish(150.0).expect("active tracker reports");
        // Steps 2, 6 and 8 were degraded.
        assert!((report.recovery_time_s - 3.0).abs() < 1e-12);
        assert!((report.disruption - 0.25).abs() < 1e-12);
        assert_eq!(report.goodput, 150.0);
    }

    #[test]
    fn serving_cursor_matches_full_scan_for_monotone_time() {
        // A messy plan: overlapping windows, agent-scoped stalls, an
        // eviction, a capacity drop that must fail nothing.
        let f = ServingFaults::new(FaultPlan::new(vec![
            FaultEvent::AgentStall {
                t: 1.0, agent: 2, factor: 4.0, duration: 2.0,
            },
            FaultEvent::CapacityDrop { t: 1.5, frac: 0.9, duration: 5.0 },
            FaultEvent::GpuEviction { t: 2.5, gpu: 0, duration: 1.0 },
            FaultEvent::AgentStall {
                t: 2.8, agent: 0, factor: 2.0, duration: 0.4,
            },
        ]));
        let mut cursor = ServingFaultCursor::new(&f);
        let mut now = 0.0;
        while now < 5.0 {
            for agent in 0..4 {
                assert_eq!(cursor.fails_at(now, agent),
                           f.fails_at(now, agent),
                           "now={now} agent={agent}");
            }
            now += 0.05; // repeated queries at equal now are fine too
            for agent in [3, 1] {
                assert_eq!(cursor.fails_at(now, agent),
                           f.fails_at(now, agent),
                           "now={now} agent={agent}");
            }
        }
    }

    #[test]
    fn tracker_cursor_preserves_overlapping_drop_order() {
        // Two overlapping drops: the old full-plan rescan multiplied
        // them in plan order; the cursor's active set must do the same
        // so the product is bit-identical.
        let cfg = FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 1.0, frac: 0.3, duration: 6.0 },
            FaultEvent::CapacityDrop { t: 3.0, frac: 0.6, duration: 2.0 },
        ]));
        let mut t = FaultTracker::new(Some(&cfg));
        assert_eq!(t.capacity_at(0, 1.0, 1.0, 2), 1.0);
        assert_eq!(t.capacity_at(1, 1.0, 1.0, 2), 1.0 * (1.0 - 0.3));
        assert_eq!(t.capacity_at(3, 1.0, 1.0, 2),
                   (1.0 - 0.3) * (1.0 - 0.6));
        assert_eq!(t.capacity_at(5, 1.0, 1.0, 2), 1.0 - 0.3);
        assert_eq!(t.capacity_at(7, 1.0, 1.0, 2), 1.0);
        let report = t.finish(1.0).unwrap();
        assert!((report.recovery_time_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn tracker_idle_until_brackets_the_fault_window() {
        let cfg = FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 4.0, frac: 0.5, duration: 2.0 },
        ]));
        let mut t = FaultTracker::new(Some(&cfg));
        // Quiet until the event's admission step.
        assert_eq!(t.idle_until(0, 1.0), Some(4));
        assert_eq!(t.idle_until(3, 1.0), Some(4));
        // Due now: not skippable.
        assert_eq!(t.idle_until(4, 1.0), None);
        let _ = t.capacity_at(4, 1.0, 1.0, 2);
        // Active event: not skippable.
        assert_eq!(t.idle_until(5, 1.0), None);
        // One dense step retires it, then quiet forever.
        let _ = t.capacity_at(6, 1.0, 1.0, 2);
        assert_eq!(t.idle_until(7, 1.0), Some(u64::MAX));
        // Inactive tracker: quiet forever.
        assert_eq!(FaultTracker::new(None).idle_until(0, 1.0),
                   Some(u64::MAX));
        // Fractional dt: the admission step matches capacity_at's own
        // comparison (first s with s·0.4 >= 4.0 is s = 10).
        let t2 = FaultTracker::new(Some(&cfg));
        assert_eq!(t2.idle_until(0, 0.4), Some(10));
    }

    #[test]
    fn first_step_conversion_agrees_with_active_at() {
        // The promise: for due = first_step_at_or_after(t, dt, from),
        // every step in [from, due) has step·dt < t, and due·dt >= t.
        for (t, dt, from) in [(4.0, 1.0, 0u64), (4.0, 0.4, 0), (0.3, 0.1, 0),
                              (10.0, 3.0, 1), (5.0, 1.0, 5), (5.0, 1.0, 7),
                              (1e-9, 1.0, 0), (7.7, 0.7, 2)] {
            let due = first_step_at_or_after(t, dt, from);
            assert!(due >= from, "t={t} dt={dt} from={from}");
            assert!((due as f64) * dt >= t || due == from,
                    "t={t} dt={dt} from={from} due={due}");
            for s in from..due.min(from + 10_000) {
                assert!((s as f64) * dt < t,
                        "skipped step {s} would admit (t={t} dt={dt})");
            }
        }
    }

    #[test]
    fn cluster_quiet_until_brackets_outages() {
        let cfg = FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 5.0, gpu: 1, duration: 10.0 },
        ]));
        let mut t = ClusterFaultTracker::new(Some(&cfg), 2, 42);
        let mut stalls = vec![0.0; 4];
        assert_eq!(t.quiet_until(0, 1.0), Some(5));
        assert_eq!(t.quiet_until(5, 1.0), None);
        t.advance(5.0, &mut stalls);
        // Offline window: not quiet.
        assert_eq!(t.quiet_until(6, 1.0), None);
        assert_eq!(t.quiet_until(14, 1.0), None);
        // Outage over, plan exhausted: quiet forever.
        assert_eq!(t.quiet_until(15, 1.0), Some(u64::MAX));
        assert_eq!(ClusterFaultTracker::new(None, 2, 1).quiet_until(0, 1.0),
                   Some(u64::MAX));
    }

    #[test]
    fn cluster_tracker_throttle_bounds_moves() {
        let cfg = FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 5.0, gpu: 1, duration: 10.0 },
        ]))
        .with_repack_throttle(0.5);
        let mut t = ClusterFaultTracker::new(Some(&cfg), 2, 42);
        assert_eq!(t.max_moves(4), 2);
        assert_eq!(t.max_moves(3), 1);
        let mut stalls = vec![0.0; 4];
        t.advance(5.0, &mut stalls);
        assert!(t.gpu_offline(1, 6.0));
        assert!(!t.gpu_offline(0, 6.0));
        assert!(t.any_offline(6.0));
        assert!(!t.any_offline(15.0));
        assert_eq!(t.effective_caps(&[1.0, 2.0], 6.0), &[1.0, 0.0]);
        assert_eq!(t.effective_caps(&[1.0, 2.0], 15.0), &[1.0, 2.0]);
        t.note_recovery(2, 4);
        let report = t.finish(10.0).expect("active tracker reports");
        assert_eq!(report.retried, 2);
        assert!((report.disruption - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cluster_tracker_rewarm_draws_only_when_configured() {
        let plan = FaultPlan::new(vec![FaultEvent::GpuEviction {
            t: 1.0, gpu: 0, duration: 2.0,
        }]);
        let dry = FaultConfig::new(plan.clone());
        let mut t = ClusterFaultTracker::new(Some(&dry), 2, 42);
        assert_eq!(t.rewarm_s(2000), 0.0);
        let wet = FaultConfig::new(plan)
            .with_rewarm(ColdStartModel::default_platform());
        let mut t = ClusterFaultTracker::new(Some(&wet), 2, 42);
        let s = t.rewarm_s(2000);
        assert!(s > 0.0, "rewarm should cost time, got {s}");
        // Same seed ⇒ same draw.
        let mut t2 = ClusterFaultTracker::new(Some(&wet), 2, 42);
        assert_eq!(t2.rewarm_s(2000), s);
    }
}
