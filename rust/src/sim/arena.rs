//! Reusable per-run simulation buffers.
//!
//! One [`SimArena`] owns every dense buffer the simulation loop touches:
//! queue depths, arrival rates/counts, observed rates, the allocation
//! vector, the per-step latency/throughput rows, and the model-size cache
//! for the serverless lifecycle. A single run's hot path was already
//! allocation-free; the arena extends that to the buffer *set* across
//! runs — a sweep worker constructs one arena and replays thousands of
//! scenarios through [`Simulator::run_with_arena`] without re-allocating
//! these buffers (they are `clear()`-ed and re-zeroed, capacity is
//! retained). Per-run output state (the `AgentStats` vector and the
//! workload generator) is still constructed per run, since it is moved
//! into the returned [`SimResult`].
//!
//! [`SimResult`]: crate::sim::SimResult
//!
//! [`Simulator::run_with_arena`]: crate::sim::Simulator::run_with_arena

/// Dense per-step buffers reused across simulation runs.
#[derive(Debug, Clone, Default)]
pub struct SimArena {
    pub(crate) queues: Vec<f64>,
    pub(crate) rates: Vec<f64>,
    pub(crate) counts: Vec<f64>,
    pub(crate) observed: Vec<f64>,
    pub(crate) alloc: Vec<f64>,
    pub(crate) lat_row: Vec<f64>,
    pub(crate) tput_row: Vec<f64>,
    pub(crate) model_mb: Vec<u32>,
}

impl SimArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        SimArena::default()
    }

    /// Arena pre-sized for `n` agents, so even the first run allocates
    /// nothing inside the engine.
    pub fn with_agents(n: usize) -> Self {
        SimArena {
            queues: Vec::with_capacity(n),
            rates: Vec::with_capacity(n),
            counts: Vec::with_capacity(n),
            observed: Vec::with_capacity(n),
            alloc: Vec::with_capacity(n),
            lat_row: Vec::with_capacity(n),
            tput_row: Vec::with_capacity(n),
            model_mb: Vec::with_capacity(n),
        }
    }

    /// Size every f64 buffer to `n` agents and zero it. Keeps capacity, so
    /// repeated runs over same-sized registries never reallocate.
    pub(crate) fn reset(&mut self, n: usize) {
        for buf in [
            &mut self.queues,
            &mut self.rates,
            &mut self.counts,
            &mut self.observed,
            &mut self.alloc,
            &mut self.lat_row,
            &mut self.tput_row,
        ] {
            buf.clear();
            buf.resize(n, 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_zeroes_and_sizes() {
        let mut a = SimArena::new();
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        a.queues[1] = 7.0;
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        // Shrinking and growing both land on the requested size.
        a.reset(1);
        assert_eq!(a.alloc.len(), 1);
        a.reset(5);
        assert_eq!(a.lat_row, vec![0.0; 5]);
    }

    #[test]
    fn reset_retains_capacity() {
        let mut a = SimArena::with_agents(8);
        a.reset(8);
        let cap = a.queues.capacity();
        a.reset(4);
        a.reset(8);
        assert!(a.queues.capacity() >= cap);
    }
}
