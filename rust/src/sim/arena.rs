//! Reusable per-run simulation buffers, struct-of-arrays throughout.
//!
//! One [`SimArena`] owns every dense buffer the simulation loop touches:
//! queue depths, arrival rates/counts, observed rates, the allocation
//! vector, the per-step latency/throughput rows, the model-size cache
//! for the serverless lifecycle, *and* the per-agent statistics
//! accumulators. A single run's hot path was already allocation-free;
//! the arena extends that to the buffer *set* across runs — a sweep
//! worker constructs one arena and replays thousands of scenarios
//! through [`Simulator::run_with_arena`] without re-allocating these
//! buffers (they are `clear()`-ed and re-zeroed, capacity is retained).
//!
//! The statistics live here as parallel `Vec<Streaming>` columns rather
//! than inside an array-of-structs `Vec<AgentStats>`: the dense inner
//! loop then updates same-kind accumulators at unit stride (each
//! [`Streaming`] is a flat 5-word record), and the skip-idle fast
//! path batch-accounts an idle window with one contiguous sweep per
//! column. The engine assembles the public per-agent
//! [`AgentStats`](crate::sim::AgentStats) rows from these columns once,
//! at the end of the run.
//!
//! [`Streaming`]: crate::metrics::Streaming
//!
//! [`Simulator::run_with_arena`]: crate::sim::Simulator::run_with_arena

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::metrics::Streaming;

/// Epoch-stamped active-set membership for the sparse stepping tier.
///
/// `stamp[i] == epoch` ⇔ agent `i` is active this run; settling writes
/// the `0` sentinel (epochs start at 1), and a run reset just bumps
/// `epoch`, instantly invalidating every stale stamp — membership state
/// is never cleared per tick or per run. The sorted `active` list is the
/// engines' iteration order (ascending agent index, so sparse folds
/// reproduce the dense folds' addition order with the settled agents'
/// `+0.0` terms elided), and the min-heap of `(wake_step, agent)` pairs
/// drives reactivation; stale heap entries (agent already woken by a
/// fault flush) are skipped on pop.
#[derive(Debug, Clone, Default)]
pub(crate) struct ActiveSet {
    pub(crate) epoch: u64,
    pub(crate) stamp: Vec<u64>,
    pub(crate) active: Vec<usize>,
    /// Step each settled agent's deferred zero-flush starts at.
    pub(crate) settled_at: Vec<u64>,
    pub(crate) wake: BinaryHeap<Reverse<(u64, usize)>>,
}

impl ActiveSet {
    /// Start a run over `n` agents with everyone active.
    pub(crate) fn reset(&mut self, n: usize) {
        self.epoch += 1;
        self.stamp.resize(n.max(self.stamp.len()), 0);
        self.settled_at.clear();
        self.settled_at.resize(n, 0);
        self.active.clear();
        self.active.extend(0..n);
        for s in self.stamp[..n].iter_mut() {
            *s = self.epoch;
        }
        self.wake.clear();
    }

    /// Is `agent` in the active set?
    pub(crate) fn is_active(&self, agent: usize) -> bool {
        self.stamp[agent] == self.epoch
    }

    /// Mark `agent` settled as of `now`, to be woken at `wake_at`
    /// (`u64::MAX` = never). The caller batch-removes settled agents
    /// from `active` afterwards (one `retain` per scan).
    pub(crate) fn settle(&mut self, agent: usize, now: u64, wake_at: u64) {
        self.stamp[agent] = 0;
        self.settled_at[agent] = now;
        if wake_at < u64::MAX {
            self.wake.push(Reverse((wake_at, agent)));
        }
    }

    /// Earliest pending wake step, ignoring stale entries.
    pub(crate) fn next_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((at, agent))) = self.wake.peek() {
            if self.is_active(agent) {
                self.wake.pop();
            } else {
                return Some(at);
            }
        }
        None
    }

    /// Move every agent whose wake step is `<= step` back into the
    /// active set, returning them (sorted ascending) in `woken`; the
    /// caller flushes their deferred zeros and merges them into
    /// `active`.
    pub(crate) fn drain_due(&mut self, step: u64, woken: &mut Vec<usize>) {
        woken.clear();
        while let Some(&Reverse((at, agent))) = self.wake.peek() {
            if at > step {
                break;
            }
            self.wake.pop();
            if !self.is_active(agent) {
                self.stamp[agent] = self.epoch;
                woken.push(agent);
            }
        }
        woken.sort_unstable();
    }
}

/// Dense per-step buffers reused across simulation runs.
#[derive(Debug, Clone, Default)]
pub struct SimArena {
    pub(crate) queues: Vec<f64>,
    pub(crate) rates: Vec<f64>,
    pub(crate) counts: Vec<f64>,
    pub(crate) observed: Vec<f64>,
    pub(crate) alloc: Vec<f64>,
    pub(crate) lat_row: Vec<f64>,
    pub(crate) tput_row: Vec<f64>,
    pub(crate) model_mb: Vec<u32>,
    // Struct-of-arrays statistics columns (one entry per agent).
    pub(crate) latency: Vec<Streaming>,
    pub(crate) throughput: Vec<Streaming>,
    pub(crate) queue_stat: Vec<Streaming>,
    pub(crate) allocation: Vec<Streaming>,
    pub(crate) utilization: Vec<Streaming>,
    pub(crate) processed_total: Vec<f64>,
    pub(crate) arrived_total: Vec<f64>,
    /// Active-set membership for the sparse stepping tier (unused — and
    /// untouched beyond reset — on the dense and skip-idle paths).
    pub(crate) active_set: ActiveSet,
    /// Scratch for [`ActiveSet::drain_due`] / merge operations.
    pub(crate) woken: Vec<usize>,
}

impl SimArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        SimArena::default()
    }

    /// Arena pre-sized for `n` agents, so even the first run allocates
    /// nothing inside the engine.
    pub fn with_agents(n: usize) -> Self {
        SimArena {
            queues: Vec::with_capacity(n),
            rates: Vec::with_capacity(n),
            counts: Vec::with_capacity(n),
            observed: Vec::with_capacity(n),
            alloc: Vec::with_capacity(n),
            lat_row: Vec::with_capacity(n),
            tput_row: Vec::with_capacity(n),
            model_mb: Vec::with_capacity(n),
            latency: Vec::with_capacity(n),
            throughput: Vec::with_capacity(n),
            queue_stat: Vec::with_capacity(n),
            allocation: Vec::with_capacity(n),
            utilization: Vec::with_capacity(n),
            processed_total: Vec::with_capacity(n),
            arrived_total: Vec::with_capacity(n),
            active_set: ActiveSet::default(),
            woken: Vec::new(),
        }
    }

    /// Size every buffer to `n` agents and zero it (statistics columns
    /// reset to empty accumulators). Keeps capacity, so repeated runs
    /// over same-sized registries never reallocate.
    pub(crate) fn reset(&mut self, n: usize) {
        for buf in [
            &mut self.queues,
            &mut self.rates,
            &mut self.counts,
            &mut self.observed,
            &mut self.alloc,
            &mut self.lat_row,
            &mut self.tput_row,
            &mut self.processed_total,
            &mut self.arrived_total,
        ] {
            buf.clear();
            buf.resize(n, 0.0);
        }
        for col in [
            &mut self.latency,
            &mut self.throughput,
            &mut self.queue_stat,
            &mut self.allocation,
            &mut self.utilization,
        ] {
            col.clear();
            col.resize(n, Streaming::new());
        }
        self.active_set.reset(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_zeroes_and_sizes() {
        let mut a = SimArena::new();
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        a.queues[1] = 7.0;
        a.latency[1].push(9.0);
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        assert_eq!(a.latency[1], Streaming::new());
        // Shrinking and growing both land on the requested size.
        a.reset(1);
        assert_eq!(a.alloc.len(), 1);
        a.reset(5);
        assert_eq!(a.lat_row, vec![0.0; 5]);
        assert_eq!(a.utilization.len(), 5);
        assert_eq!(a.processed_total, vec![0.0; 5]);
    }

    #[test]
    fn active_set_epoch_stamping() {
        let mut s = ActiveSet::default();
        s.reset(4);
        assert!(s.is_active(0) && s.is_active(3));
        assert_eq!(s.active, vec![0, 1, 2, 3]);
        // Settle two agents with different wakes.
        s.settle(1, 10, 50);
        s.settle(3, 12, u64::MAX);
        s.active.retain(|&i| s.stamp[i] == s.epoch);
        assert!(!s.is_active(1) && !s.is_active(3));
        assert_eq!(s.active, vec![0, 2]);
        assert_eq!(s.settled_at[1], 10);
        assert_eq!(s.next_wake(), Some(50));
        // Nothing due before step 50.
        let mut woken = Vec::new();
        s.drain_due(49, &mut woken);
        assert!(woken.is_empty());
        s.drain_due(50, &mut woken);
        assert_eq!(woken, vec![1]);
        assert!(s.is_active(1));
        // Never-wake agent stays settled; heap is empty.
        assert_eq!(s.next_wake(), None);
        // A reset invalidates every stale stamp without clearing.
        let old_epoch = s.epoch;
        s.reset(2);
        assert_eq!(s.epoch, old_epoch + 1);
        assert!(s.is_active(0) && s.is_active(1));
        assert_eq!(s.active, vec![0, 1]);
        assert_eq!(s.next_wake(), None);
    }

    #[test]
    fn active_set_drain_skips_already_active() {
        let mut s = ActiveSet::default();
        s.reset(3);
        s.settle(2, 5, 20);
        s.active.retain(|&i| s.stamp[i] == s.epoch);
        // A fault flush wakes everyone early, out of band.
        s.stamp[2] = s.epoch;
        s.active = vec![0, 1, 2];
        // The stale heap entry must not re-wake (or duplicate) agent 2.
        let mut woken = Vec::new();
        s.drain_due(25, &mut woken);
        assert!(woken.is_empty());
        assert_eq!(s.next_wake(), None);
    }

    #[test]
    fn reset_retains_capacity() {
        let mut a = SimArena::with_agents(8);
        a.reset(8);
        let cap = a.queues.capacity();
        let stat_cap = a.latency.capacity();
        a.reset(4);
        a.reset(8);
        assert!(a.queues.capacity() >= cap);
        assert!(a.latency.capacity() >= stat_cap);
    }
}
