//! Reusable per-run simulation buffers, struct-of-arrays throughout.
//!
//! One [`SimArena`] owns every dense buffer the simulation loop touches:
//! queue depths, arrival rates/counts, observed rates, the allocation
//! vector, the per-step latency/throughput rows, the model-size cache
//! for the serverless lifecycle, *and* the per-agent statistics
//! accumulators. A single run's hot path was already allocation-free;
//! the arena extends that to the buffer *set* across runs — a sweep
//! worker constructs one arena and replays thousands of scenarios
//! through [`Simulator::run_with_arena`] without re-allocating these
//! buffers (they are `clear()`-ed and re-zeroed, capacity is retained).
//!
//! The statistics live here as parallel `Vec<Streaming>` columns rather
//! than inside an array-of-structs `Vec<AgentStats>`: the dense inner
//! loop then updates same-kind accumulators at unit stride (each
//! [`Streaming`] is a flat 5-word record), and the skip-idle fast
//! path batch-accounts an idle window with one contiguous sweep per
//! column. The engine assembles the public per-agent
//! [`AgentStats`](crate::sim::AgentStats) rows from these columns once,
//! at the end of the run.
//!
//! [`Streaming`]: crate::metrics::Streaming
//!
//! [`Simulator::run_with_arena`]: crate::sim::Simulator::run_with_arena

use crate::metrics::Streaming;

/// Dense per-step buffers reused across simulation runs.
#[derive(Debug, Clone, Default)]
pub struct SimArena {
    pub(crate) queues: Vec<f64>,
    pub(crate) rates: Vec<f64>,
    pub(crate) counts: Vec<f64>,
    pub(crate) observed: Vec<f64>,
    pub(crate) alloc: Vec<f64>,
    pub(crate) lat_row: Vec<f64>,
    pub(crate) tput_row: Vec<f64>,
    pub(crate) model_mb: Vec<u32>,
    // Struct-of-arrays statistics columns (one entry per agent).
    pub(crate) latency: Vec<Streaming>,
    pub(crate) throughput: Vec<Streaming>,
    pub(crate) queue_stat: Vec<Streaming>,
    pub(crate) allocation: Vec<Streaming>,
    pub(crate) utilization: Vec<Streaming>,
    pub(crate) processed_total: Vec<f64>,
    pub(crate) arrived_total: Vec<f64>,
}

impl SimArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        SimArena::default()
    }

    /// Arena pre-sized for `n` agents, so even the first run allocates
    /// nothing inside the engine.
    pub fn with_agents(n: usize) -> Self {
        SimArena {
            queues: Vec::with_capacity(n),
            rates: Vec::with_capacity(n),
            counts: Vec::with_capacity(n),
            observed: Vec::with_capacity(n),
            alloc: Vec::with_capacity(n),
            lat_row: Vec::with_capacity(n),
            tput_row: Vec::with_capacity(n),
            model_mb: Vec::with_capacity(n),
            latency: Vec::with_capacity(n),
            throughput: Vec::with_capacity(n),
            queue_stat: Vec::with_capacity(n),
            allocation: Vec::with_capacity(n),
            utilization: Vec::with_capacity(n),
            processed_total: Vec::with_capacity(n),
            arrived_total: Vec::with_capacity(n),
        }
    }

    /// Size every buffer to `n` agents and zero it (statistics columns
    /// reset to empty accumulators). Keeps capacity, so repeated runs
    /// over same-sized registries never reallocate.
    pub(crate) fn reset(&mut self, n: usize) {
        for buf in [
            &mut self.queues,
            &mut self.rates,
            &mut self.counts,
            &mut self.observed,
            &mut self.alloc,
            &mut self.lat_row,
            &mut self.tput_row,
            &mut self.processed_total,
            &mut self.arrived_total,
        ] {
            buf.clear();
            buf.resize(n, 0.0);
        }
        for col in [
            &mut self.latency,
            &mut self.throughput,
            &mut self.queue_stat,
            &mut self.allocation,
            &mut self.utilization,
        ] {
            col.clear();
            col.resize(n, Streaming::new());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_zeroes_and_sizes() {
        let mut a = SimArena::new();
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        a.queues[1] = 7.0;
        a.latency[1].push(9.0);
        a.reset(3);
        assert_eq!(a.queues, vec![0.0; 3]);
        assert_eq!(a.latency[1], Streaming::new());
        // Shrinking and growing both land on the requested size.
        a.reset(1);
        assert_eq!(a.alloc.len(), 1);
        a.reset(5);
        assert_eq!(a.lat_row, vec![0.0; 5]);
        assert_eq!(a.utilization.len(), 5);
        assert_eq!(a.processed_total, vec![0.0; 5]);
    }

    #[test]
    fn reset_retains_capacity() {
        let mut a = SimArena::with_agents(8);
        a.reset(8);
        let cap = a.queues.capacity();
        let stat_cap = a.latency.capacity();
        a.reset(4);
        a.reset(8);
        assert!(a.queues.capacity() >= cap);
        assert!(a.latency.capacity() >= stat_cap);
    }
}
