//! The simulation loop (§IV.B methodology), with a skip-idle event core.
//!
//! The dense loop steps every timestep. The skip-idle core in front of it
//! fast-forwards windows that are *provably* idle — zero queues, a
//! workload shape that guarantees zero arrivals, no pending fault
//! transition, and policy/economics state that is a fixed point under
//! zero demand — by batch-accounting the window in O(agents) instead of
//! O(agents × steps). The skipped window is bit-exact with the dense
//! path by construction (asserted by the `skip_idle_*` tests against
//! [`Simulator::run_dense`]): every per-step quantity in such a window
//! is exactly `0.0`, pushing `0.0` into the power-sum
//! [`Streaming`](crate::metrics::Streaming) accumulators is the
//! identity on every float field, zero-rate Poisson steps consume no
//! RNG, and zero-allocation billing charges `+0.0`.

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::AllocationPolicy;
use crate::allocator::AllocContext;
use crate::metrics::TimeSeries;
use crate::serverless::EconInstruments;
use crate::sim::fault::FaultTracker;
use crate::sim::{AgentStats, SimArena, SimConfig, SimResult, Timelines};
use crate::workload::{WorkflowTracker, WorkflowWorkload,
                      WorkloadGenerator};

/// Arrival stream feeding [`Simulator`]'s inner loop: realized per-step
/// arrivals plus the skip-idle oracle.
trait ArrivalSource {
    /// Write this step's arrival counts and rates (counts / dt).
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]);

    /// Skip-idle oracle: `Some(until)` when every step in
    /// `[step, until)` is guaranteed to produce zero arrivals for every
    /// agent *and* producing them would not advance any internal state
    /// (RNG included); `u64::MAX` means "idle forever". `None` when this
    /// step may produce arrivals.
    fn idle_until(&mut self, step: u64) -> Option<u64>;
}

/// The configured [`WorkloadGenerator`] as an arrival source.
struct GeneratorSource(WorkloadGenerator);

impl ArrivalSource for GeneratorSource {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        self.0.step(step, dt, rates, counts);
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        // Zero-rate Poisson/deterministic steps consume no RNG state, so
        // the generator's schedule-level window is the whole answer.
        self.0.idle_until(step)
    }
}

/// A recorded [`Trace`](crate::workload::trace::Trace) as an arrival
/// source. The idle oracle scans forward for the next row with any
/// nonzero cell; the scan restarts where the previous window ended, so
/// replay stays O(rows × agents) overall.
struct TraceSource<'a> {
    rows: &'a [Vec<f64>],
}

impl ArrivalSource for TraceSource<'_> {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        let row = &self.rows[step as usize];
        counts.copy_from_slice(row);
        for (r, c) in rates.iter_mut().zip(row) {
            *r = c / dt;
        }
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        let mut s = step as usize;
        if s >= self.rows.len()
            || self.rows[s].iter().any(|c| *c != 0.0)
        {
            return None;
        }
        while s < self.rows.len()
            && self.rows[s].iter().all(|c| *c == 0.0)
        {
            s += 1;
        }
        if s >= self.rows.len() {
            Some(u64::MAX)
        } else {
            Some(s as u64)
        }
    }
}

/// Discrete-time simulator over one agent registry.
#[derive(Debug, Clone)]
pub struct Simulator {
    cfg: SimConfig,
    registry: AgentRegistry,
}

impl Simulator {
    /// Build from profiles (panics on invalid profiles — use
    /// [`Simulator::with_registry`] for fallible construction).
    pub fn new(cfg: SimConfig, agents: Vec<AgentProfile>) -> Self {
        let registry = AgentRegistry::new(agents).expect("valid agents");
        Simulator::with_registry(cfg, registry)
    }

    /// Build from an already-validated registry.
    pub fn with_registry(cfg: SimConfig, registry: AgentRegistry) -> Self {
        assert_eq!(cfg.arrival_rates.len(), registry.len(),
                   "arrival_rates must cover every agent");
        if let Some(wf) = &cfg.workflow {
            if let Err(e) = wf.spec.validate_for(registry.len()) {
                panic!("{e}");
            }
        }
        Simulator { cfg, registry }
    }

    /// The agent registry simulated over.
    pub fn registry(&self) -> &AgentRegistry {
        &self.registry
    }

    /// The configuration simulated under.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Run one policy over the configured workload.
    ///
    /// The policy is `reset()` first so instances can be reused across
    /// runs. The per-step hot path performs no heap allocation.
    /// Provably-idle windows are fast-forwarded by the skip-idle core —
    /// bit-exact with the dense path ([`Simulator::run_dense`] is the
    /// always-dense reference the property tests compare against).
    pub fn run<P>(&self, policy: &mut P) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_with_arena(policy, &mut SimArena::new())
    }

    /// [`Simulator::run`], but with caller-owned buffers: repeated runs
    /// (sweeps, batch workers) reuse the arena instead of re-allocating
    /// the per-step buffer set on every run.
    pub fn run_with_arena<P>(&self, policy: &mut P, arena: &mut SimArena)
                             -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_workload(policy, arena, true)
    }

    /// [`Simulator::run`] with the skip-idle core disabled: every step
    /// runs through the dense loop. This is the reference path the
    /// skip-idle bit-exactness properties (and the scaling bench's
    /// dense-vs-skip comparison) measure against; results are
    /// bit-identical to [`Simulator::run`] by construction.
    pub fn run_dense<P>(&self, policy: &mut P) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_dense_with_arena(policy, &mut SimArena::new())
    }

    /// [`Simulator::run_dense`] with caller-owned buffers.
    pub fn run_dense_with_arena<P>(&self, policy: &mut P,
                                   arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_workload(policy, arena, false)
    }

    fn run_workload<P>(&self, policy: &mut P, arena: &mut SimArena,
                       skip_idle: bool) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let mut source = GeneratorSource(WorkloadGenerator::new(
            self.cfg.arrival_rates.clone(), self.cfg.workload_kind.clone(),
            self.cfg.arrival_process, self.cfg.seed));
        self.run_inner(policy, &mut source, self.cfg.steps, self.cfg.dt,
                       arena, skip_idle, self.cfg.workflow.as_ref())
    }

    /// Run one policy over a recorded arrival [`Trace`] instead of the
    /// configured generator — bit-exact replay of a production (or
    /// previously recorded) workload. The trace's `dt` and length
    /// override the config's.
    ///
    /// Panics with the trace's labelled [`Error::Trace`] message when
    /// any row's width disagrees with the trace's agent count (a ragged
    /// trace built by hand; [`Trace::load`] and [`Trace::new`] already
    /// reject these at construction).
    ///
    /// [`Trace`]: crate::workload::trace::Trace
    /// [`Trace::load`]: crate::workload::trace::Trace::load
    /// [`Trace::new`]: crate::workload::trace::Trace::new
    /// [`Error::Trace`]: crate::error::Error::Trace
    pub fn run_trace<P>(&self, policy: &mut P,
                        trace: &crate::workload::trace::Trace) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_with_arena(policy, trace, &mut SimArena::new())
    }

    /// [`Simulator::run_trace`] with caller-owned buffers.
    pub fn run_trace_with_arena<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace,
        arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, arena, true)
    }

    /// [`Simulator::run_trace`] with the skip-idle core disabled — the
    /// dense reference for trace replay, bit-identical by construction.
    pub fn run_trace_dense<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace)
        -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, &mut SimArena::new(), false)
    }

    fn run_trace_inner<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace,
        arena: &mut SimArena, skip_idle: bool) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        assert_eq!(trace.agents.len(), self.registry.len(),
                   "trace agent count must match registry");
        if let Err(e) = trace.validate() {
            panic!("{e}");
        }
        let mut source = TraceSource { rows: &trace.counts };
        // Trace replay reproduces a recorded per-agent stream; the
        // workflow axis does not apply to it.
        self.run_inner(policy, &mut source, trace.counts.len() as u64,
                       trace.dt, arena, skip_idle, None)
    }

    fn run_inner<P>(&self, policy: &mut P, source: &mut dyn ArrivalSource,
                    steps: u64, dt: f64, arena: &mut SimArena,
                    skip_idle: bool, workflow: Option<&WorkflowWorkload>)
                    -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let n = self.registry.len();
        let cfg = &self.cfg;
        policy.reset();
        arena.reset(n);

        let names: Vec<String> = self.registry.profiles().iter()
            .map(|p| p.name.clone()).collect();
        let mut timelines = cfg.record_timelines.then(|| Timelines {
            allocation: TimeSeries::new(names.clone()),
            queue: TimeSeries::new(names.clone()),
            latency: TimeSeries::new(names.clone()),
            throughput: TimeSeries::new(names.clone()),
        });

        // Dense per-step buffers and struct-of-arrays statistics columns
        // — arena-owned, zero allocation in the loop and none on
        // repeated runs either.
        let SimArena {
            queues, rates, counts, observed, alloc, lat_row, tput_row,
            model_mb, latency: lat_col, throughput: tput_col,
            queue_stat: queue_col, allocation: alloc_col,
            utilization: util_col, processed_total, arrived_total,
        } = arena;
        let base_tput = self.registry.base_tput();

        // Optional serverless economics — billing (the model's pricing
        // replaces the config meter for the run), per-agent metering, and
        // the scale-to-zero lifecycle, all shared with the cluster engine
        // via EconInstruments. `None` branches per step when disabled —
        // zero overhead.
        model_mb.clear();
        model_mb.extend(self.registry.profiles().iter().map(|p| p.model_mb));
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);

        // Optional fault injection — same zero-cost-when-disabled shape
        // as EconInstruments: every hook returns its input untouched when
        // no fault can fire, so the disabled path is bit-exact.
        let mut fault = FaultTracker::new(cfg.faults.as_ref());
        let mut processed_sum = 0.0;

        // Optional workflow-DAG coupling: the tracker replaces the
        // arrival source outright — it releases multi-stage instances,
        // injects each stage's work as arrivals only once its upstream
        // stages complete, and meters end-to-end instance latency.
        let mut wf = workflow.map(|w| WorkflowTracker::new(
            w, cfg.arrival_process, cfg.seed, n));

        let mut step = 0u64;
        while step < steps {
            // 0. Skip-idle fast path: when the whole system is provably
            //    quiescent — empty queues, a workload window guaranteed
            //    to produce no arrivals, no fault transition due, and
            //    policy/economics state that zero demand leaves
            //    bit-identical — the dense loop would execute `k` steps
            //    in which every recorded quantity is exactly 0.0, no RNG
            //    is consumed, and billing charges +0.0. Batch-account
            //    the window instead. Utilization is untouched: the dense
            //    path records it only when capacity was allocated.
            if skip_idle
                && timelines.is_none()
                && queues.iter().all(|q| *q == 0.0)
                && policy.idle_fixed_point(n)
                && econ.idle_fixed_point()
            {
                let arrivals_idle = match wf.as_ref() {
                    // A drained workflow tracker stays drained: no rate,
                    // no armed stages, no in-flight work anywhere.
                    Some(t) => t.idle().then_some(u64::MAX),
                    None => source.idle_until(step),
                };
                if let (Some(w), Some(f)) =
                    (arrivals_idle, fault.idle_until(step, dt))
                {
                    let until = w.min(f).min(steps);
                    if until > step {
                        let k = until - step;
                        for s in lat_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in tput_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in queue_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in alloc_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        step = until;
                        continue;
                    }
                }
            }

            // 1. Arrivals join their agent's queue. With a workflow
            //    configured, the tracker is the arrival process: armed
            //    downstream stages plus this tick's newly released
            //    instances, instead of the per-agent streams.
            match wf.as_mut() {
                Some(t) => {
                    counts.fill(0.0);
                    t.begin_step(step, dt, &mut counts[..]);
                    for (r, c) in rates.iter_mut().zip(counts.iter()) {
                        *r = c / dt;
                    }
                }
                None => {
                    source.next(step, dt, &mut rates[..], &mut counts[..]);
                }
            }
            for i in 0..n {
                queues[i] += counts[i];
                arrived_total[i] += counts[i];
                // Policies observe the realized arrival *rate* (rps).
                observed[i] = counts[i] / dt;
            }

            // 2. The policy distributes GPU fractions. Under faults the
            //    policy sees the degraded capacity (evictions zero it,
            //    drops scale it) — that is how allocators get to adapt.
            let capacity = fault.capacity_at(step, dt, cfg.capacity, n);
            let ctx = AllocContext {
                registry: &self.registry,
                arrival_rates: &observed[..],
                queue_depths: &queues[..],
                step,
                capacity,
            };
            policy.allocate(&ctx, &mut alloc[..]);

            // 2a. Physical enforcement: whatever the policy asked for,
            //     the degraded device cannot serve more than the
            //     surviving capacity (floors/min-guarantees included).
            if fault.is_active() && capacity < cfg.capacity {
                let total: f64 = alloc.iter().sum();
                if total > capacity {
                    let s = if total > 0.0 { capacity / total } else { 0.0 };
                    for g in alloc.iter_mut() {
                        *g *= s;
                    }
                }
            }

            // 2b. Serverless lifecycle: cold agents cannot process this
            //     step (their allocation is forfeited, not billed), and
            //     demand triggers warm-up with a model-size-dependent
            //     cold-start delay.
            econ.apply_lifecycle(step, dt, &queues[..], &model_mb[..],
                                 &mut alloc[..]);

            // 3. Agents process proportionally to their allocation; record
            //    metrics on the post-processing queue (§IV.B ordering —
            //    this ordering is what Table II's closed forms assume).
            let mut total_alloc = 0.0;
            for i in 0..n {
                let g = alloc[i];
                total_alloc += g;
                // rps at this allocation, after any active stall divisor.
                let rate = fault.degrade_rate(step, dt, i, base_tput[i] * g);
                let cap = rate * dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                processed_sum += processed;
                if processed > 0.0 {
                    if let Some(t) = wf.as_mut() {
                        t.consume(i, processed, (step as f64 + 1.0) * dt);
                    }
                }

                let latency = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                let tput = processed / dt;

                lat_col[i].push(latency);
                tput_col[i].push(tput);
                queue_col[i].push(queues[i]);
                alloc_col[i].push(g);
                if cap > 0.0 {
                    util_col[i].push(processed / cap);
                }
                processed_total[i] += processed;
                lat_row[i] = latency;
                tput_row[i] = tput;
            }

            // 4. Billing: pay for what was allocated this step (alloc is
            //    post-lifecycle, so forfeited fractions are never billed
            //    — by either meter).
            econ.charge_step(total_alloc, &alloc[..], dt);

            if let Some(tl) = timelines.as_mut() {
                tl.allocation.push_row(&alloc[..]);
                tl.queue.push_row(&queues[..]);
                tl.latency.push_row(&lat_row[..]);
                tl.throughput.push_row(&tput_row[..]);
            }

            step += 1;
        }

        // Assemble the public array-of-structs rows from the arena's
        // struct-of-arrays columns (Streaming is Copy).
        let stats: Vec<AgentStats> = names.into_iter().enumerate()
            .map(|(i, name)| AgentStats {
                name,
                latency: lat_col[i],
                throughput: tput_col[i],
                queue: queue_col[i],
                allocation: alloc_col[i],
                utilization: util_col[i],
                processed_total: processed_total[i],
                arrived_total: arrived_total[i],
                final_queue: queues[i],
            })
            .collect();

        let (cost_dollars, gpu_seconds, economics) = econ.finish(steps);
        let resilience =
            fault.finish(processed_sum / (steps as f64 * dt).max(1e-9));

        SimResult {
            policy: policy.name().to_string(),
            steps,
            dt,
            per_agent: stats,
            cost_dollars,
            gpu_seconds,
            economics,
            resilience,
            workflow: wf.map(WorkflowTracker::finish),
            timelines,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::{AdaptivePolicy, RoundRobinPolicy,
                           StaticEqualPolicy};
    use crate::serverless::EconomicsModel;
    use crate::workload::WorkloadKind;

    fn paper_sim() -> Simulator {
        Simulator::new(SimConfig::paper(), AgentProfile::paper_agents())
    }

    /// Full bit-identity between two results: every Streaming
    /// accumulator field-for-field, every total, both optional reports.
    fn assert_bit_identical(a: &SimResult, b: &SimResult) {
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.per_agent.len(), b.per_agent.len());
        for (x, y) in a.per_agent.iter().zip(&b.per_agent) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.latency, y.latency, "latency {}", x.name);
            assert_eq!(x.throughput, y.throughput, "tput {}", x.name);
            assert_eq!(x.queue, y.queue, "queue {}", x.name);
            assert_eq!(x.allocation, y.allocation, "alloc {}", x.name);
            assert_eq!(x.utilization, y.utilization, "util {}", x.name);
            assert_eq!(x.processed_total, y.processed_total);
            assert_eq!(x.arrived_total, y.arrived_total);
            assert_eq!(x.final_queue, y.final_queue);
        }
        assert_eq!(a.cost_dollars, b.cost_dollars);
        assert_eq!(a.gpu_seconds, b.gpu_seconds);
        assert_eq!(a.economics, b.economics);
        assert_eq!(a.resilience, b.resilience);
        assert_eq!(a.workflow, b.workflow);
    }

    /// A workload whose only traffic is one agent's mid-run burst — the
    /// canonical shape where the skip-idle core actually fires (before
    /// the burst and after the backlog drains).
    fn burst_cfg() -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0, 40.0, 0.0, 0.0];
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1], start: 50, end: 70,
        };
        cfg
    }

    #[test]
    fn static_equal_reproduces_table2_row() {
        let r = paper_sim().run(&mut StaticEqualPolicy);
        // Paper: 110.3 s, 60.0 rps, $0.020.
        assert!((r.mean_latency() - 110.3).abs() < 0.5,
                "latency={}", r.mean_latency());
        assert!((r.total_throughput() - 60.0).abs() < 0.3,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6,
                "cost={}", r.cost_dollars);
    }

    #[test]
    fn adaptive_reproduces_table2_row() {
        let r = paper_sim().run(&mut AdaptivePolicy::default());
        // Paper: 111.9 s, 58.1 rps, $0.020.
        assert!((r.mean_latency() - 111.9).abs() < 0.6,
                "latency={}", r.mean_latency());
        assert!((r.total_throughput() - 58.1).abs() < 0.3,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6);
        // Per-agent: reasoning lowest (91.6 s), vision highest (128.6 s).
        let lat = r.agent_latencies();
        assert!((lat[3] - 91.7).abs() < 0.6, "reasoning={}", lat[3]);
        assert!((lat[2] - 128.6).abs() < 0.7, "vision={}", lat[2]);
        let min = lat.iter().cloned().fold(f64::MAX, f64::min);
        let max = lat.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(min, lat[3]);
        assert_eq!(max, lat[2]);
    }

    #[test]
    fn round_robin_reproduces_table2_row() {
        let r = paper_sim().run(&mut RoundRobinPolicy::default());
        // Paper: 756.1 s mean, std 0.5, 60.0 rps, $0.020.
        assert!((r.mean_latency() - 756.1).abs() < 2.0,
                "latency={}", r.mean_latency());
        assert!(r.latency_std() < 1.5, "std={}", r.latency_std());
        assert!((r.total_throughput() - 60.0).abs() < 0.5,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6);
    }

    #[test]
    fn headline_claim_85_percent_latency_reduction() {
        let sim = paper_sim();
        let adaptive = sim.run(&mut AdaptivePolicy::default());
        let rr = sim.run(&mut RoundRobinPolicy::default());
        let reduction = 1.0 - adaptive.mean_latency() / rr.mean_latency();
        assert!(reduction > 0.83 && reduction < 0.87,
                "reduction={reduction}");
    }

    #[test]
    fn conservation_holds_for_all_policies() {
        let sim = paper_sim();
        for mut p in crate::allocator::all_policies() {
            let r = sim.run(p.as_mut());
            assert!(r.conservation_error() < 1e-6,
                    "{}: {}", r.policy, r.conservation_error());
        }
    }

    #[test]
    fn arena_reuse_is_bit_identical_to_fresh_buffers() {
        // One arena shared across runs of different policies must leave
        // no state behind: every reused run matches its fresh-buffer twin
        // exactly.
        let sim = paper_sim();
        let mut arena = SimArena::new();
        for _ in 0..3 {
            for mut p in crate::allocator::all_policies() {
                let reused = sim.run_with_arena(p.as_mut(), &mut arena);
                let fresh = sim.run(p.as_mut());
                assert_eq!(reused.mean_latency(), fresh.mean_latency(),
                           "{}", reused.policy);
                assert_eq!(reused.total_throughput(),
                           fresh.total_throughput());
                assert_eq!(reused.cost_dollars, fresh.cost_dollars);
            }
        }
    }

    #[test]
    fn arena_adapts_to_registry_size_changes() {
        // The same arena must serve simulators of different agent counts.
        let mut arena = SimArena::with_agents(4);
        let four = paper_sim()
            .run_with_arena(&mut AdaptivePolicy::default(), &mut arena);
        assert_eq!(four.per_agent.len(), 4);

        let mut agents = AgentProfile::paper_agents();
        agents.truncate(2);
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates.truncate(2);
        let two = Simulator::new(cfg, agents)
            .run_with_arena(&mut AdaptivePolicy::default(), &mut arena);
        assert_eq!(two.per_agent.len(), 2);
        assert!(two.total_throughput() > 0.0);
    }

    #[test]
    fn timelines_recorded_when_requested() {
        let mut cfg = SimConfig::paper_poisson();
        cfg.record_timelines = true;
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let tl = r.timelines.expect("timelines");
        assert_eq!(tl.allocation.len(), 100);
        assert_eq!(tl.queue.len(), 100);
        // Allocation rows sum to <= capacity.
        for row in tl.allocation.rows() {
            let total: f64 = row.iter().sum();
            assert!(total <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn poisson_run_is_reproducible() {
        let cfg = SimConfig::paper_poisson();
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let a = sim.run(&mut AdaptivePolicy::default());
        let b = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(a.mean_latency(), b.mean_latency());
        assert_eq!(a.total_throughput(), b.total_throughput());
    }

    #[test]
    fn all_warm_economics_reproduces_table2_cost_row() {
        // Economics enabled with the paper's all-warm model must not
        // perturb Table II: the total stays $0.020 / 100 s and the
        // per-agent bills partition it exactly.
        let mut cfg = SimConfig::paper();
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let r = sim.run(p.as_mut());
            assert!((r.cost_dollars - 0.020).abs() < 1e-6, "{}", r.policy);
            let econ = r.economics.as_ref().expect("economics enabled");
            assert!((econ.total_cost() - r.cost_dollars).abs() < 1e-12,
                    "{}: per-agent bills must sum to the total", r.policy);
            assert_eq!(econ.cold_starts, vec![0; 4], "{}", r.policy);
            assert_eq!(econ.warm_fraction, vec![1.0; 4], "{}", r.policy);
        }
    }

    #[test]
    fn scale_to_zero_saves_money_on_idle_agents() {
        // Under static-equal, an idle agent still holds (and bills) 25%
        // of the GPU — unless scale-to-zero tears its instance down.
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![80.0, 0.0, 0.0, 0.0]; // only coordinator
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let warm_sim = Simulator::new(cfg.clone(),
                                      AgentProfile::paper_agents());
        let warm = warm_sim.run(&mut StaticEqualPolicy);

        cfg.economics = Some(EconomicsModel::with_idle_timeout(5.0));
        let s2z_sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let s2z = s2z_sim.run(&mut StaticEqualPolicy);

        assert!(s2z.cost_dollars < warm.cost_dollars * 0.5,
                "scale-to-zero {} vs always-warm {}",
                s2z.cost_dollars, warm.cost_dollars);
        // The busy agent is unaffected.
        assert!((s2z.per_agent[0].throughput.mean()
                 - warm.per_agent[0].throughput.mean()).abs() < 1e-9);
        // The report shows where the money went: the coordinator keeps
        // billing, the never-busy agents stop after the timeout.
        let econ = s2z.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.warm_fraction[0], 1.0);
        for i in 1..4 {
            assert!(econ.warm_fraction[i] < 0.1,
                    "agent {i} warm fraction {}", econ.warm_fraction[i]);
            assert!(econ.per_agent_cost[i] < warm.cost_dollars * 0.02,
                    "agent {i} still billing {}", econ.per_agent_cost[i]);
        }
        assert_eq!(econ.total_cold_starts(), 0, "nothing ever wakes");
    }

    #[test]
    fn cold_start_delays_processing_after_burst() {
        // NLP idles hard (zero arrivals), scales to zero, then a mid-run
        // burst arrives: its first post-burst steps process nothing while
        // the ~2.2 s cold start (2 GB checkpoint) completes, and the wake
        // is counted in the economics report.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1], start: 50, end: 100,
        };
        cfg.economics = Some(EconomicsModel::with_idle_timeout(3.0));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let nlp = &r.per_agent[1];
        assert!(nlp.processed_total > 0.0, "burst eventually served");
        assert!(nlp.processed_total < nlp.arrived_total,
                "cold start must cost some processing");
        let econ = r.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.cold_starts[1], 1, "one wake for the burst");
        assert!(econ.warm_fraction[1] < 1.0);
        // Always-busy agents never cold-start.
        assert_eq!(econ.cold_starts[0], 0);
        assert_eq!(econ.warm_fraction[0], 1.0);
    }

    #[test]
    fn eviction_outage_degrades_then_recovers() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 20.0, gpu: 0, duration: 10.0 },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let faulted = sim.run(&mut AdaptivePolicy::default());
        let clean = paper_sim().run(&mut AdaptivePolicy::default());
        let r = faulted.resilience.as_ref().expect("faults configured");
        assert!((r.recovery_time_s - 10.0).abs() < 1e-9,
                "outage window is 10 s, got {}", r.recovery_time_s);
        assert!(r.goodput < clean.total_throughput(),
                "outage must cost goodput: {} vs {}",
                r.goodput, clean.total_throughput());
        assert!(r.goodput > 0.0, "run recovers after the outage");
        // During the outage nothing processes; conservation still holds.
        assert!(faulted.conservation_error() < 1e-6);
        assert!(clean.resilience.is_none());
    }

    #[test]
    fn capacity_drop_degrades_proportionally() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 0.0, frac: 0.5, duration: 1e9 },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut StaticEqualPolicy);
        // Half capacity for the whole run: allocations are scaled to fit.
        for a in &r.per_agent {
            assert!(a.allocation.mean() <= 0.125 + 1e-9,
                    "{}: {}", a.name, a.allocation.mean());
        }
        let rep = r.resilience.expect("faults configured");
        assert!((rep.recovery_time_s - 100.0).abs() < 1e-9);
    }

    #[test]
    fn agent_stall_slows_only_the_stalled_agent() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::AgentStall {
                t: 0.0, agent: 1, factor: 4.0, duration: 1e9,
            },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let stalled = sim.run(&mut StaticEqualPolicy);
        let clean = paper_sim().run(&mut StaticEqualPolicy);
        let s = stalled.agent_throughputs();
        let c = clean.agent_throughputs();
        assert!(s[1] < c[1] * 0.5, "stalled agent slows: {} vs {}",
                s[1], c[1]);
        assert_eq!(s[0], c[0], "other agents are untouched");
        assert_eq!(s[2], c[2]);
        let rep = stalled.resilience.expect("faults configured");
        assert!((rep.disruption - 0.25).abs() < 1e-12,
                "1 of 4 agents stalled, got {}", rep.disruption);
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_faults() {
        use crate::sim::fault::{FaultConfig, FaultPlan};
        let mut cfg = SimConfig::paper_poisson();
        cfg.faults = Some(FaultConfig::new(FaultPlan::empty()));
        let gated = Simulator::new(cfg, AgentProfile::paper_agents());
        let plain = Simulator::new(SimConfig::paper_poisson(),
                                   AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let a = gated.run(p.as_mut());
            let b = plain.run(p.as_mut());
            assert_eq!(a.mean_latency(), b.mean_latency(), "{}", a.policy);
            assert_eq!(a.total_throughput(), b.total_throughput());
            assert_eq!(a.cost_dollars, b.cost_dollars);
            assert!(a.resilience.is_none(), "inert faults report nothing");
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_on_burst_windows() {
        use crate::workload::ArrivalProcess;
        // Deterministic and Poisson, every policy: the skipped run must
        // match the dense reference to the bit. Poisson works because
        // zero-rate steps consume no RNG state.
        for poisson in [false, true] {
            let mut cfg = burst_cfg();
            if poisson {
                cfg.arrival_process = ArrivalProcess::Poisson;
            }
            let sim = Simulator::new(cfg, AgentProfile::paper_agents());
            for mut p in crate::allocator::all_policies() {
                let skip = sim.run(p.as_mut());
                let dense = sim.run_dense(p.as_mut());
                assert_bit_identical(&skip, &dense);
            }
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_under_economics() {
        // Scale-to-zero lifecycle: the idle window is only skippable
        // once every instance has gone cold (warm idle instances accrue
        // teardown time densely), and the cold-start wake on the burst
        // must land on the same step with the same RNG draws.
        let mut cfg = burst_cfg();
        cfg.economics = Some(EconomicsModel::with_idle_timeout(3.0));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
        }
        // And the all-warm model, where the lifecycle never exists.
        let mut cfg = burst_cfg();
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let skip = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_bit_identical(&skip, &dense);
    }

    #[test]
    fn skip_idle_is_bit_exact_under_faults() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Faults scheduled inside, before, and after the idle windows:
        // the fault cursor must stop the skip exactly at each event's
        // first step and the resilience accounting must not drift.
        let mut cfg = burst_cfg();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 10.0, gpu: 0, duration: 5.0 },
            FaultEvent::CapacityDrop { t: 30.0, frac: 0.3, duration: 10.0 },
            FaultEvent::AgentStall {
                t: 55.0, agent: 1, factor: 3.0, duration: 5.0,
            },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
            assert!(skip.resilience.is_some());
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_on_all_zero_and_steady_workloads() {
        // All-zero: the entire run is one skipped window.
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0; 4];
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
        }
        // Steady paper workload: never idle, the skip never fires, and
        // Table II comes out of the same dense loop either way.
        let sim = paper_sim();
        let skip = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_bit_identical(&skip, &dense);
        assert!((skip.mean_latency() - 111.9).abs() < 0.6);
    }

    #[test]
    fn skip_idle_is_bit_exact_on_trace_replay() {
        use crate::workload::trace::Trace;
        let names = (0..4).map(|i| format!("a{i}")).collect::<Vec<_>>();
        let mut rows = vec![vec![0.0; 4]; 20];
        for i in 0..10 {
            rows.push(vec![5.0 + i as f64, 0.0, 2.0, 0.0]);
        }
        rows.extend(vec![vec![0.0; 4]; 30]);
        let trace = Trace::new(names, 1.0, rows).expect("rectangular");
        let sim = paper_sim();
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run_trace(p.as_mut(), &trace);
            let dense = sim.run_trace_dense(p.as_mut(), &trace);
            assert_bit_identical(&skip, &dense);
            assert_eq!(skip.steps, 60);
        }
    }

    #[test]
    #[should_panic(expected = "trace error")]
    fn run_trace_panics_on_ragged_rows() {
        use crate::workload::trace::Trace;
        // A hand-built ragged trace must be rejected up front with the
        // labelled trace error, not die on copy_from_slice mid-run.
        let trace = Trace {
            agents: (0..4).map(|i| format!("a{i}")).collect(),
            dt: 1.0,
            counts: vec![vec![0.0; 4], vec![1.0; 3], vec![0.0; 4]],
        };
        paper_sim().run_trace(&mut AdaptivePolicy::default(), &trace);
    }

    #[test]
    fn workflow_run_surfaces_end_to_end_stats() {
        use crate::workload::WorkflowWorkload;
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::paper());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let wf = r.workflow.as_ref().expect("workflow configured");
        assert!(wf.started > 0, "instances released");
        assert!(wf.completed > 0, "instances finish end to end");
        assert!(wf.completed <= wf.started);
        assert!(wf.mean_s() > 0.0, "fan-out takes at least 3 ticks");
        assert!(wf.p99_s() >= wf.mean_s() - 1e-9);
        // Plain runs carry no workflow report.
        assert!(paper_sim().run(&mut AdaptivePolicy::default())
                .workflow.is_none());
    }

    #[test]
    fn workflow_stages_wait_for_upstream_in_virtual_time() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        // A 2-stage chain 0 -> 1 at 1 instance/s: the specialist agent
        // must see zero throughput on the very first tick (its stage is
        // not yet eligible) and nonzero on the next.
        let spec = WorkflowSpec::chain("chain2", &[0, 1]);
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(spec, 1.0));
        cfg.record_timelines = true;
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let tl = r.timelines.expect("timelines");
        let t0 = tl.throughput.rows().next().expect("step 0");
        assert!(t0[0] > 0.0, "stage 0 processes on arrival");
        assert_eq!(t0[1], 0.0, "stage 1 cannot start before stage 0");
        let t1 = tl.throughput.rows().nth(1).expect("step 1");
        assert!(t1[1] > 0.0, "stage 1 armed the tick after");
        // Agents off the DAG never see traffic.
        assert_eq!(r.per_agent[2].arrived_total, 0.0);
        assert_eq!(r.per_agent[3].arrived_total, 0.0);
    }

    #[test]
    fn skip_idle_is_bit_exact_on_workflow_runs() {
        use crate::workload::{ArrivalProcess, WorkflowWorkload};
        for poisson in [false, true] {
            let mut cfg = SimConfig::paper();
            if poisson {
                cfg.arrival_process = ArrivalProcess::Poisson;
            }
            cfg.workflow = Some(WorkflowWorkload::paper());
            let sim = Simulator::new(cfg, AgentProfile::paper_agents());
            for mut p in crate::allocator::all_policies() {
                let skip = sim.run(p.as_mut());
                let dense = sim.run_dense(p.as_mut());
                assert_bit_identical(&skip, &dense);
                assert!(skip.workflow.is_some());
            }
        }
    }

    #[test]
    #[should_panic(expected = "config error")]
    fn workflow_spec_must_fit_the_registry() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        let spec = WorkflowSpec::chain("wide", &[0, 9]);
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(spec, 1.0));
        Simulator::new(cfg, AgentProfile::paper_agents());
    }

    #[test]
    fn idle_workload_costs_nothing_under_adaptive() {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0; 4];
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(r.cost_dollars, 0.0);
        assert_eq!(r.mean_latency(), 0.0);
        assert_eq!(r.total_throughput(), 0.0);
    }
}
