//! The simulation loop (§IV.B methodology), with a three-tier event
//! core: dense, skip-idle, and active-set (see `sim::mod` for the
//! tier diagram and the per-agent oracle contract).
//!
//! The dense loop steps every agent every timestep. The skip-idle core
//! in front of it fast-forwards windows in which the *whole system* is
//! provably idle — zero queues, a workload shape that guarantees zero
//! arrivals, no pending fault transition, and policy/economics state
//! that is a fixed point under zero demand — by batch-accounting the
//! window in O(agents) instead of O(agents × steps). The active-set
//! tier refines that per agent: inside busy ticks it iterates only the
//! agents whose state can still change (nonzero queue, arrival due per
//! [`WorkloadGenerator::agent_idle_until`], pending fault transition,
//! or an allocation not at its per-agent fixed point per
//! [`AllocationPolicy::zero_fixed_point`]), while settled agents get
//! one deferred O(1) zero-flush when they wake or the run ends. Both
//! fast tiers are bit-exact with the dense path by construction
//! (asserted by the `skip_idle_*`/`active_set_*` tests against
//! [`Simulator::run_dense`]): every per-step quantity of a skipped
//! window or settled agent is exactly `0.0`, pushing `0.0` into the
//! power-sum [`Streaming`](crate::metrics::Streaming) accumulators is
//! the identity on every float field, zero-rate Poisson steps consume
//! no RNG, ascending-index folds are unchanged by eliding `+0.0`
//! terms, and zero-allocation billing charges `+0.0`.

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::AllocationPolicy;
use crate::allocator::AllocContext;
use crate::metrics::TimeSeries;
use crate::serverless::EconInstruments;
use crate::sim::fault::FaultTracker;
use crate::sim::{AgentStats, SimArena, SimConfig, SimResult, Timelines};
use crate::workload::{TraceSource, WorkflowTracker, WorkflowWorkload,
                      WorkloadGenerator};

/// Arrival stream feeding [`Simulator`]'s inner loop: realized per-step
/// arrivals plus the skip-idle oracle.
trait ArrivalSource {
    /// Write this step's arrival counts and rates (counts / dt).
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]);

    /// Skip-idle oracle: `Some(until)` when every step in
    /// `[step, until)` is guaranteed to produce zero arrivals for every
    /// agent *and* producing them would not advance any internal state
    /// (RNG included); `u64::MAX` means "idle forever". `None` when this
    /// step may produce arrivals.
    fn idle_until(&mut self, step: u64) -> Option<u64>;
}

/// The configured [`WorkloadGenerator`] as an arrival source.
struct GeneratorSource(WorkloadGenerator);

impl ArrivalSource for GeneratorSource {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        self.0.step(step, dt, rates, counts);
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        // Zero-rate Poisson/deterministic steps consume no RNG state, so
        // the generator's schedule-level window is the whole answer.
        self.0.idle_until(step)
    }
}

/// Any recorded replay source — the in-memory CSV
/// [`Trace`](crate::workload::trace::Trace) or the zero-copy binary
/// [`BinTrace`](crate::workload::BinTrace) — adapted to the inner
/// loop's [`ArrivalSource`] through the public [`TraceSource`] trait.
/// Burst microstructure collapses by summation in
/// [`TraceSource::fill_row`], so the fluid engine replays burst
/// recordings bit-exactly like their dense per-step totals.
struct SourceAdapter<'a> {
    src: &'a dyn TraceSource,
}

impl ArrivalSource for SourceAdapter<'_> {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        self.src.fill_row(step, counts);
        for (r, c) in rates.iter_mut().zip(counts.iter()) {
            *r = c / dt;
        }
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        // Recorded data: replaying an idle window consumes no state,
        // so the source's forward scan is the whole answer.
        self.src.idle_until(step)
    }
}

/// Which tier of the event core a run steps through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StepMode {
    /// Every agent, every step — the reference loop.
    Dense,
    /// Dense loop plus whole-system idle-window fast-forwarding.
    SkipIdle,
    /// Per-agent sparse stepping inside busy ticks (falls back to
    /// skip-idle when the run is not active-set eligible).
    ActiveSet,
}

/// Discrete-time simulator over one agent registry.
#[derive(Debug, Clone)]
pub struct Simulator {
    cfg: SimConfig,
    registry: AgentRegistry,
}

impl Simulator {
    /// Build from profiles (panics on invalid profiles — use
    /// [`Simulator::with_registry`] for fallible construction).
    pub fn new(cfg: SimConfig, agents: Vec<AgentProfile>) -> Self {
        let registry = AgentRegistry::new(agents).expect("valid agents");
        Simulator::with_registry(cfg, registry)
    }

    /// Build from an already-validated registry.
    pub fn with_registry(cfg: SimConfig, registry: AgentRegistry) -> Self {
        assert_eq!(cfg.arrival_rates.len(), registry.len(),
                   "arrival_rates must cover every agent");
        if let Some(wf) = &cfg.workflow {
            if let Err(e) = wf.spec.validate_for(registry.len()) {
                panic!("{e}");
            }
        }
        Simulator { cfg, registry }
    }

    /// The agent registry simulated over.
    pub fn registry(&self) -> &AgentRegistry {
        &self.registry
    }

    /// The configuration simulated under.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Run one policy over the configured workload.
    ///
    /// The policy is `reset()` first so instances can be reused across
    /// runs. The per-step hot path performs no heap allocation. Runs
    /// step through the active-set tier of the event core when eligible
    /// (no workflow, no timelines, no economics, and a policy whose
    /// all-idle state is a fixed point — see
    /// [`Simulator::run_skip_idle`] for the fallback tier), so busy
    /// ticks iterate only the agents whose state can still change and
    /// provably-idle windows are fast-forwarded wholesale. Every tier
    /// is bit-exact with the dense reference ([`Simulator::run_dense`]
    /// is what the property tests compare against).
    pub fn run<P>(&self, policy: &mut P) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_with_arena(policy, &mut SimArena::new())
    }

    /// [`Simulator::run`], but with caller-owned buffers: repeated runs
    /// (sweeps, batch workers) reuse the arena instead of re-allocating
    /// the per-step buffer set on every run.
    pub fn run_with_arena<P>(&self, policy: &mut P, arena: &mut SimArena)
                             -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_workload(policy, arena, StepMode::ActiveSet)
    }

    /// [`Simulator::run`] pinned to the skip-idle tier: the dense loop
    /// plus whole-system idle-window fast-forwarding, without per-agent
    /// sparse stepping. This is the middle rung the scaling bench's
    /// three-way comparison measures, and the tier ineligible runs
    /// (workflow, timelines, economics, globally-coupled policies) fall
    /// back to; results are bit-identical to both [`Simulator::run`]
    /// and [`Simulator::run_dense`] by construction.
    pub fn run_skip_idle<P>(&self, policy: &mut P) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_skip_idle_with_arena(policy, &mut SimArena::new())
    }

    /// [`Simulator::run_skip_idle`] with caller-owned buffers.
    pub fn run_skip_idle_with_arena<P>(&self, policy: &mut P,
                                       arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_workload(policy, arena, StepMode::SkipIdle)
    }

    /// [`Simulator::run`] with both fast tiers disabled: every step
    /// runs through the dense loop. This is the reference path the
    /// skip-idle and active-set bit-exactness properties (and the
    /// scaling bench's dense-vs-sparse comparison) measure against;
    /// results are bit-identical to [`Simulator::run`] by construction.
    pub fn run_dense<P>(&self, policy: &mut P) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_dense_with_arena(policy, &mut SimArena::new())
    }

    /// [`Simulator::run_dense`] with caller-owned buffers.
    pub fn run_dense_with_arena<P>(&self, policy: &mut P,
                                   arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_workload(policy, arena, StepMode::Dense)
    }

    fn run_workload<P>(&self, policy: &mut P, arena: &mut SimArena,
                       mode: StepMode) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let mut gen = WorkloadGenerator::new(
            self.cfg.arrival_rates.clone(), self.cfg.workload_kind.clone(),
            self.cfg.arrival_process, self.cfg.seed);
        if mode == StepMode::ActiveSet {
            // Eligibility for per-agent sparse stepping. Workflow runs
            // couple agents through the DAG, timelines need a dense row
            // per step, and the economics lifecycle/meter walk every
            // agent per step (warm idle instances accrue teardown time)
            // — all three get the skip-idle fallback, as do policies
            // whose allocation is globally coupled (round-robin rotates
            // every window, static-equal always bills floors; their
            // `idle_fixed_point` is false). `reset()` first so the
            // check sees this run's state, not a previous run's
            // (predictive's seeded-EMA gate; reset is idempotent and
            // the inner loops reset again).
            policy.reset();
            if self.cfg.workflow.is_none()
                && !self.cfg.record_timelines
                && self.cfg.economics.is_none()
                && policy.idle_fixed_point(self.registry.len())
            {
                return self.run_active_inner(policy, &mut gen,
                                             self.cfg.steps, self.cfg.dt,
                                             arena);
            }
        }
        let mut source = GeneratorSource(gen);
        self.run_inner(policy, &mut source, self.cfg.steps, self.cfg.dt,
                       arena, mode != StepMode::Dense,
                       self.cfg.workflow.as_ref())
    }

    /// Run one policy over a recorded arrival [`Trace`] instead of the
    /// configured generator — bit-exact replay of a production (or
    /// previously recorded) workload. The trace's `dt` and length
    /// override the config's.
    ///
    /// Panics with the trace's labelled [`Error::Trace`] message when
    /// any row's width disagrees with the trace's agent count (a ragged
    /// trace built by hand; [`Trace::load`] and [`Trace::new`] already
    /// reject these at construction).
    ///
    /// [`Trace`]: crate::workload::trace::Trace
    /// [`Trace::load`]: crate::workload::trace::Trace::load
    /// [`Trace::new`]: crate::workload::trace::Trace::new
    /// [`Error::Trace`]: crate::error::Error::Trace
    pub fn run_trace<P>(&self, policy: &mut P,
                        trace: &crate::workload::trace::Trace) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_with_arena(policy, trace, &mut SimArena::new())
    }

    /// [`Simulator::run_trace`] with caller-owned buffers.
    pub fn run_trace_with_arena<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace,
        arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, arena, true)
    }

    /// [`Simulator::run_trace`] with the skip-idle core disabled — the
    /// dense reference for trace replay, bit-identical by construction.
    pub fn run_trace_dense<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace)
        -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, &mut SimArena::new(), false)
    }

    fn run_trace_inner<P>(
        &self, policy: &mut P, trace: &crate::workload::trace::Trace,
        arena: &mut SimArena, skip_idle: bool) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        if let Err(e) = trace.validate() {
            panic!("{e}");
        }
        self.run_source_inner(policy, trace, arena, skip_idle)
    }

    /// Run one policy over any recorded replay source — the in-memory
    /// CSV [`Trace`] or the zero-copy binary
    /// [`BinTrace`](crate::workload::BinTrace) — through the same inner
    /// loop as [`Simulator::run_trace`]. Burst-encoded steps collapse
    /// by summation, so a burst recording replays bit-exactly like a
    /// dense trace of its per-step totals. The source's `dt` and length
    /// override the config's.
    ///
    /// [`Trace`]: crate::workload::trace::Trace
    pub fn run_source<P>(&self, policy: &mut P,
                         source: &dyn TraceSource) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_inner(policy, source, &mut SimArena::new(),
                              true)
    }

    /// [`Simulator::run_source`] with caller-owned buffers.
    pub fn run_source_with_arena<P>(
        &self, policy: &mut P, source: &dyn TraceSource,
        arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_inner(policy, source, arena, true)
    }

    /// [`Simulator::run_source`] with the skip-idle core disabled —
    /// the dense reference for source replay, bit-identical by
    /// construction.
    pub fn run_source_dense<P>(&self, policy: &mut P,
                               source: &dyn TraceSource) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_inner(policy, source, &mut SimArena::new(),
                              false)
    }

    fn run_source_inner<P>(
        &self, policy: &mut P, source: &dyn TraceSource,
        arena: &mut SimArena, skip_idle: bool) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        assert_eq!(source.agent_names().len(), self.registry.len(),
                   "trace agent count must match registry");
        let mut adapter = SourceAdapter { src: source };
        // Trace replay reproduces a recorded per-agent stream; the
        // workflow axis does not apply to it.
        self.run_inner(policy, &mut adapter, source.steps(),
                       source.dt(), arena, skip_idle, None)
    }

    fn run_inner<P>(&self, policy: &mut P, source: &mut dyn ArrivalSource,
                    steps: u64, dt: f64, arena: &mut SimArena,
                    skip_idle: bool, workflow: Option<&WorkflowWorkload>)
                    -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let n = self.registry.len();
        let cfg = &self.cfg;
        policy.reset();
        arena.reset(n);

        let names: Vec<String> = self.registry.profiles().iter()
            .map(|p| p.name.clone()).collect();
        let mut timelines = cfg.record_timelines.then(|| Timelines {
            allocation: TimeSeries::new(names.clone()),
            queue: TimeSeries::new(names.clone()),
            latency: TimeSeries::new(names.clone()),
            throughput: TimeSeries::new(names.clone()),
        });

        // Dense per-step buffers and struct-of-arrays statistics columns
        // — arena-owned, zero allocation in the loop and none on
        // repeated runs either.
        let SimArena {
            queues, rates, counts, observed, alloc, lat_row, tput_row,
            model_mb, latency: lat_col, throughput: tput_col,
            queue_stat: queue_col, allocation: alloc_col,
            utilization: util_col, processed_total, arrived_total, ..
        } = arena;
        let base_tput = self.registry.base_tput();

        // Optional serverless economics — billing (the model's pricing
        // replaces the config meter for the run), per-agent metering, and
        // the scale-to-zero lifecycle, all shared with the cluster engine
        // via EconInstruments. `None` branches per step when disabled —
        // zero overhead.
        model_mb.clear();
        model_mb.extend(self.registry.profiles().iter().map(|p| p.model_mb));
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);

        // Optional fault injection — same zero-cost-when-disabled shape
        // as EconInstruments: every hook returns its input untouched when
        // no fault can fire, so the disabled path is bit-exact.
        let mut fault = FaultTracker::new(cfg.faults.as_ref());
        let mut processed_sum = 0.0;

        // Optional workflow-DAG coupling: the tracker replaces the
        // arrival source outright — it releases multi-stage instances,
        // injects each stage's work as arrivals only once its upstream
        // stages complete, and meters end-to-end instance latency.
        let mut wf = workflow.map(|w| WorkflowTracker::new(
            w, cfg.arrival_process, cfg.seed, n));

        let mut step = 0u64;
        while step < steps {
            // 0. Skip-idle fast path: when the whole system is provably
            //    quiescent — empty queues, a workload window guaranteed
            //    to produce no arrivals, no fault transition due, and
            //    policy/economics state that zero demand leaves
            //    bit-identical — the dense loop would execute `k` steps
            //    in which every recorded quantity is exactly 0.0, no RNG
            //    is consumed, and billing charges +0.0. Batch-account
            //    the window instead. Utilization is untouched: the dense
            //    path records it only when capacity was allocated.
            if skip_idle
                && timelines.is_none()
                && queues.iter().all(|q| *q == 0.0)
                && policy.idle_fixed_point(n)
                && econ.idle_fixed_point()
            {
                let arrivals_idle = match wf.as_ref() {
                    // A drained workflow tracker stays drained: no rate,
                    // no armed stages, no in-flight work anywhere.
                    Some(t) => t.idle().then_some(u64::MAX),
                    None => source.idle_until(step),
                };
                if let (Some(w), Some(f)) =
                    (arrivals_idle, fault.idle_until(step, dt))
                {
                    let until = w.min(f).min(steps);
                    if until > step {
                        let k = until - step;
                        for s in lat_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in tput_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in queue_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in alloc_col.iter_mut() {
                            s.push_zeros(k);
                        }
                        step = until;
                        continue;
                    }
                }
            }

            // 1. Arrivals join their agent's queue. With a workflow
            //    configured, the tracker is the arrival process: armed
            //    downstream stages plus this tick's newly released
            //    instances, instead of the per-agent streams.
            match wf.as_mut() {
                Some(t) => {
                    counts.fill(0.0);
                    t.begin_step(step, dt, &mut counts[..]);
                    for (r, c) in rates.iter_mut().zip(counts.iter()) {
                        *r = c / dt;
                    }
                }
                None => {
                    source.next(step, dt, &mut rates[..], &mut counts[..]);
                }
            }
            for i in 0..n {
                queues[i] += counts[i];
                arrived_total[i] += counts[i];
                // Policies observe the realized arrival *rate* (rps).
                observed[i] = counts[i] / dt;
            }

            // 2. The policy distributes GPU fractions. Under faults the
            //    policy sees the degraded capacity (evictions zero it,
            //    drops scale it) — that is how allocators get to adapt.
            let capacity = fault.capacity_at(step, dt, cfg.capacity, n);
            let ctx = AllocContext {
                registry: &self.registry,
                arrival_rates: &observed[..],
                queue_depths: &queues[..],
                step,
                capacity,
            };
            policy.allocate(&ctx, &mut alloc[..]);

            // 2a. Physical enforcement: whatever the policy asked for,
            //     the degraded device cannot serve more than the
            //     surviving capacity (floors/min-guarantees included).
            if fault.is_active() && capacity < cfg.capacity {
                let total: f64 = alloc.iter().sum();
                if total > capacity {
                    let s = if total > 0.0 { capacity / total } else { 0.0 };
                    for g in alloc.iter_mut() {
                        *g *= s;
                    }
                }
            }

            // 2b. Serverless lifecycle: cold agents cannot process this
            //     step (their allocation is forfeited, not billed), and
            //     demand triggers warm-up with a model-size-dependent
            //     cold-start delay.
            econ.apply_lifecycle(step, dt, &queues[..], &model_mb[..],
                                 &mut alloc[..]);

            // 3. Agents process proportionally to their allocation; record
            //    metrics on the post-processing queue (§IV.B ordering —
            //    this ordering is what Table II's closed forms assume).
            let mut total_alloc = 0.0;
            for i in 0..n {
                let g = alloc[i];
                total_alloc += g;
                // rps at this allocation, after any active stall divisor.
                let rate = fault.degrade_rate(step, dt, i, base_tput[i] * g);
                let cap = rate * dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                processed_sum += processed;
                if processed > 0.0 {
                    if let Some(t) = wf.as_mut() {
                        t.consume(i, processed, (step as f64 + 1.0) * dt);
                    }
                }

                let latency = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                let tput = processed / dt;

                lat_col[i].push(latency);
                tput_col[i].push(tput);
                queue_col[i].push(queues[i]);
                alloc_col[i].push(g);
                if cap > 0.0 {
                    util_col[i].push(processed / cap);
                }
                processed_total[i] += processed;
                lat_row[i] = latency;
                tput_row[i] = tput;
            }

            // 4. Billing: pay for what was allocated this step (alloc is
            //    post-lifecycle, so forfeited fractions are never billed
            //    — by either meter).
            econ.charge_step(total_alloc, &alloc[..], dt);

            if let Some(tl) = timelines.as_mut() {
                tl.allocation.push_row(&alloc[..]);
                tl.queue.push_row(&queues[..]);
                tl.latency.push_row(&lat_row[..]);
                tl.throughput.push_row(&tput_row[..]);
            }

            step += 1;
        }

        // Assemble the public array-of-structs rows from the arena's
        // struct-of-arrays columns (Streaming is Copy).
        let stats: Vec<AgentStats> = names.into_iter().enumerate()
            .map(|(i, name)| AgentStats {
                name,
                latency: lat_col[i],
                throughput: tput_col[i],
                queue: queue_col[i],
                allocation: alloc_col[i],
                utilization: util_col[i],
                processed_total: processed_total[i],
                arrived_total: arrived_total[i],
                final_queue: queues[i],
            })
            .collect();

        let (cost_dollars, gpu_seconds, economics) = econ.finish(steps);
        let resilience =
            fault.finish(processed_sum / (steps as f64 * dt).max(1e-9));

        SimResult {
            policy: policy.name().to_string(),
            steps,
            dt,
            per_agent: stats,
            cost_dollars,
            gpu_seconds,
            economics,
            resilience,
            workflow: wf.map(WorkflowTracker::finish),
            timelines,
        }
    }

    /// The active-set tier: per-agent sparse stepping inside busy ticks.
    ///
    /// Live steps iterate only the sorted active list. An active agent
    /// *settles* (leaves the list) at the end of a fault-quiet step when
    /// its realized state is exactly zero (`queue == alloc == observed
    /// == 0.0`), the policy vouches that zero is its per-agent fixed
    /// point ([`AllocationPolicy::zero_fixed_point`]), and the workload
    /// oracle ([`WorkloadGenerator::agent_idle_until`]) promises it zero
    /// arrivals until a known wake step. A settled agent's dense steps
    /// would each record exactly `0.0` on the latency / throughput /
    /// queue / allocation columns (utilization is untouched — the dense
    /// path records it only when capacity was allocated) and contribute
    /// `+0.0` to every ascending fold, so the whole settled span is
    /// batch-accounted with one deferred `push_zeros` flush when the
    /// agent wakes (arrival due, fault window, or end of run).
    ///
    /// Fault windows step densely: the moment the fault oracle stops
    /// promising quiet, every settled agent is flushed and woken, and
    /// the step runs with the full fault hooks over all agents —
    /// `capacity_at`'s event cursor then sees every step it must, and
    /// stall/eviction accounting never misses a settled agent. During
    /// quiet windows the same oracle licenses skipping those hooks
    /// entirely (`capacity_at` would return base capacity untouched and
    /// `degrade_rate` is the identity). The whole-idle jump from the
    /// skip-idle tier is retained inside this loop, so runs that are
    /// globally idle stay O(1) per skipped window rather than O(active).
    ///
    /// Caller (`run_workload`) guarantees: no workflow, no timelines,
    /// no economics, and `policy.idle_fixed_point(n)`.
    fn run_active_inner<P>(&self, policy: &mut P,
                           gen: &mut WorkloadGenerator, steps: u64,
                           dt: f64, arena: &mut SimArena) -> SimResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let n = self.registry.len();
        let cfg = &self.cfg;
        debug_assert!(cfg.workflow.is_none() && !cfg.record_timelines
                      && cfg.economics.is_none());
        policy.reset();
        arena.reset(n);

        let names: Vec<String> = self.registry.profiles().iter()
            .map(|p| p.name.clone()).collect();

        let SimArena {
            queues, rates, counts, observed, alloc, lat_row, tput_row,
            latency: lat_col, throughput: tput_col,
            queue_stat: queue_col, allocation: alloc_col,
            utilization: util_col, processed_total, arrived_total,
            active_set, woken, ..
        } = arena;
        let base_tput = self.registry.base_tput();

        // Economics is None by eligibility: billing only (O(1)/step,
        // never reads the allocation slice), no meter, no lifecycle.
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);
        let mut fault = FaultTracker::new(cfg.faults.as_ref());
        let mut processed_sum = 0.0;

        let mut step = 0u64;
        while step < steps {
            // 0. Reactivate agents whose scheduled wake is due, flushing
            //    the zeros their settled span deferred.
            active_set.drain_due(step, woken);
            if !woken.is_empty() {
                for &i in woken.iter() {
                    let k = step - active_set.settled_at[i];
                    lat_col[i].push_zeros(k);
                    tput_col[i].push_zeros(k);
                    queue_col[i].push_zeros(k);
                    alloc_col[i].push_zeros(k);
                }
                active_set.active.extend_from_slice(woken);
                active_set.active.sort_unstable();
            }

            // 1. Fault gate. `Some(f)` (with f > step) licenses running
            //    this step without the fault hooks; `None` means a fault
            //    transition may fire, so flush-and-wake every settled
            //    agent and step densely until the oracle goes quiet
            //    again (stale wake-heap entries are skipped on pop).
            let fault_quiet = fault.idle_until(step, dt)
                .filter(|&f| f > step);
            if fault_quiet.is_none() && active_set.active.len() < n {
                for i in 0..n {
                    if active_set.stamp[i] != active_set.epoch {
                        let k = step - active_set.settled_at[i];
                        lat_col[i].push_zeros(k);
                        tput_col[i].push_zeros(k);
                        queue_col[i].push_zeros(k);
                        alloc_col[i].push_zeros(k);
                        active_set.stamp[i] = active_set.epoch;
                    }
                }
                active_set.active.clear();
                active_set.active.extend(0..n);
            }

            // 2. Whole-idle jump (the skip-idle tier, kept inside this
            //    loop): settled agents are zero by invariant, so the
            //    whole system is provably idle as soon as every ACTIVE
            //    queue is empty and the schedule-level oracles agree.
            //    Active agents' windows are batch-accounted here; the
            //    settled stay deferred — `gen.idle_until`'s promise
            //    covers all agents, so a wake scheduled inside the
            //    window still flushes exactly its zero span at drain.
            if let Some(fq) = fault_quiet {
                if active_set.active.iter().all(|&i| queues[i] == 0.0) {
                    if let Some(w) = gen.idle_until(step) {
                        let until = w.min(fq).min(steps);
                        if until > step {
                            let k = until - step;
                            for &i in active_set.active.iter() {
                                lat_col[i].push_zeros(k);
                                tput_col[i].push_zeros(k);
                                queue_col[i].push_zeros(k);
                                alloc_col[i].push_zeros(k);
                            }
                            step = until;
                            continue;
                        }
                    }
                }
            }

            // 3. Arrivals, active agents only — bit-the-same draws as
            //    the dense loop (settled agents' zero-rate steps consume
            //    no RNG, and their stale rate/count cells are never
            //    read: `observed` is what policies see, and it holds
            //    0.0 for settled agents by the settle condition).
            gen.step_active(step, dt, &active_set.active, rates, counts);
            for &i in active_set.active.iter() {
                queues[i] += counts[i];
                arrived_total[i] += counts[i];
                observed[i] = counts[i] / dt;
            }

            // 4. Allocation. Quiet windows take base capacity directly
            //    (what `capacity_at` would return, without advancing
            //    its cursor — the promise says there is nothing to
            //    advance); fault windows run the real hook over the
            //    full (all-awake) agent set.
            let capacity = match fault_quiet {
                Some(_) => cfg.capacity,
                None => fault.capacity_at(step, dt, cfg.capacity, n),
            };
            let ctx = AllocContext {
                registry: &self.registry,
                arrival_rates: &observed[..],
                queue_depths: &queues[..],
                step,
                capacity,
            };
            policy.allocate_active(&ctx, &active_set.active,
                                   &mut alloc[..]);

            // 4a. Physical enforcement under degraded capacity —
            //     unreachable in quiet windows (capacity == base there),
            //     and everyone is awake when it fires, so the full-slice
            //     fold matches the dense loop exactly.
            if fault.is_active() && capacity < cfg.capacity {
                let total: f64 = alloc.iter().sum();
                if total > capacity {
                    let s = if total > 0.0 { capacity / total } else { 0.0 };
                    for g in alloc.iter_mut() {
                        *g *= s;
                    }
                }
            }

            // 5. Processing, active agents only. The ascending-index
            //    fold over the active list equals the dense 0..n fold
            //    with the settled agents' `+0.0` terms elided.
            let mut total_alloc = 0.0;
            for &i in active_set.active.iter() {
                let g = alloc[i];
                total_alloc += g;
                let rate = match fault_quiet {
                    Some(_) => base_tput[i] * g,
                    None => fault.degrade_rate(step, dt, i,
                                               base_tput[i] * g),
                };
                let cap = rate * dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                processed_sum += processed;

                let latency = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                let tput = processed / dt;

                lat_col[i].push(latency);
                tput_col[i].push(tput);
                queue_col[i].push(queues[i]);
                alloc_col[i].push(g);
                if cap > 0.0 {
                    util_col[i].push(processed / cap);
                }
                processed_total[i] += processed;
                lat_row[i] = latency;
                tput_row[i] = tput;
            }

            // 6. Billing — O(1), `total_alloc` is the dense fold.
            econ.charge_step(total_alloc, &alloc[..], dt);

            // 7. Settle scan, quiet steps only (fault windows wake
            //    everyone anyway, so settling inside one is churn).
            //    `observed == 0.0` guards the stale-buffer hazard: the
            //    policy reads the full slices, so a settled agent must
            //    hold exact zeros in every cell a later allocate sees.
            if fault_quiet.is_some() {
                let settle_ctx = AllocContext {
                    registry: &self.registry,
                    arrival_rates: &observed[..],
                    queue_depths: &queues[..],
                    step,
                    capacity,
                };
                let mut any_settled = false;
                for idx in 0..active_set.active.len() {
                    let i = active_set.active[idx];
                    if queues[i] != 0.0 || alloc[i] != 0.0
                        || observed[i] != 0.0
                        || !policy.zero_fixed_point(&settle_ctx, i)
                    {
                        continue;
                    }
                    let Some(w) = gen.agent_idle_until(i, step + 1)
                    else {
                        continue;
                    };
                    if w <= step + 1 {
                        continue;
                    }
                    active_set.settle(i, step + 1, w);
                    any_settled = true;
                }
                if any_settled {
                    let epoch = active_set.epoch;
                    let stamp = &active_set.stamp;
                    active_set.active.retain(|&i| stamp[i] == epoch);
                }
            }

            step += 1;
        }

        // Flush every still-settled agent's deferred zero span to the
        // end of the run.
        for i in 0..n {
            if active_set.stamp[i] != active_set.epoch {
                let k = steps - active_set.settled_at[i];
                lat_col[i].push_zeros(k);
                tput_col[i].push_zeros(k);
                queue_col[i].push_zeros(k);
                alloc_col[i].push_zeros(k);
            }
        }

        let stats: Vec<AgentStats> = names.into_iter().enumerate()
            .map(|(i, name)| AgentStats {
                name,
                latency: lat_col[i],
                throughput: tput_col[i],
                queue: queue_col[i],
                allocation: alloc_col[i],
                utilization: util_col[i],
                processed_total: processed_total[i],
                arrived_total: arrived_total[i],
                final_queue: queues[i],
            })
            .collect();

        let (cost_dollars, gpu_seconds, economics) = econ.finish(steps);
        let resilience =
            fault.finish(processed_sum / (steps as f64 * dt).max(1e-9));

        SimResult {
            policy: policy.name().to_string(),
            steps,
            dt,
            per_agent: stats,
            cost_dollars,
            gpu_seconds,
            economics,
            resilience,
            workflow: None,
            timelines: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::{AdaptivePolicy, RoundRobinPolicy,
                           StaticEqualPolicy};
    use crate::serverless::EconomicsModel;
    use crate::workload::WorkloadKind;

    fn paper_sim() -> Simulator {
        Simulator::new(SimConfig::paper(), AgentProfile::paper_agents())
    }

    /// Full bit-identity between two results: every Streaming
    /// accumulator field-for-field, every total, both optional reports.
    fn assert_bit_identical(a: &SimResult, b: &SimResult) {
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.per_agent.len(), b.per_agent.len());
        for (x, y) in a.per_agent.iter().zip(&b.per_agent) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.latency, y.latency, "latency {}", x.name);
            assert_eq!(x.throughput, y.throughput, "tput {}", x.name);
            assert_eq!(x.queue, y.queue, "queue {}", x.name);
            assert_eq!(x.allocation, y.allocation, "alloc {}", x.name);
            assert_eq!(x.utilization, y.utilization, "util {}", x.name);
            assert_eq!(x.processed_total, y.processed_total);
            assert_eq!(x.arrived_total, y.arrived_total);
            assert_eq!(x.final_queue, y.final_queue);
        }
        assert_eq!(a.cost_dollars, b.cost_dollars);
        assert_eq!(a.gpu_seconds, b.gpu_seconds);
        assert_eq!(a.economics, b.economics);
        assert_eq!(a.resilience, b.resilience);
        assert_eq!(a.workflow, b.workflow);
    }

    /// A workload whose only traffic is one agent's mid-run burst — the
    /// canonical shape where the skip-idle core actually fires (before
    /// the burst and after the backlog drains).
    fn burst_cfg() -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0, 40.0, 0.0, 0.0];
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1], start: 50, end: 70,
        };
        cfg
    }

    #[test]
    fn static_equal_reproduces_table2_row() {
        let r = paper_sim().run(&mut StaticEqualPolicy);
        // Paper: 110.3 s, 60.0 rps, $0.020.
        assert!((r.mean_latency() - 110.3).abs() < 0.5,
                "latency={}", r.mean_latency());
        assert!((r.total_throughput() - 60.0).abs() < 0.3,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6,
                "cost={}", r.cost_dollars);
    }

    #[test]
    fn adaptive_reproduces_table2_row() {
        let r = paper_sim().run(&mut AdaptivePolicy::default());
        // Paper: 111.9 s, 58.1 rps, $0.020.
        assert!((r.mean_latency() - 111.9).abs() < 0.6,
                "latency={}", r.mean_latency());
        assert!((r.total_throughput() - 58.1).abs() < 0.3,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6);
        // Per-agent: reasoning lowest (91.6 s), vision highest (128.6 s).
        let lat = r.agent_latencies();
        assert!((lat[3] - 91.7).abs() < 0.6, "reasoning={}", lat[3]);
        assert!((lat[2] - 128.6).abs() < 0.7, "vision={}", lat[2]);
        let min = lat.iter().cloned().fold(f64::MAX, f64::min);
        let max = lat.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(min, lat[3]);
        assert_eq!(max, lat[2]);
    }

    #[test]
    fn round_robin_reproduces_table2_row() {
        let r = paper_sim().run(&mut RoundRobinPolicy::default());
        // Paper: 756.1 s mean, std 0.5, 60.0 rps, $0.020.
        assert!((r.mean_latency() - 756.1).abs() < 2.0,
                "latency={}", r.mean_latency());
        assert!(r.latency_std() < 1.5, "std={}", r.latency_std());
        assert!((r.total_throughput() - 60.0).abs() < 0.5,
                "tput={}", r.total_throughput());
        assert!((r.cost_dollars - 0.020).abs() < 1e-6);
    }

    #[test]
    fn headline_claim_85_percent_latency_reduction() {
        let sim = paper_sim();
        let adaptive = sim.run(&mut AdaptivePolicy::default());
        let rr = sim.run(&mut RoundRobinPolicy::default());
        let reduction = 1.0 - adaptive.mean_latency() / rr.mean_latency();
        assert!(reduction > 0.83 && reduction < 0.87,
                "reduction={reduction}");
    }

    #[test]
    fn conservation_holds_for_all_policies() {
        let sim = paper_sim();
        for mut p in crate::allocator::all_policies() {
            let r = sim.run(p.as_mut());
            assert!(r.conservation_error() < 1e-6,
                    "{}: {}", r.policy, r.conservation_error());
        }
    }

    #[test]
    fn arena_reuse_is_bit_identical_to_fresh_buffers() {
        // One arena shared across runs of different policies must leave
        // no state behind: every reused run matches its fresh-buffer twin
        // exactly.
        let sim = paper_sim();
        let mut arena = SimArena::new();
        for _ in 0..3 {
            for mut p in crate::allocator::all_policies() {
                let reused = sim.run_with_arena(p.as_mut(), &mut arena);
                let fresh = sim.run(p.as_mut());
                assert_eq!(reused.mean_latency(), fresh.mean_latency(),
                           "{}", reused.policy);
                assert_eq!(reused.total_throughput(),
                           fresh.total_throughput());
                assert_eq!(reused.cost_dollars, fresh.cost_dollars);
            }
        }
    }

    #[test]
    fn arena_adapts_to_registry_size_changes() {
        // The same arena must serve simulators of different agent counts.
        let mut arena = SimArena::with_agents(4);
        let four = paper_sim()
            .run_with_arena(&mut AdaptivePolicy::default(), &mut arena);
        assert_eq!(four.per_agent.len(), 4);

        let mut agents = AgentProfile::paper_agents();
        agents.truncate(2);
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates.truncate(2);
        let two = Simulator::new(cfg, agents)
            .run_with_arena(&mut AdaptivePolicy::default(), &mut arena);
        assert_eq!(two.per_agent.len(), 2);
        assert!(two.total_throughput() > 0.0);
    }

    #[test]
    fn timelines_recorded_when_requested() {
        let mut cfg = SimConfig::paper_poisson();
        cfg.record_timelines = true;
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let tl = r.timelines.expect("timelines");
        assert_eq!(tl.allocation.len(), 100);
        assert_eq!(tl.queue.len(), 100);
        // Allocation rows sum to <= capacity.
        for row in tl.allocation.rows() {
            let total: f64 = row.iter().sum();
            assert!(total <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn poisson_run_is_reproducible() {
        let cfg = SimConfig::paper_poisson();
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let a = sim.run(&mut AdaptivePolicy::default());
        let b = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(a.mean_latency(), b.mean_latency());
        assert_eq!(a.total_throughput(), b.total_throughput());
    }

    #[test]
    fn all_warm_economics_reproduces_table2_cost_row() {
        // Economics enabled with the paper's all-warm model must not
        // perturb Table II: the total stays $0.020 / 100 s and the
        // per-agent bills partition it exactly.
        let mut cfg = SimConfig::paper();
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let r = sim.run(p.as_mut());
            assert!((r.cost_dollars - 0.020).abs() < 1e-6, "{}", r.policy);
            let econ = r.economics.as_ref().expect("economics enabled");
            assert!((econ.total_cost() - r.cost_dollars).abs() < 1e-12,
                    "{}: per-agent bills must sum to the total", r.policy);
            assert_eq!(econ.cold_starts, vec![0; 4], "{}", r.policy);
            assert_eq!(econ.warm_fraction, vec![1.0; 4], "{}", r.policy);
        }
    }

    #[test]
    fn scale_to_zero_saves_money_on_idle_agents() {
        // Under static-equal, an idle agent still holds (and bills) 25%
        // of the GPU — unless scale-to-zero tears its instance down.
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![80.0, 0.0, 0.0, 0.0]; // only coordinator
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let warm_sim = Simulator::new(cfg.clone(),
                                      AgentProfile::paper_agents());
        let warm = warm_sim.run(&mut StaticEqualPolicy);

        cfg.economics = Some(EconomicsModel::with_idle_timeout(5.0));
        let s2z_sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let s2z = s2z_sim.run(&mut StaticEqualPolicy);

        assert!(s2z.cost_dollars < warm.cost_dollars * 0.5,
                "scale-to-zero {} vs always-warm {}",
                s2z.cost_dollars, warm.cost_dollars);
        // The busy agent is unaffected.
        assert!((s2z.per_agent[0].throughput.mean()
                 - warm.per_agent[0].throughput.mean()).abs() < 1e-9);
        // The report shows where the money went: the coordinator keeps
        // billing, the never-busy agents stop after the timeout.
        let econ = s2z.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.warm_fraction[0], 1.0);
        for i in 1..4 {
            assert!(econ.warm_fraction[i] < 0.1,
                    "agent {i} warm fraction {}", econ.warm_fraction[i]);
            assert!(econ.per_agent_cost[i] < warm.cost_dollars * 0.02,
                    "agent {i} still billing {}", econ.per_agent_cost[i]);
        }
        assert_eq!(econ.total_cold_starts(), 0, "nothing ever wakes");
    }

    #[test]
    fn cold_start_delays_processing_after_burst() {
        // NLP idles hard (zero arrivals), scales to zero, then a mid-run
        // burst arrives: its first post-burst steps process nothing while
        // the ~2.2 s cold start (2 GB checkpoint) completes, and the wake
        // is counted in the economics report.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1], start: 50, end: 100,
        };
        cfg.economics = Some(EconomicsModel::with_idle_timeout(3.0));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let nlp = &r.per_agent[1];
        assert!(nlp.processed_total > 0.0, "burst eventually served");
        assert!(nlp.processed_total < nlp.arrived_total,
                "cold start must cost some processing");
        let econ = r.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.cold_starts[1], 1, "one wake for the burst");
        assert!(econ.warm_fraction[1] < 1.0);
        // Always-busy agents never cold-start.
        assert_eq!(econ.cold_starts[0], 0);
        assert_eq!(econ.warm_fraction[0], 1.0);
    }

    #[test]
    fn eviction_outage_degrades_then_recovers() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 20.0, gpu: 0, duration: 10.0 },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let faulted = sim.run(&mut AdaptivePolicy::default());
        let clean = paper_sim().run(&mut AdaptivePolicy::default());
        let r = faulted.resilience.as_ref().expect("faults configured");
        assert!((r.recovery_time_s - 10.0).abs() < 1e-9,
                "outage window is 10 s, got {}", r.recovery_time_s);
        assert!(r.goodput < clean.total_throughput(),
                "outage must cost goodput: {} vs {}",
                r.goodput, clean.total_throughput());
        assert!(r.goodput > 0.0, "run recovers after the outage");
        // During the outage nothing processes; conservation still holds.
        assert!(faulted.conservation_error() < 1e-6);
        assert!(clean.resilience.is_none());
    }

    #[test]
    fn capacity_drop_degrades_proportionally() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::CapacityDrop { t: 0.0, frac: 0.5, duration: 1e9 },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut StaticEqualPolicy);
        // Half capacity for the whole run: allocations are scaled to fit.
        for a in &r.per_agent {
            assert!(a.allocation.mean() <= 0.125 + 1e-9,
                    "{}: {}", a.name, a.allocation.mean());
        }
        let rep = r.resilience.expect("faults configured");
        assert!((rep.recovery_time_s - 100.0).abs() < 1e-9);
    }

    #[test]
    fn agent_stall_slows_only_the_stalled_agent() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::AgentStall {
                t: 0.0, agent: 1, factor: 4.0, duration: 1e9,
            },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let stalled = sim.run(&mut StaticEqualPolicy);
        let clean = paper_sim().run(&mut StaticEqualPolicy);
        let s = stalled.agent_throughputs();
        let c = clean.agent_throughputs();
        assert!(s[1] < c[1] * 0.5, "stalled agent slows: {} vs {}",
                s[1], c[1]);
        assert_eq!(s[0], c[0], "other agents are untouched");
        assert_eq!(s[2], c[2]);
        let rep = stalled.resilience.expect("faults configured");
        assert!((rep.disruption - 0.25).abs() < 1e-12,
                "1 of 4 agents stalled, got {}", rep.disruption);
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_no_faults() {
        use crate::sim::fault::{FaultConfig, FaultPlan};
        let mut cfg = SimConfig::paper_poisson();
        cfg.faults = Some(FaultConfig::new(FaultPlan::empty()));
        let gated = Simulator::new(cfg, AgentProfile::paper_agents());
        let plain = Simulator::new(SimConfig::paper_poisson(),
                                   AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let a = gated.run(p.as_mut());
            let b = plain.run(p.as_mut());
            assert_eq!(a.mean_latency(), b.mean_latency(), "{}", a.policy);
            assert_eq!(a.total_throughput(), b.total_throughput());
            assert_eq!(a.cost_dollars, b.cost_dollars);
            assert!(a.resilience.is_none(), "inert faults report nothing");
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_on_burst_windows() {
        use crate::workload::ArrivalProcess;
        // Deterministic and Poisson, every policy: the skipped run must
        // match the dense reference to the bit. Poisson works because
        // zero-rate steps consume no RNG state.
        for poisson in [false, true] {
            let mut cfg = burst_cfg();
            if poisson {
                cfg.arrival_process = ArrivalProcess::Poisson;
            }
            let sim = Simulator::new(cfg, AgentProfile::paper_agents());
            for mut p in crate::allocator::all_policies() {
                let skip = sim.run(p.as_mut());
                let dense = sim.run_dense(p.as_mut());
                assert_bit_identical(&skip, &dense);
            }
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_under_economics() {
        // Scale-to-zero lifecycle: the idle window is only skippable
        // once every instance has gone cold (warm idle instances accrue
        // teardown time densely), and the cold-start wake on the burst
        // must land on the same step with the same RNG draws.
        let mut cfg = burst_cfg();
        cfg.economics = Some(EconomicsModel::with_idle_timeout(3.0));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
        }
        // And the all-warm model, where the lifecycle never exists.
        let mut cfg = burst_cfg();
        cfg.economics = Some(EconomicsModel::paper_all_warm());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let skip = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_bit_identical(&skip, &dense);
    }

    #[test]
    fn skip_idle_is_bit_exact_under_faults() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Faults scheduled inside, before, and after the idle windows:
        // the fault cursor must stop the skip exactly at each event's
        // first step and the resilience accounting must not drift.
        let mut cfg = burst_cfg();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 10.0, gpu: 0, duration: 5.0 },
            FaultEvent::CapacityDrop { t: 30.0, frac: 0.3, duration: 10.0 },
            FaultEvent::AgentStall {
                t: 55.0, agent: 1, factor: 3.0, duration: 5.0,
            },
        ])));
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
            assert!(skip.resilience.is_some());
        }
    }

    #[test]
    fn skip_idle_is_bit_exact_on_all_zero_and_steady_workloads() {
        // All-zero: the entire run is one skipped window.
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0; 4];
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&skip, &dense);
        }
        // Steady paper workload: never idle, the skip never fires, and
        // Table II comes out of the same dense loop either way.
        let sim = paper_sim();
        let skip = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_bit_identical(&skip, &dense);
        assert!((skip.mean_latency() - 111.9).abs() < 0.6);
    }

    #[test]
    fn skip_idle_is_bit_exact_on_trace_replay() {
        use crate::workload::trace::Trace;
        let names = (0..4).map(|i| format!("a{i}")).collect::<Vec<_>>();
        let mut rows = vec![vec![0.0; 4]; 20];
        for i in 0..10 {
            rows.push(vec![5.0 + i as f64, 0.0, 2.0, 0.0]);
        }
        rows.extend(vec![vec![0.0; 4]; 30]);
        let trace = Trace::new(names, 1.0, rows).expect("rectangular");
        let sim = paper_sim();
        for mut p in crate::allocator::all_policies() {
            let skip = sim.run_trace(p.as_mut(), &trace);
            let dense = sim.run_trace_dense(p.as_mut(), &trace);
            assert_bit_identical(&skip, &dense);
            assert_eq!(skip.steps, 60);
        }
    }

    #[test]
    #[should_panic(expected = "trace error")]
    fn run_trace_panics_on_ragged_rows() {
        use crate::workload::trace::Trace;
        // A hand-built ragged trace must be rejected up front with the
        // labelled trace error, not die on copy_from_slice mid-run.
        let trace = Trace {
            agents: (0..4).map(|i| format!("a{i}")).collect(),
            dt: 1.0,
            counts: vec![vec![0.0; 4], vec![1.0; 3], vec![0.0; 4]],
        };
        paper_sim().run_trace(&mut AdaptivePolicy::default(), &trace);
    }

    #[test]
    fn workflow_run_surfaces_end_to_end_stats() {
        use crate::workload::WorkflowWorkload;
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::paper());
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let wf = r.workflow.as_ref().expect("workflow configured");
        assert!(wf.started > 0, "instances released");
        assert!(wf.completed > 0, "instances finish end to end");
        assert!(wf.completed <= wf.started);
        assert!(wf.mean_s() > 0.0, "fan-out takes at least 3 ticks");
        assert!(wf.p99_s() >= wf.mean_s() - 1e-9);
        // Plain runs carry no workflow report.
        assert!(paper_sim().run(&mut AdaptivePolicy::default())
                .workflow.is_none());
    }

    #[test]
    fn workflow_stages_wait_for_upstream_in_virtual_time() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        // A 2-stage chain 0 -> 1 at 1 instance/s: the specialist agent
        // must see zero throughput on the very first tick (its stage is
        // not yet eligible) and nonzero on the next.
        let spec = WorkflowSpec::chain("chain2", &[0, 1]);
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(spec, 1.0));
        cfg.record_timelines = true;
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        let tl = r.timelines.expect("timelines");
        let t0 = tl.throughput.rows().next().expect("step 0");
        assert!(t0[0] > 0.0, "stage 0 processes on arrival");
        assert_eq!(t0[1], 0.0, "stage 1 cannot start before stage 0");
        let t1 = tl.throughput.rows().nth(1).expect("step 1");
        assert!(t1[1] > 0.0, "stage 1 armed the tick after");
        // Agents off the DAG never see traffic.
        assert_eq!(r.per_agent[2].arrived_total, 0.0);
        assert_eq!(r.per_agent[3].arrived_total, 0.0);
    }

    #[test]
    fn skip_idle_is_bit_exact_on_workflow_runs() {
        use crate::workload::{ArrivalProcess, WorkflowWorkload};
        for poisson in [false, true] {
            let mut cfg = SimConfig::paper();
            if poisson {
                cfg.arrival_process = ArrivalProcess::Poisson;
            }
            cfg.workflow = Some(WorkflowWorkload::paper());
            let sim = Simulator::new(cfg, AgentProfile::paper_agents());
            for mut p in crate::allocator::all_policies() {
                let skip = sim.run(p.as_mut());
                let dense = sim.run_dense(p.as_mut());
                assert_bit_identical(&skip, &dense);
                assert!(skip.workflow.is_some());
            }
        }
    }

    #[test]
    #[should_panic(expected = "config error")]
    fn workflow_spec_must_fit_the_registry() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        let spec = WorkflowSpec::chain("wide", &[0, 9]);
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(spec, 1.0));
        Simulator::new(cfg, AgentProfile::paper_agents());
    }

    #[test]
    fn idle_workload_costs_nothing_under_adaptive() {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0; 4];
        let sim = Simulator::new(cfg, AgentProfile::paper_agents());
        let r = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(r.cost_dollars, 0.0);
        assert_eq!(r.mean_latency(), 0.0);
        assert_eq!(r.total_throughput(), 0.0);
    }

    /// Zero-floor profiles: agents can scale to exactly zero GPU, so
    /// the active-set tier really settles them. Agent 0 keeps a floor
    /// (and no traffic) to pin that floored idle agents never settle
    /// but still come out bit-exact — they stay in the active list.
    fn sparse_agents(n: usize) -> Vec<AgentProfile> {
        use crate::agents::Priority;
        (0..n)
            .map(|i| AgentProfile {
                name: format!("a{i}"),
                model_mb: 800,
                base_tput: 40.0 + (i % 3) as f64 * 10.0,
                min_gpu: if i == 0 { 0.1 } else { 0.0 },
                priority: match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Medium,
                    _ => Priority::Low,
                },
            })
            .collect()
    }

    /// Only `hot` ever receives arrivals, and only inside a mid-run
    /// burst window — the canonical active-set shape: the idle herd
    /// settles at step 0, the hot agents settle before the window,
    /// wake at its start, and re-settle once the backlog drains.
    fn sparse_burst_cfg(n: usize, hot: &[usize]) -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = (0..n)
            .map(|i| if hot.contains(&i) { 30.0 } else { 0.0 })
            .collect();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: hot.to_vec(),
            start: 40,
            end: 60,
        };
        cfg
    }

    #[test]
    fn active_set_is_bit_exact_on_sparse_bursts() {
        use crate::workload::ArrivalProcess;
        // All three tiers, every policy, deterministic and Poisson:
        // full-result bit identity. Poisson holds because settled
        // agents' zero-rate draws consume no RNG state.
        for poisson in [false, true] {
            let mut cfg = sparse_burst_cfg(16, &[3, 11]);
            if poisson {
                cfg.arrival_process = ArrivalProcess::Poisson;
            }
            let sim = Simulator::new(cfg, sparse_agents(16));
            for mut p in crate::allocator::all_policies() {
                let active = sim.run(p.as_mut());
                let dense = sim.run_dense(p.as_mut());
                let skip = sim.run_skip_idle(p.as_mut());
                assert_bit_identical(&active, &dense);
                assert_bit_identical(&skip, &dense);
                // The burst really happened: hot agents saw traffic,
                // the herd saw none.
                assert!(active.per_agent[3].arrived_total > 0.0);
                assert_eq!(active.per_agent[4].arrived_total, 0.0);
            }
        }
    }

    #[test]
    fn active_set_is_bit_exact_under_steady_sparse_load() {
        // Steady traffic on 2 of 16 agents: the zero-floor herd settles
        // at step 0 and sleeps to the end of the run; the hot pair and
        // the floored straggler step live throughout.
        let mut cfg = sparse_burst_cfg(16, &[3, 11]);
        cfg.workload_kind = WorkloadKind::Steady;
        let sim = Simulator::new(cfg, sparse_agents(16));
        for mut p in crate::allocator::all_policies() {
            let active = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&active, &dense);
        }
    }

    #[test]
    fn active_set_is_bit_exact_under_mid_window_faults() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Fault events land while most agents are settled (before the
        // burst), inside the burst, and after the backlog drains. Each
        // must flush-and-wake the settled herd on exactly its first
        // step — stall accounting, the capacity cursor, and resilience
        // totals all have to match the dense reference to the bit.
        let mut cfg = sparse_burst_cfg(16, &[3, 11]);
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::AgentStall {
                t: 15.0, agent: 7, factor: 3.0, duration: 5.0,
            },
            FaultEvent::CapacityDrop {
                t: 45.0, frac: 0.4, duration: 8.0,
            },
            FaultEvent::GpuEviction { t: 80.0, gpu: 0, duration: 6.0 },
        ])));
        let sim = Simulator::new(cfg, sparse_agents(16));
        for mut p in crate::allocator::all_policies() {
            let active = sim.run(p.as_mut());
            let dense = sim.run_dense(p.as_mut());
            assert_bit_identical(&active, &dense);
            assert!(active.resilience.is_some());
        }
    }

    #[test]
    fn globally_coupled_policies_take_the_skip_idle_fallback() {
        use crate::allocator::PolicyKind;
        // The active-set gate is `idle_fixed_point`: round-robin
        // rotates its cursor every window and static-equal always
        // grants floors, so neither is per-agent settleable. `run()`
        // must route them through the skip-idle fallback — asserted
        // via the gate condition itself plus bit-identity on a shape
        // where settling would otherwise fire.
        assert!(!PolicyKind::round_robin().idle_fixed_point(16));
        assert!(!PolicyKind::static_equal().idle_fixed_point(16));
        let sim = Simulator::new(sparse_burst_cfg(16, &[3, 11]),
                                 sparse_agents(16));
        for mut p in [PolicyKind::round_robin(),
                      PolicyKind::static_equal()] {
            let fallback = sim.run(&mut p);
            let dense = sim.run_dense(&mut p);
            let skip = sim.run_skip_idle(&mut p);
            assert_bit_identical(&fallback, &dense);
            assert_bit_identical(&skip, &dense);
        }
    }

    #[test]
    fn active_set_wakes_settled_agents_for_late_bursts() {
        // A single hot agent whose burst starts late: the wake must
        // land on exactly the burst's first step even though the
        // whole-idle jump leaps straight to it, and the deferred zero
        // flush must cover precisely the settled span.
        let mut cfg = sparse_burst_cfg(8, &[5]);
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![5],
            start: 90,
            end: 95,
        };
        let sim = Simulator::new(cfg, sparse_agents(8));
        let active = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_bit_identical(&active, &dense);
        // Every column saw all 100 steps despite the 90-step sleep.
        for a in &active.per_agent {
            assert_eq!(a.latency.count(), 100, "{}", a.name);
            assert_eq!(a.allocation.count(), 100, "{}", a.name);
        }
        assert!(active.per_agent[5].arrived_total > 0.0);
    }
}
