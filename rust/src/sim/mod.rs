//! Discrete-time serverless-GPU simulator (§IV.B).
//!
//! Reproduces the paper's simulation methodology exactly: per one-second
//! timestep, requests arrive, the policy allocates GPU fractions, agents
//! process `min(queue, g·T·dt)` requests, and metrics are recorded on the
//! post-processing queue. The latency metric is the *estimated backlog
//! wait* `Q / (g·T)` capped at [`SimConfig::latency_cap_s`] (1000 s) — the
//! estimator reverse-engineered in DESIGN.md §1 that reproduces every
//! Table II number to the reported decimal.
//!
//! # The three-tier event core
//!
//! The engines are *event-stepped*, not purely fixed-step — three tiers,
//! each bit-exact with the one below it:
//!
//! ```text
//!  step ─►┌──────────────────────────────────────────────────────┐
//!         │ whole-sim idle oracles (skip-idle tier):             │
//!         │ queues all empty? timelines off?                     │
//!         │ policy.idle_fixed_point()   (zero demand → zero out) │
//!         │ econ.idle_fixed_point()     (no pending transition)  │
//!         │ source.idle_until(step)     (workload: zero arrivals)│
//!         │ fault.idle_until(step, dt)  (no event in window)     │
//!         └───────────┬───────────────────────────┬──────────────┘
//!                all Some(·)                  any None/false
//!                     │                           │
//!                     ▼                           ▼
//!          fast-forward to min(u)   ┌─────────────────────────────┐
//!          push_zeros(k) on metric  │ busy tick — dense, or       │
//!          columns — closed form,   │ *active-set* when eligible: │
//!          O(1) per column          │ walk only agents whose      │
//!                                   │ state can change this step, │
//!                                   │ push_repeat(v, k) the       │
//!                                   │ settled rest in O(1)        │
//!                                   └─────────────────────────────┘
//! ```
//!
//! The **skip-idle** tier fast-forwards whole-sim idle windows: every
//! oracle answers either "nothing until step `u`" or "can't promise
//! anything", and when all promise, the window is batch-accounted.
//!
//! The **active-set** tier is the same idea per agent, inside busy
//! ticks. Each arena carries an epoch-stamped active set
//! (`sim::arena::ActiveSet`): an agent leaves it ("settles") when a
//! per-agent oracle proves its state is a fixed point — queue exactly
//! 0.0, allocation exactly 0.0, no observed demand, and
//! `WorkloadGenerator::agent_idle_until` promising zero arrivals until
//! some wake step (pushed on a min-heap). Settled agents' metric
//! columns are flushed with [`crate::metrics::Streaming::push_repeat`]
//! when they re-activate or the run ends. The per-agent contract
//! mirrors the policy invariance documented on
//! [`crate::allocator::AllocationPolicy`]: unchanged inputs ⇒ unchanged
//! allocation; globally-coupled policies (round-robin's rotating
//! pointer) fail `zero_fixed_point` and fall back to dense busy ticks.
//! The serving engine's analog restricts arrival materialization to the
//! workload's *support set* (`WorkloadGenerator::support`).
//!
//! All of it is *bit-exact* with stepping densely: zero arrivals leave
//! queues at exactly 0.0, the fixed points guarantee allocations stay
//! exactly 0.0 (`+0.0` terms neither shift ascending-order folds nor
//! consume RNG), and the streaming batch pushes fold `k` repeated
//! samples into the naive power sums with the same rounding the dense
//! loop would produce. `run_dense` twins on every simulator
//! ([`Simulator::run_dense`], `ClusterSimulator::run_dense`,
//! `ServingSimulator::run_dense`) keep the dense path alive as the
//! reference the property tests assert against, and `run_skip_idle`
//! twins isolate the middle tier. This is what makes
//! `synthetic_registry(4096)` burst cells routine sweep members — only
//! the burst window is stepped, the idle four fifths of the run are
//! batch-accounted — and what makes the `sparse{N}x{k}` cells cheap
//! even *inside* the burst: with 8 hot agents out of 4096, a busy tick
//! walks 8, not 4096.

pub(crate) mod arena;
pub mod batch;
mod engine;
pub mod fault;
mod result;

pub use arena::SimArena;
pub use batch::{run_batch, run_sweep, BatchRun, CellResult,
                ClusterScenario, CostScenario, FaultScenario, Scenario,
                ScenarioBuilder, ServingScenario, SweepArena, SweepCell,
                SweepRun, TraceScenario, WorkflowScenario};
pub use engine::Simulator;
pub use fault::{AdmissionControl, FaultConfig, FaultEvent, FaultModel,
                FaultPlan, ResilienceReport, RetryPolicy, ServingFaults,
                ShedPolicy};
pub use result::{AgentStats, SimResult, Timelines};

use crate::serverless::{EconomicsModel, GpuPricing};
use crate::workload::{ArrivalProcess, WorkflowWorkload, WorkloadKind};

/// Full configuration of one simulation run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Number of discrete steps (paper: 100).
    pub steps: u64,
    /// Step length in seconds (paper: 1.0; spike experiments use 0.01).
    pub dt: f64,
    /// Total GPU capacity to distribute (paper normalizes to 1.0).
    pub capacity: f64,
    /// Latency-estimator cap in seconds (paper-implied: 1000).
    pub latency_cap_s: f64,
    /// GPU pricing for the billing meter.
    pub pricing: GpuPricing,
    /// Mean arrival rate per agent (rps), in agent-id order.
    pub arrival_rates: Vec<f64>,
    /// Arrival schedule shape (steady / scaled / spike / dominance / ...).
    pub workload_kind: WorkloadKind,
    /// Deterministic or Poisson arrivals.
    pub arrival_process: ArrivalProcess,
    /// RNG seed (§IV.B fixed seed).
    pub seed: u64,
    /// Record full per-step timelines (Fig 2(c) data) — costs memory.
    pub record_timelines: bool,
    /// Serverless economics: per-agent billing, scale-to-zero, and cold
    /// starts ([`EconomicsModel`]). When enabled, each step charges every
    /// agent for its allocated fraction under the model's pricing (which
    /// replaces [`SimConfig::pricing`] for the run), idle agents are torn
    /// down after the model's timeout and forfeit (unbilled) their
    /// allocation until a sampled cold start completes, and the run's
    /// [`EconomicsReport`] is surfaced on the result. `None` (the paper's
    /// evaluation) bills the whole device through
    /// [`SimConfig::pricing`] and keeps every agent warm forever.
    ///
    /// [`EconomicsReport`]: crate::serverless::EconomicsReport
    pub economics: Option<EconomicsModel>,
    /// Deterministic fault injection ([`FaultConfig`]). The fluid engine
    /// consumes capacity drops, whole-device evictions, and agent
    /// stalls; the cluster engine consumes evictions (offline devices,
    /// throttled repack recovery, optional rewarm cold starts) and
    /// stalls. When set and non-inert, the run's
    /// [`ResilienceReport`] is surfaced on the result. `None` (the
    /// default) is provably zero-cost: no float op or RNG draw differs
    /// from a build without the fault layer.
    pub faults: Option<FaultConfig>,
    /// Workflow-DAG workload ([`WorkflowWorkload`]): when set, the
    /// arrival process releases multi-stage workflow instances (spec ×
    /// rate) instead of the independent per-agent streams —
    /// [`SimConfig::arrival_rates`] and [`SimConfig::workload_kind`]
    /// are ignored for arrival generation. Downstream stages inject
    /// their work only after their upstream stages complete, and the
    /// run surfaces end-to-end [`WorkflowStats`] on the result. `None`
    /// (the default) keeps the paper's per-agent streams.
    ///
    /// [`WorkflowStats`]: crate::workload::WorkflowStats
    pub workflow: Option<WorkflowWorkload>,
}

impl SimConfig {
    /// The paper's §IV evaluation setup in closed-form (deterministic
    /// arrivals). Reproduces Table II exactly.
    pub fn paper() -> Self {
        SimConfig {
            steps: 100,
            dt: 1.0,
            capacity: 1.0,
            latency_cap_s: 1000.0,
            pricing: GpuPricing::t4(),
            arrival_rates: crate::agents::AgentProfile::paper_arrival_rates(),
            workload_kind: WorkloadKind::Steady,
            arrival_process: ArrivalProcess::Deterministic,
            seed: 42,
            record_timelines: false,
            economics: None,
            faults: None,
            workflow: None,
        }
    }

    /// Paper setup with Poisson arrivals (seed 42) — the stochastic runs
    /// behind Fig 2(c)'s gently-wiggling allocation curves.
    pub fn paper_poisson() -> Self {
        SimConfig {
            arrival_process: ArrivalProcess::Poisson,
            ..SimConfig::paper()
        }
    }
}

/// A compact summary row (one policy) for reports.
#[derive(Debug, Clone)]
pub struct SummaryRow {
    /// Policy identifier.
    pub policy: String,
    /// Mean of per-agent mean latencies (s) — Table II "Avg Latency".
    pub avg_latency_s: f64,
    /// Sum of per-agent mean throughputs (rps) — "Total Throughput".
    pub total_throughput_rps: f64,
    /// Total billed cost in dollars — "Cost".
    pub cost_dollars: f64,
    /// Std of per-agent mean latencies (s) — "Latency Std Dev".
    pub latency_std_s: f64,
    /// Mean GPU utilization across agents and steps.
    pub mean_utilization: f64,
}
