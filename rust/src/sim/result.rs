//! Simulation outputs: per-agent statistics, aggregates, and timelines.

use crate::metrics::{Streaming, TimeSeries};
use crate::serverless::EconomicsReport;
use crate::sim::fault::ResilienceReport;
use crate::sim::SummaryRow;
use crate::util;

/// Accumulated statistics for one agent over a run.
#[derive(Debug, Clone)]
pub struct AgentStats {
    /// Agent name (Table I).
    pub name: String,
    /// Estimated backlog-wait latency per step (s).
    pub latency: Streaming,
    /// Processed requests per second, per step.
    pub throughput: Streaming,
    /// Queue depth after processing, per step.
    pub queue: Streaming,
    /// GPU fraction allocated, per step.
    pub allocation: Streaming,
    /// processed / allocated-capacity per step (in [0,1]).
    pub utilization: Streaming,
    /// Total requests processed.
    pub processed_total: f64,
    /// Total requests that arrived.
    pub arrived_total: f64,
    /// Queue depth at the end of the run.
    pub final_queue: f64,
}

/// Optional full per-step traces (Fig 2(c) and robustness plots).
#[derive(Debug, Clone)]
pub struct Timelines {
    /// GPU fraction per agent per step.
    pub allocation: TimeSeries,
    /// Queue depth per agent per step.
    pub queue: TimeSeries,
    /// Latency estimate per agent per step.
    pub latency: TimeSeries,
    /// Throughput per agent per step.
    pub throughput: TimeSeries,
}

/// Everything one simulation run produced.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Policy that produced this run.
    pub policy: String,
    /// Steps simulated and step length.
    pub steps: u64,
    /// Step length (seconds).
    pub dt: f64,
    /// Per-agent statistics in agent-id order.
    pub per_agent: Vec<AgentStats>,
    /// Billed cost over the run (dollars).
    pub cost_dollars: f64,
    /// Fraction-weighted GPU-seconds consumed.
    pub gpu_seconds: f64,
    /// Per-agent cost, cold-start, and warm-fraction breakdown, present
    /// when the run's config enabled an
    /// [`EconomicsModel`](crate::serverless::EconomicsModel).
    pub economics: Option<EconomicsReport>,
    /// Degraded time, goodput, and disruption under injected faults,
    /// present when the run's config set a non-inert
    /// [`FaultConfig`](crate::sim::fault::FaultConfig).
    pub resilience: Option<ResilienceReport>,
    /// End-to-end workflow latency stats (started/completed instances,
    /// mean/p99), present when the run's config carried a
    /// [`WorkflowWorkload`](crate::workload::WorkflowWorkload).
    pub workflow: Option<crate::workload::WorkflowStats>,
    /// Full timelines when requested.
    pub timelines: Option<Timelines>,
}

impl SimResult {
    /// Table II "Avg Latency": mean of per-agent mean latencies.
    pub fn mean_latency(&self) -> f64 {
        util::mean(&self.agent_latencies())
    }

    /// Table II "Latency Std Dev": std across per-agent mean latencies.
    pub fn latency_std(&self) -> f64 {
        util::std_dev(&self.agent_latencies())
    }

    /// Table II "Total Throughput": sum of per-agent mean throughputs.
    pub fn total_throughput(&self) -> f64 {
        self.per_agent.iter().map(|a| a.throughput.mean()).sum()
    }

    /// Mean utilization across agents.
    pub fn mean_utilization(&self) -> f64 {
        let us: Vec<f64> =
            self.per_agent.iter().map(|a| a.utilization.mean()).collect();
        util::mean(&us)
    }

    /// Per-agent mean latencies in agent order (Fig 2(a)).
    pub fn agent_latencies(&self) -> Vec<f64> {
        self.per_agent.iter().map(|a| a.latency.mean()).collect()
    }

    /// Per-agent mean throughputs in agent order (Fig 2(b)).
    pub fn agent_throughputs(&self) -> Vec<f64> {
        self.per_agent.iter().map(|a| a.throughput.mean()).collect()
    }

    /// Conservation check: arrivals == processed + final queue, per agent.
    /// (Invariant behind the proptest suite.)
    pub fn conservation_error(&self) -> f64 {
        self.per_agent.iter()
            .map(|a| (a.arrived_total - a.processed_total - a.final_queue)
                 .abs())
            .fold(0.0, f64::max)
    }

    /// The paper's Eq. 2 objective: `α·L + β·C − γ·H` (lower is better).
    ///
    /// L = mean latency (s), C = cost ($), H = total throughput (rps).
    /// The weights are application-specific (§III.A); defaults used by
    /// the sweep example are (1, 100, 1).
    pub fn objective(&self, alpha: f64, beta: f64, gamma: f64) -> f64 {
        alpha * self.mean_latency() + beta * self.cost_dollars
            - gamma * self.total_throughput()
    }

    /// Flatten into the serializable summary row used by reports/CSV.
    pub fn summary(&self) -> SummaryRow {
        SummaryRow {
            policy: self.policy.clone(),
            avg_latency_s: self.mean_latency(),
            total_throughput_rps: self.total_throughput(),
            cost_dollars: self.cost_dollars,
            latency_std_s: self.latency_std(),
            mean_utilization: self.mean_utilization(),
        }
    }
}
