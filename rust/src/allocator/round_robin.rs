//! Round-robin baseline (§IV.A, "100 % sequential"): the whole GPU goes to
//! one agent per timestep, rotating in id order.
//!
//! This is the policy the paper's headline claim is measured against: the
//! descheduled agents' backlogs sit idle 3 of every 4 steps, which drives
//! the latency estimator to its cap and produces the ~756 s per-agent
//! latencies (std 0.5 s) in Table II.

use crate::allocator::{AllocContext, AllocationPolicy};

/// Rotating exclusive allocation.
#[derive(Debug, Clone, Default)]
pub struct RoundRobinPolicy {
    /// Steps observed so far; `next % N` picks the holder. Kept internal
    /// (rather than using `ctx.step`) so interleaved runs stay independent.
    next: u64,
}

impl AllocationPolicy for RoundRobinPolicy {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        out.fill(0.0);
        let n = ctx.registry.len() as u64;
        out[(self.next % n) as usize] = ctx.capacity;
        self.next += 1;
    }

    fn reset(&mut self) {
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;

    fn ctx(reg: &AgentRegistry) -> AllocContext<'_> {
        AllocContext {
            registry: reg,
            arrival_rates: &[80.0, 40.0, 45.0, 25.0],
            queue_depths: &[0.0; 4],
            step: 0,
            capacity: 1.0,
        }
    }

    #[test]
    fn rotates_exclusively_in_id_order() {
        let reg = AgentRegistry::paper();
        let mut p = RoundRobinPolicy::default();
        let mut out = vec![0.0; 4];
        for round in 0..8 {
            p.allocate(&ctx(&reg), &mut out);
            for (i, &g) in out.iter().enumerate() {
                let want = if i == round % 4 { 1.0 } else { 0.0 };
                assert_eq!(g, want, "round {round} agent {i}");
            }
        }
    }

    #[test]
    fn reset_restarts_rotation() {
        let reg = AgentRegistry::paper();
        let mut p = RoundRobinPolicy::default();
        let mut out = vec![0.0; 4];
        p.allocate(&ctx(&reg), &mut out);
        p.allocate(&ctx(&reg), &mut out);
        p.reset();
        p.allocate(&ctx(&reg), &mut out);
        assert_eq!(out[0], 1.0);
    }
}
