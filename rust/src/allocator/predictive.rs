//! Predictive extension (paper §VI "future work"): Algorithm 1 driven by an
//! exponential-moving-average forecast of arrival rates instead of the raw
//! instantaneous observation.
//!
//! Under steady load this converges to exactly the adaptive allocation;
//! under bursty load it trades a slower reaction for smoother allocation
//! curves (less thrash for platforms where reallocation has a cost). The
//! `robustness` bench quantifies the trade-off on the 10× spike workload.

use crate::allocator::{AdaptivePolicy, AllocContext, AllocationPolicy};

/// EMA-forecasting wrapper around [`AdaptivePolicy`].
#[derive(Debug, Clone)]
pub struct PredictivePolicy {
    /// EMA smoothing factor in (0, 1]; 1.0 degenerates to adaptive.
    alpha: f64,
    ema: Vec<f64>,
    inner: AdaptivePolicy,
    forecast: Vec<f64>,
}

impl Default for PredictivePolicy {
    fn default() -> Self {
        PredictivePolicy::new(0.3)
    }
}

impl PredictivePolicy {
    /// Create with a given EMA factor (clamped into (0, 1]).
    pub fn new(alpha: f64) -> Self {
        PredictivePolicy {
            alpha: alpha.clamp(1e-6, 1.0),
            ema: Vec::new(),
            inner: AdaptivePolicy::default(),
            forecast: Vec::new(),
        }
    }

    /// Current forecast (empty before the first observation).
    pub fn forecast(&self) -> &[f64] {
        &self.ema
    }
}

impl AllocationPolicy for PredictivePolicy {
    fn name(&self) -> &'static str {
        "predictive"
    }

    /// Only a fixed point once the EMA exists *and* has decayed to exactly
    /// zero: a fresh (empty-EMA) policy is NOT one, because the first
    /// `allocate` call seeds the EMA from the observed rates — skipping
    /// that seeding step would change later forecasts. An all-zero EMA
    /// observing zero rates stays bit-identical (`e += α·(0 − 0)`).
    fn idle_fixed_point(&self, n: usize) -> bool {
        self.ema.len() == n && self.ema.iter().all(|e| *e == 0.0)
    }

    /// Per-agent fixed point only once the EMA is seeded and this agent's
    /// entry has decayed to exactly zero: then the per-step update is
    /// `e += α·(0 − 0)` (a bit-no-op), the forecast handed to the inner
    /// adaptive policy carries `+0.0` for the agent, and the adaptive
    /// fixed point applies iff the floor is zero. A fresh (empty-EMA)
    /// policy is NOT one — the first `allocate` seeds the EMA from the
    /// observed rates.
    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        self.ema.len() == ctx.registry.len()
            && self.ema[agent] == 0.0
            && ctx.registry.min_gpu()[agent] == 0.0
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        let n = ctx.arrival_rates.len();
        if self.ema.len() != n {
            // First observation seeds the EMA directly.
            self.ema = ctx.arrival_rates.to_vec();
            self.forecast = vec![0.0; n];
        } else {
            for i in 0..n {
                self.ema[i] += self.alpha * (ctx.arrival_rates[i]
                    - self.ema[i]);
            }
        }
        self.forecast.copy_from_slice(&self.ema);
        let fctx = AllocContext {
            registry: ctx.registry,
            arrival_rates: &self.forecast,
            queue_depths: ctx.queue_depths,
            step: ctx.step,
            capacity: ctx.capacity,
        };
        self.inner.allocate(&fctx, out);
    }

    fn reset(&mut self) {
        self.ema.clear();
        self.forecast.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;

    fn run_steps(p: &mut PredictivePolicy, rates: &[f64], steps: u64)
                 -> Vec<f64> {
        let reg = AgentRegistry::paper();
        let queues = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        for step in 0..steps {
            let ctx = AllocContext {
                registry: &reg,
                arrival_rates: rates,
                queue_depths: &queues,
                step,
                capacity: 1.0,
            };
            p.allocate(&ctx, &mut out);
        }
        out
    }

    #[test]
    fn steady_state_matches_adaptive() {
        let rates = [80.0, 40.0, 45.0, 25.0];
        let mut pred = PredictivePolicy::default();
        let got = run_steps(&mut pred, &rates, 50);

        let reg = AgentRegistry::paper();
        let queues = vec![0.0; 4];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &queues,
            step: 0,
            capacity: 1.0,
        };
        let mut want = vec![0.0; 4];
        AdaptivePolicy::default().allocate(&ctx, &mut want);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn smooths_spikes() {
        // After one spiked observation the EMA moves only alpha of the way.
        let mut p = PredictivePolicy::new(0.3);
        run_steps(&mut p, &[80.0, 40.0, 45.0, 25.0], 100);
        run_steps(&mut p, &[800.0, 40.0, 45.0, 25.0], 1);
        let f = p.forecast();
        assert!((f[0] - (80.0 + 0.3 * 720.0)).abs() < 1e-6, "{f:?}");
    }

    #[test]
    fn idle_fixed_point_requires_seeded_zero_ema() {
        let mut p = PredictivePolicy::default();
        // Fresh policy: the next allocate seeds the EMA, so skipping idle
        // steps here would change every later forecast.
        assert!(!p.idle_fixed_point(4));
        run_steps(&mut p, &[0.0; 4], 1);
        assert!(p.idle_fixed_point(4));
        // Idle steps on a zero EMA are bit-no-ops.
        let before = p.forecast().to_vec();
        run_steps(&mut p, &[0.0; 4], 17);
        assert_eq!(p.forecast(), &before[..]);
        // Any nonzero history disqualifies it again (EMA decays toward
        // zero but never reaches it exactly).
        run_steps(&mut p, &[80.0, 40.0, 45.0, 25.0], 1);
        run_steps(&mut p, &[0.0; 4], 5);
        assert!(!p.idle_fixed_point(4));
    }

    #[test]
    fn reset_forgets_history() {
        let mut p = PredictivePolicy::default();
        run_steps(&mut p, &[800.0, 0.0, 0.0, 0.0], 10);
        p.reset();
        assert!(p.forecast().is_empty());
    }
}
