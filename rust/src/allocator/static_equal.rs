//! Static equal-share baseline (§IV.A): capacity / N to every agent,
//! regardless of workload. The paper's strongest baseline on latency.

use crate::allocator::{AllocContext, AllocationPolicy};

/// Equal static split of the GPU.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticEqualPolicy;

impl AllocationPolicy for StaticEqualPolicy {
    fn name(&self) -> &'static str {
        "static_equal"
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        let share = ctx.capacity / ctx.registry.len() as f64;
        out.fill(share);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;

    #[test]
    fn equal_quarter_shares_for_paper_agents() {
        let reg = AgentRegistry::paper();
        let rates = [80.0, 40.0, 45.0, 25.0];
        let queues = [0.0; 4];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &queues,
            step: 17,
            capacity: 1.0,
        };
        let mut out = vec![0.0; 4];
        StaticEqualPolicy.allocate(&ctx, &mut out);
        assert_eq!(out, vec![0.25; 4]);
    }

    #[test]
    fn respects_reduced_capacity() {
        let reg = AgentRegistry::paper();
        let rates = [1.0; 4];
        let queues = [0.0; 4];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &queues,
            step: 0,
            capacity: 0.5,
        };
        let mut out = vec![0.0; 4];
        StaticEqualPolicy.allocate(&ctx, &mut out);
        assert_eq!(out, vec![0.125; 4]);
    }
}
