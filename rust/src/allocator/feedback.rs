//! Queue-feedback extension: Algorithm 1's demand augmented with a
//! backpressure term proportional to the standing queue depth.
//!
//! `d_i = (λ_i + κ · Q_i) · R_i / P_i`
//!
//! With κ = 0 this is exactly the paper's Algorithm 1; κ > 0 shifts
//! capacity toward agents with standing backlog so bursts drain faster.
//! §III.D motivates this ("real-time monitoring of queue lengths ... drives
//! allocation adaptation"); the paper's evaluated algorithm uses only λ, so
//! this ships as an extension policy and is ablated in the robustness
//! bench.

use crate::allocator::{normalize_to_capacity, AllocContext, AllocationPolicy};

/// Backpressure-augmented Algorithm 1.
#[derive(Debug, Clone)]
pub struct FeedbackPolicy {
    /// Queue weight κ (per-second⁻¹): how strongly backlog inflates demand.
    kappa: f64,
}

impl Default for FeedbackPolicy {
    fn default() -> Self {
        FeedbackPolicy { kappa: 0.05 }
    }
}

impl FeedbackPolicy {
    /// Create with an explicit backpressure gain.
    pub fn new(kappa: f64) -> Self {
        FeedbackPolicy { kappa: kappa.max(0.0) }
    }
}

impl AllocationPolicy for FeedbackPolicy {
    fn name(&self) -> &'static str {
        "feedback"
    }

    /// Stateless (κ is a constant); zero rates *and* zero queues give
    /// zero pressure, which short-circuits to `out.fill(0.0)`.
    fn idle_fixed_point(&self, _n: usize) -> bool {
        true
    }

    /// A zero-pressure agent (zero rate *and* zero queue — both are part
    /// of the caller's contract) has demand exactly `+0.0`, so phase 2
    /// allocates it `(+0.0 · scale).max(min_gpu)` — exactly `+0.0` iff
    /// its floor is zero.
    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        ctx.registry.min_gpu()[agent] == 0.0
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        let n = ctx.registry.len();
        let min_gpu = ctx.registry.min_gpu();
        let weight = ctx.registry.priority_weight();

        let mut d_total = 0.0;
        for i in 0..n {
            let pressure = ctx.arrival_rates[i]
                + self.kappa * ctx.queue_depths[i];
            let d = pressure * min_gpu[i] / weight[i];
            out[i] = d;
            d_total += d;
        }
        if d_total <= 0.0 {
            out.fill(0.0);
            return;
        }
        let scale = ctx.capacity / d_total;
        for i in 0..n {
            out[i] = (out[i] * scale).max(min_gpu[i]);
        }
        normalize_to_capacity(out, ctx.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;
    use crate::allocator::AdaptivePolicy;

    #[test]
    fn zero_kappa_equals_adaptive() {
        let reg = AgentRegistry::paper();
        let rates = [80.0, 40.0, 45.0, 25.0];
        let queues = [500.0, 100.0, 0.0, 900.0];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &queues,
            step: 0,
            capacity: 1.0,
        };
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        FeedbackPolicy::new(0.0).allocate(&ctx, &mut a);
        AdaptivePolicy::default().allocate(&ctx, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn backlog_shifts_allocation_toward_queued_agent() {
        let reg = AgentRegistry::paper();
        let rates = [80.0, 40.0, 45.0, 25.0];
        let no_queue = [0.0; 4];
        let nlp_backlog = [0.0, 5000.0, 0.0, 0.0];
        let mut base = vec![0.0; 4];
        let mut shifted = vec![0.0; 4];
        let ctx_a = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &no_queue,
            step: 0,
            capacity: 1.0,
        };
        let ctx_b = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &nlp_backlog,
            step: 0,
            capacity: 1.0,
        };
        FeedbackPolicy::default().allocate(&ctx_a, &mut base);
        FeedbackPolicy::default().allocate(&ctx_b, &mut shifted);
        assert!(shifted[1] > base[1],
                "backlogged agent should gain share: {base:?} {shifted:?}");
        let total: f64 = shifted.iter().sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn idle_with_no_backlog_allocates_nothing() {
        let reg = AgentRegistry::paper();
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &[0.0; 4],
            queue_depths: &[0.0; 4],
            step: 0,
            capacity: 1.0,
        };
        let mut out = vec![1.0; 4];
        FeedbackPolicy::default().allocate(&ctx, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }
}
