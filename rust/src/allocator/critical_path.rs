//! DAG-critical-path-aware allocation — the workflow extension of the
//! paper's Algorithm 1.
//!
//! When the workload is a multi-stage workflow DAG (see
//! [`WorkflowSpec`](crate::workload::WorkflowSpec)), per-agent arrival
//! rates understate how much an agent matters: a slow stage on the DAG's
//! critical path delays *every* downstream stage, so end-to-end workflow
//! latency is governed by the critical path, not by aggregate demand.
//! This policy runs Algorithm 1's demand/floor/normalize pipeline but
//! boosts each agent's demand score by its criticality weight:
//!
//! ```text
//!   d_i = λ_i · R_i / P_i · (1 + BOOST · w_i)
//! ```
//!
//! where `w_i ∈ [0, 1]` comes from
//! [`WorkflowSpec::critical_path_weights`](crate::workload::WorkflowSpec::critical_path_weights)
//! (fraction of the DAG's longest path running through the agent, work
//! weighted) and `BOOST = 2`. With no weights configured the boost term
//! is `1` everywhere and the policy is bit-identical to
//! [`AdaptivePolicy`](crate::allocator::AdaptivePolicy).

use crate::allocator::{normalize_to_capacity, AllocContext, AllocationPolicy};

/// Demand multiplier applied to a fully-critical agent (`w_i == 1`).
const BOOST: f64 = 2.0;

/// Algorithm 1 with a critical-path demand boost. `Default` carries no
/// weights (behaves exactly like the adaptive policy); build a weighted
/// instance with [`CriticalPathPolicy::for_workflow`] or via
/// [`PolicyKind::critical_path_for`](crate::allocator::PolicyKind::critical_path_for).
#[derive(Debug, Clone, Default)]
pub struct CriticalPathPolicy {
    /// Per-agent criticality in `[0, 1]`; agents beyond the vector's
    /// length (or the empty default) weigh 0.
    weights: Vec<f64>,
}

impl CriticalPathPolicy {
    /// Policy weighted for `spec` on a deployment of `n_agents` agents.
    pub fn for_workflow(spec: &crate::workload::WorkflowSpec,
                        n_agents: usize) -> CriticalPathPolicy {
        CriticalPathPolicy { weights: spec.critical_path_weights(n_agents) }
    }

    /// Criticality weight for agent `i` (0 when unconfigured).
    fn weight(&self, i: usize) -> f64 {
        self.weights.get(i).copied().unwrap_or(0.0)
    }
}

impl AllocationPolicy for CriticalPathPolicy {
    fn name(&self) -> &'static str {
        "critical_path"
    }

    /// Stateless like the adaptive policy, and zero demand short-circuits
    /// to `out.fill(0.0)`, so an all-idle step is a true no-op.
    fn idle_fixed_point(&self, _n: usize) -> bool {
        true
    }

    /// The criticality boost multiplies the demand score, so a zero-rate
    /// agent's demand is `+0.0 · (1 + BOOST·w) == +0.0` regardless of its
    /// weight; as with the adaptive policy the fixed point then hinges
    /// only on a zero floor.
    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        ctx.registry.min_gpu()[agent] == 0.0
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        let n = ctx.registry.len();
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(ctx.arrival_rates.len(), n);
        let min_gpu = ctx.registry.min_gpu();
        let weight = ctx.registry.priority_weight();

        // Phase 1: demand scores with the critical-path boost.
        let mut d_total = 0.0;
        for i in 0..n {
            let d = ctx.arrival_rates[i] * min_gpu[i] / weight[i]
                * (1.0 + BOOST * self.weight(i));
            out[i] = d;
            d_total += d;
        }

        // Idle system: allocate nothing.
        if d_total <= 0.0 {
            out.fill(0.0);
            return;
        }

        // Phase 2: proportional share with minimum floor.
        let scale = ctx.capacity / d_total;
        for i in 0..n {
            out[i] = (out[i] * scale).max(min_gpu[i]);
        }

        // Phase 3: capacity normalization.
        normalize_to_capacity(out, ctx.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;
    use crate::allocator::AdaptivePolicy;
    use crate::workload::WorkflowSpec;

    fn alloc(policy: &mut dyn AllocationPolicy, rates: &[f64]) -> Vec<f64> {
        let reg = AgentRegistry::paper();
        let queues = vec![0.0; reg.len()];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: rates,
            queue_depths: &queues,
            step: 0,
            capacity: 1.0,
        };
        let mut out = vec![0.0; reg.len()];
        policy.allocate(&ctx, &mut out);
        out
    }

    #[test]
    fn unweighted_matches_adaptive_exactly() {
        let rates = [80.0, 40.0, 45.0, 25.0];
        let a = alloc(&mut AdaptivePolicy::default(), &rates);
        let b = alloc(&mut CriticalPathPolicy::default(), &rates);
        assert_eq!(a, b);
    }

    #[test]
    fn weighted_shifts_capacity_toward_critical_agents() {
        // fanout2 runs through agents 0-2 only, so agent 3 is off the
        // DAG (weight 0) while the coordinator is fully critical.
        let spec = WorkflowSpec::fan_out("fanout2", 0, &[1, 2]);
        let w = spec.critical_path_weights(4);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert_eq!(w[3], 0.0);
        let rates = [80.0, 40.0, 45.0, 25.0];
        let base = alloc(&mut AdaptivePolicy::default(), &rates);
        let boosted =
            alloc(&mut CriticalPathPolicy::for_workflow(&spec, 4), &rates);
        // The fully-critical, floor-free coordinator gains share; the
        // off-DAG agent loses it.
        assert!(boosted[0] > base[0],
                "critical agent not boosted: {boosted:?} vs {base:?}");
        assert!(boosted[3] < base[3],
                "off-DAG agent not demoted: {boosted:?} vs {base:?}");
        let total: f64 = boosted.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn idle_system_allocates_nothing() {
        let spec = WorkflowSpec::paper();
        let g = alloc(&mut CriticalPathPolicy::for_workflow(&spec, 4),
                      &[0.0; 4]);
        assert_eq!(g, vec![0.0; 4]);
    }
}
