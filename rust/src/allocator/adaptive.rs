//! The paper's Algorithm 1: adaptive demand-proportional allocation.
//!
//! Three phases, all O(N) and allocation-free:
//!
//! 1. **Demand**: `d_i = λ_i · R_i / P_i` — arrival rate weighted by the
//!    agent's minimum requirement and (inversely) by its priority value,
//!    so high-priority agents (P = 1) weigh more.
//! 2. **Proportional + floor**: `g_i = max(R_i, d_i / Σd · capacity)` —
//!    proportional share with the minimum floor preventing starvation.
//! 3. **Normalize**: if Σg exceeds capacity, scale all shares down
//!    proportionally (relative priorities preserved).
//!
//! With the paper's Table I agents and §IV.A arrival rates this yields
//! g = (0.2386, 0.2538, 0.2115, 0.2961), the allocation behind every
//! adaptive-row number in Table II.

use crate::allocator::{normalize_to_capacity, AllocContext, AllocationPolicy};

/// Algorithm 1. Stateless; `Default` is the canonical instance.
#[derive(Debug, Clone, Default)]
pub struct AdaptivePolicy {
    _private: (),
}

impl AllocationPolicy for AdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    /// Stateless, and zero demand short-circuits to `out.fill(0.0)`
    /// (Algorithm 1 line 10-12), so an all-idle step is a true no-op.
    fn idle_fixed_point(&self, _n: usize) -> bool {
        true
    }

    /// A zero-rate agent's demand is exactly `+0.0` (phase 1), so it is
    /// allocated `(+0.0 · scale).max(min_gpu)` — exactly `+0.0` iff its
    /// floor is zero. A floored idle agent instead holds its nonzero
    /// minimum whenever any other agent has demand, so it is *not* a
    /// per-agent fixed point.
    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        ctx.registry.min_gpu()[agent] == 0.0
    }

    /// Sparse Algorithm 1: every phase folds or writes only the active
    /// subset. Bit-identical to the dense [`AllocationPolicy::allocate`]
    /// under the `allocate_active` contract: an inactive agent's demand
    /// is `+0.0` (adding it anywhere in the ascending fold is the
    /// identity), its phase-2 write would be `(+0.0 · scale).max(0.0) ==
    /// +0.0` (the bits it already holds), and its phase-3 rescale would
    /// be `+0.0 · s == +0.0`.
    fn allocate_active(&mut self, ctx: &AllocContext<'_>,
                       active: &[usize], out: &mut [f64]) {
        let min_gpu = ctx.registry.min_gpu();
        let weight = ctx.registry.priority_weight();

        // Phase 1: demand scores over the active subset, in ascending
        // agent order — the same addition order as the dense fold with
        // the inactive agents' +0.0 terms elided.
        let mut d_total = 0.0;
        for &i in active {
            let d = ctx.arrival_rates[i] * min_gpu[i] / weight[i];
            out[i] = d;
            d_total += d;
        }

        // Idle system: allocate nothing (inactive entries already 0.0).
        if d_total <= 0.0 {
            for &i in active {
                out[i] = 0.0;
            }
            return;
        }

        // Phase 2: proportional share with minimum floor.
        let scale = ctx.capacity / d_total;
        for &i in active {
            out[i] = (out[i] * scale).max(min_gpu[i]);
        }

        // Phase 3: capacity normalization over the active subset.
        let mut total = 0.0;
        for &i in active {
            total += out[i];
        }
        if total > ctx.capacity && total > 0.0 {
            let s = ctx.capacity / total;
            for &i in active {
                out[i] *= s;
            }
        }
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        let n = ctx.registry.len();
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(ctx.arrival_rates.len(), n);
        let min_gpu = ctx.registry.min_gpu();
        let weight = ctx.registry.priority_weight();

        // Phase 1: demand scores. `out` doubles as the demand buffer so the
        // hot path stays allocation-free.
        let mut d_total = 0.0;
        for i in 0..n {
            let d = ctx.arrival_rates[i] * min_gpu[i] / weight[i];
            out[i] = d;
            d_total += d;
        }

        // Idle system: allocate nothing (Algorithm 1 line 10-12).
        if d_total <= 0.0 {
            out.fill(0.0);
            return;
        }

        // Phase 2: proportional share with minimum floor.
        let scale = ctx.capacity / d_total;
        for i in 0..n {
            out[i] = (out[i] * scale).max(min_gpu[i]);
        }

        // Phase 3: capacity normalization.
        normalize_to_capacity(out, ctx.capacity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::AgentRegistry;

    fn alloc_for(rates: &[f64]) -> Vec<f64> {
        let reg = AgentRegistry::paper();
        let queues = vec![0.0; reg.len()];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: rates,
            queue_depths: &queues,
            step: 0,
            capacity: 1.0,
        };
        let mut out = vec![0.0; reg.len()];
        AdaptivePolicy::default().allocate(&ctx, &mut out);
        out
    }

    #[test]
    fn paper_workload_allocation_matches_closed_form() {
        // §IV.A rates -> the allocation that produces Table II's adaptive
        // row (58.1 rps, 111.9 s mean latency). Closed form derived in
        // DESIGN.md §1.
        let g = alloc_for(&[80.0, 40.0, 45.0, 25.0]);
        let expected = [0.238_62, 0.253_81, 0.211_51, 0.296_07];
        for (got, want) in g.iter().zip(expected) {
            assert!((got - want).abs() < 5e-4, "got {got}, want {want}");
        }
        let total: f64 = g.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn idle_system_allocates_nothing() {
        let g = alloc_for(&[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(g, vec![0.0; 4]);
    }

    #[test]
    fn minimums_enforced_before_normalization() {
        // One agent dominating 90% of traffic must not starve the others:
        // every floor participates before the final scaling (§V.B).
        let g = alloc_for(&[171.0, 9.0, 5.0, 5.0]);
        // After normalization the *relative* floors are preserved: nobody
        // is at zero and nobody exceeds capacity.
        for &gi in &g {
            assert!(gi > 0.0);
        }
        let total: f64 = g.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        // The dominant agent is capped well below 90% of the GPU.
        assert!(g[0] < 0.5, "monopolization not prevented: {g:?}");
    }

    #[test]
    fn allocation_scale_invariant_in_workload() {
        // d_i is linear in λ, so scaling all rates leaves g unchanged
        // (the paper's 3x overload case degrades latency, not allocation).
        let a = alloc_for(&[80.0, 40.0, 45.0, 25.0]);
        let b = alloc_for(&[240.0, 120.0, 135.0, 75.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn allocate_active_is_bit_identical_to_dense() {
        // A registry where the idle agents carry zero floors (the
        // zero_fixed_point precondition) — the sparse phases must
        // reproduce the dense allocation bit-for-bit.
        use crate::agents::{AgentProfile, AgentRegistry, Priority};
        let profiles: Vec<AgentProfile> = (0..8).map(|i| AgentProfile {
            name: format!("a{i}"),
            model_mb: 500,
            base_tput: 40.0,
            // Only the two active agents hold reservations.
            min_gpu: if i == 2 || i == 5 { 0.2 } else { 0.0 },
            priority: Priority::Medium,
        }).collect();
        let reg = AgentRegistry::new(profiles).unwrap();
        let mut rates = vec![0.0; 8];
        rates[2] = 60.0;
        rates[5] = 25.0;
        let queues = vec![0.0; 8];
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &queues,
            step: 0,
            capacity: 1.0,
        };
        let mut dense = vec![0.0; 8];
        AdaptivePolicy::default().allocate(&ctx, &mut dense);
        let mut sparse = vec![0.0; 8];
        AdaptivePolicy::default()
            .allocate_active(&ctx, &[2, 5], &mut sparse);
        assert_eq!(dense, sparse);
        // All-idle active subset: the short-circuit zeroes only the
        // active entries, which is all the dense fill(0.0) would change.
        let zero = vec![0.0; 8];
        let idle_ctx = AllocContext {
            registry: &reg,
            arrival_rates: &zero,
            queue_depths: &queues,
            step: 1,
            capacity: 1.0,
        };
        let mut dense_idle = vec![0.0; 8];
        AdaptivePolicy::default().allocate(&idle_ctx, &mut dense_idle);
        let mut sparse_idle = vec![0.0; 8];
        AdaptivePolicy::default()
            .allocate_active(&idle_ctx, &[2, 5], &mut sparse_idle);
        assert_eq!(dense_idle, sparse_idle);
    }

    #[test]
    fn single_active_agent_respects_other_floors() {
        let g = alloc_for(&[0.0, 0.0, 100.0, 0.0]);
        // Idle agents still get their minimum floor (no starvation on
        // reactivation), active agent gets the rest.
        assert!(g[2] > g[0] && g[2] > g[1] && g[2] > g[3]);
        let total: f64 = g.iter().sum();
        assert!(total <= 1.0 + 1e-9);
    }
}
