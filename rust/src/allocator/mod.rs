//! GPU-fraction allocation policies — the paper's contribution (§III).
//!
//! The central abstraction is [`AllocationPolicy`]: given the current
//! workload observation (arrival rates, queue depths) and the static agent
//! registry, write a GPU fraction per agent into a caller-provided buffer.
//! Policies are `&mut self` so stateful strategies (round-robin rotation,
//! EMA predictors) work without interior mutability, and the buffer is
//! caller-owned so the per-step hot path allocates nothing.
//!
//! Implemented policies:
//!
//! * [`AdaptivePolicy`] — the paper's Algorithm 1 (demand-proportional with
//!   priority weighting, minimum-floor enforcement, and capacity
//!   normalization). O(N), allocation-free.
//! * [`StaticEqualPolicy`] — baseline: capacity / N for every agent.
//! * [`RoundRobinPolicy`] — baseline: 100 % of the GPU to one agent per
//!   step, rotating ("100 % sequential" in §IV.A).
//! * [`PredictivePolicy`] — extension (paper §VI future work): Algorithm 1
//!   driven by an EMA forecast of arrival rates instead of the instant
//!   observation.
//! * [`FeedbackPolicy`] — extension: demand augmented with a queue-depth
//!   backpressure term, so backlog drains faster after bursts.

mod adaptive;
mod feedback;
mod predictive;
mod round_robin;
mod static_equal;

pub use adaptive::AdaptivePolicy;
pub use feedback::FeedbackPolicy;
pub use predictive::PredictivePolicy;
pub use round_robin::RoundRobinPolicy;
pub use static_equal::StaticEqualPolicy;

use crate::agents::AgentRegistry;

/// Everything a policy may observe when allocating for one timestep.
#[derive(Debug)]
pub struct AllocContext<'a> {
    /// Static agent characteristics (Table I).
    pub registry: &'a AgentRegistry,
    /// Observed arrival rate per agent over the last step (λ_i(t), rps).
    pub arrival_rates: &'a [f64],
    /// Current queue depth per agent (requests waiting).
    pub queue_depths: &'a [f64],
    /// Discrete timestep index.
    pub step: u64,
    /// Total GPU capacity to distribute (the paper normalizes to 1.0).
    pub capacity: f64,
}

/// A GPU-fraction allocation policy.
pub trait AllocationPolicy: Send {
    /// Stable identifier used in reports and CSV output.
    fn name(&self) -> &'static str;

    /// Write one GPU fraction per agent into `out`.
    ///
    /// Contract (checked by the proptest suite for every implementation):
    /// `out.len() == registry.len()`, every `out[i] >= 0`, and
    /// `Σ out[i] <= capacity + ε`.
    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]);

    /// Reset any internal state (rotation counters, EMA history) so a
    /// policy instance can be reused across independent runs.
    fn reset(&mut self) {}
}

/// Scale `out` in place so it sums to at most `capacity` (Algorithm 1's
/// normalization phase). No-op when already within capacity or all-zero.
pub fn normalize_to_capacity(out: &mut [f64], capacity: f64) {
    let total: f64 = out.iter().sum();
    if total > capacity && total > 0.0 {
        let scale = capacity / total;
        for g in out.iter_mut() {
            *g *= scale;
        }
    }
}

/// Construct every policy this crate ships, for comparison harnesses.
pub fn all_policies() -> Vec<Box<dyn AllocationPolicy>> {
    vec![
        Box::new(StaticEqualPolicy),
        Box::new(RoundRobinPolicy::default()),
        Box::new(AdaptivePolicy::default()),
        Box::new(PredictivePolicy::default()),
        Box::new(FeedbackPolicy::default()),
    ]
}

/// Construct a policy by its CLI/report name.
pub fn policy_by_name(name: &str) -> Option<Box<dyn AllocationPolicy>> {
    match name {
        "static" | "static_equal" => Some(Box::new(StaticEqualPolicy)),
        "round_robin" | "rr" => Some(Box::new(RoundRobinPolicy::default())),
        "adaptive" => Some(Box::new(AdaptivePolicy::default())),
        "predictive" => Some(Box::new(PredictivePolicy::default())),
        "feedback" => Some(Box::new(FeedbackPolicy::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_scales_only_when_over() {
        let mut g = vec![0.5, 0.5, 0.5];
        normalize_to_capacity(&mut g, 1.0);
        let total: f64 = g.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        // Relative proportions preserved.
        assert!((g[0] - g[1]).abs() < 1e-12);

        let mut h = vec![0.2, 0.3];
        normalize_to_capacity(&mut h, 1.0);
        assert_eq!(h, vec![0.2, 0.3]); // under capacity: untouched

        let mut z = vec![0.0, 0.0];
        normalize_to_capacity(&mut z, 1.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn policy_by_name_resolves_aliases() {
        for n in ["static", "static_equal", "rr", "round_robin", "adaptive",
                  "predictive", "feedback"] {
            assert!(policy_by_name(n).is_some(), "{n}");
        }
        assert!(policy_by_name("nope").is_none());
    }

    #[test]
    fn all_policies_have_unique_names() {
        let ps = all_policies();
        let mut names: Vec<&str> = ps.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ps.len());
    }
}
