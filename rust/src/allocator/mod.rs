//! GPU-fraction allocation policies — the paper's contribution (§III).
//!
//! The central abstraction is [`AllocationPolicy`]: given the current
//! workload observation (arrival rates, queue depths) and the static agent
//! registry, write a GPU fraction per agent into a caller-provided buffer.
//! Policies are `&mut self` so stateful strategies (round-robin rotation,
//! EMA predictors) work without interior mutability, and the buffer is
//! caller-owned so the per-step hot path allocates nothing.
//!
//! Implemented policies:
//!
//! * [`AdaptivePolicy`] — the paper's Algorithm 1 (demand-proportional with
//!   priority weighting, minimum-floor enforcement, and capacity
//!   normalization). O(N), allocation-free.
//! * [`StaticEqualPolicy`] — baseline: capacity / N for every agent.
//! * [`RoundRobinPolicy`] — baseline: 100 % of the GPU to one agent per
//!   step, rotating ("100 % sequential" in §IV.A).
//! * [`PredictivePolicy`] — extension (paper §VI future work): Algorithm 1
//!   driven by an EMA forecast of arrival rates instead of the instant
//!   observation.
//! * [`FeedbackPolicy`] — extension: demand augmented with a queue-depth
//!   backpressure term, so backlog drains faster after bursts.
//! * [`CriticalPathPolicy`] — extension for workflow-DAG workloads:
//!   Algorithm 1 with demand boosted by each agent's share of the DAG's
//!   critical path, so end-to-end workflow latency — not just per-agent
//!   latency — drives the split.

mod adaptive;
mod critical_path;
mod feedback;
mod predictive;
mod round_robin;
mod static_equal;

pub use adaptive::AdaptivePolicy;
pub use critical_path::CriticalPathPolicy;
pub use feedback::FeedbackPolicy;
pub use predictive::PredictivePolicy;
pub use round_robin::RoundRobinPolicy;
pub use static_equal::StaticEqualPolicy;

use crate::agents::AgentRegistry;

/// Everything a policy may observe when allocating for one timestep.
#[derive(Debug)]
pub struct AllocContext<'a> {
    /// Static agent characteristics (Table I).
    pub registry: &'a AgentRegistry,
    /// Observed arrival rate per agent over the last step (λ_i(t), rps).
    pub arrival_rates: &'a [f64],
    /// Current queue depth per agent (requests waiting).
    pub queue_depths: &'a [f64],
    /// Discrete timestep index.
    pub step: u64,
    /// Total GPU capacity to distribute (the paper normalizes to 1.0).
    pub capacity: f64,
}

/// A GPU-fraction allocation policy.
pub trait AllocationPolicy: Send {
    /// Stable identifier used in reports and CSV output.
    fn name(&self) -> &'static str;

    /// Write one GPU fraction per agent into `out`.
    ///
    /// Contract (checked by the proptest suite for every implementation):
    /// `out.len() == registry.len()`, every `out[i] >= 0`, and
    /// `Σ out[i] <= capacity + ε`.
    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]);

    /// Reset any internal state (rotation counters, EMA history) so a
    /// policy instance can be reused across independent runs.
    fn reset(&mut self) {}

    /// Skip-idle contract: return `true` only when, for `n` agents with
    /// **all-zero** arrival rates and queue depths, calling
    /// [`AllocationPolicy::allocate`] any number of times would (a)
    /// write all zeros and (b) leave the policy's internal state
    /// bit-identical — i.e. the zero-demand step is a fixed point. The
    /// simulation engines use this to fast-forward provably-idle
    /// windows without invoking the policy; a policy that allocates
    /// nonzero fractions at zero demand (static-equal) or mutates state
    /// per call (round-robin's rotation) must return `false` (the
    /// default), which simply keeps the dense path.
    fn idle_fixed_point(&self, n: usize) -> bool {
        let _ = n;
        false
    }

    /// Active-set contract, the per-agent refinement of
    /// [`AllocationPolicy::idle_fixed_point`]: return `true` only when
    /// agent `agent`, observed with **zero own arrival rate and zero own
    /// queue depth**, is allocated exactly `+0.0` by every
    /// [`AllocationPolicy::allocate`] call — regardless of the other
    /// agents' state — and contributes exactly `+0.0` to every internal
    /// aggregate the policy folds over agents (so iterating only the
    /// active subset reproduces the dense fold bit-for-bit).
    ///
    /// "Unchanged inputs ⇒ unchanged allocation" must hold in the
    /// strongest sense: the answer may depend on the policy's current
    /// internal state (predictive requires its EMA entry to be exactly
    /// zero) and on static registry data in `ctx` (the adaptive family
    /// requires a zero `min_gpu` floor — a floored idle agent is held at
    /// its nonzero minimum whenever anyone else has demand), but never
    /// on the other agents' dynamic inputs. Globally-coupled policies —
    /// round-robin's rotation, static-equal's held `capacity / n` —
    /// must return `false` (the default), which keeps them on the
    /// documented dense fallback.
    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        let _ = (ctx, agent);
        false
    }

    /// Allocate touching only the agents in `active` (sorted ascending,
    /// deduplicated). The caller guarantees that every agent *not* in
    /// `active` (a) satisfies [`AllocationPolicy::zero_fixed_point`],
    /// (b) shows zero arrival rate and zero queue depth in `ctx`, and
    /// (c) already holds exactly `0.0` in `out`. Under that contract
    /// the default implementation — a full dense
    /// [`AllocationPolicy::allocate`] — is always correct (it rewrites
    /// the settled agents' `+0.0` with the same bits); sparse overrides
    /// are pure optimizations and must stay bit-identical to it.
    fn allocate_active(&mut self, ctx: &AllocContext<'_>,
                       active: &[usize], out: &mut [f64]) {
        let _ = active;
        self.allocate(ctx, out);
    }
}

/// Forwarding impl so a borrowed policy can drive engines that take the
/// policy by value (the serving core owns its policy; `Simulator`-style
/// callers hold `&mut P`).
impl<P: AllocationPolicy + ?Sized> AllocationPolicy for &mut P {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        (**self).allocate(ctx, out)
    }

    fn reset(&mut self) {
        (**self).reset()
    }

    fn idle_fixed_point(&self, n: usize) -> bool {
        (**self).idle_fixed_point(n)
    }

    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        (**self).zero_fixed_point(ctx, agent)
    }

    fn allocate_active(&mut self, ctx: &AllocContext<'_>,
                       active: &[usize], out: &mut [f64]) {
        (**self).allocate_active(ctx, active, out)
    }
}

/// Forwarding impl for boxed policies, so `Box<dyn AllocationPolicy>`
/// (the `policy_by_name` return type) is itself a policy.
impl<P: AllocationPolicy + ?Sized> AllocationPolicy for Box<P> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        (**self).allocate(ctx, out)
    }

    fn reset(&mut self) {
        (**self).reset()
    }

    fn idle_fixed_point(&self, n: usize) -> bool {
        (**self).idle_fixed_point(n)
    }

    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        (**self).zero_fixed_point(ctx, agent)
    }

    fn allocate_active(&mut self, ctx: &AllocContext<'_>,
                       active: &[usize], out: &mut [f64]) {
        (**self).allocate_active(ctx, active, out)
    }
}

/// Scale `out` in place so it sums to at most `capacity` (Algorithm 1's
/// normalization phase). No-op when already within capacity or all-zero.
pub fn normalize_to_capacity(out: &mut [f64], capacity: f64) {
    let total: f64 = out.iter().sum();
    if total > capacity && total > 0.0 {
        let scale = capacity / total;
        for g in out.iter_mut() {
            *g *= scale;
        }
    }
}

/// The built-in policies as a statically-dispatched enum.
///
/// The `dyn AllocationPolicy` object path stays available for external
/// policies, but everything in-crate (the batch sweep engine, the repro
/// drivers) goes through `PolicyKind`: the per-step `allocate()` call in
/// the simulation loop becomes a direct (inlinable) match instead of a
/// virtual call, and a policy is `Clone`-able into worker threads without
/// boxing.
#[derive(Debug, Clone)]
pub enum PolicyKind {
    /// [`StaticEqualPolicy`].
    StaticEqual(StaticEqualPolicy),
    /// [`RoundRobinPolicy`].
    RoundRobin(RoundRobinPolicy),
    /// [`AdaptivePolicy`] — the paper's Algorithm 1.
    Adaptive(AdaptivePolicy),
    /// [`PredictivePolicy`].
    Predictive(PredictivePolicy),
    /// [`FeedbackPolicy`].
    Feedback(FeedbackPolicy),
    /// [`CriticalPathPolicy`] — DAG-critical-path-aware extension.
    CriticalPath(CriticalPathPolicy),
}

impl PolicyKind {
    /// Fresh static-equal baseline.
    pub fn static_equal() -> PolicyKind {
        PolicyKind::StaticEqual(StaticEqualPolicy)
    }

    /// Fresh round-robin baseline.
    pub fn round_robin() -> PolicyKind {
        PolicyKind::RoundRobin(RoundRobinPolicy::default())
    }

    /// Fresh Algorithm 1 instance.
    pub fn adaptive() -> PolicyKind {
        PolicyKind::Adaptive(AdaptivePolicy::default())
    }

    /// Fresh EMA-predictive extension.
    pub fn predictive() -> PolicyKind {
        PolicyKind::Predictive(PredictivePolicy::default())
    }

    /// Fresh queue-feedback extension.
    pub fn feedback() -> PolicyKind {
        PolicyKind::Feedback(FeedbackPolicy::default())
    }

    /// Fresh unweighted critical-path policy (identical to adaptive
    /// until weighted for a workflow spec).
    pub fn critical_path() -> PolicyKind {
        PolicyKind::CriticalPath(CriticalPathPolicy::default())
    }

    /// Critical-path policy weighted for `spec` on `n_agents` agents.
    pub fn critical_path_for(spec: &crate::workload::WorkflowSpec,
                             n_agents: usize) -> PolicyKind {
        PolicyKind::CriticalPath(
            CriticalPathPolicy::for_workflow(spec, n_agents))
    }

    /// Every built-in policy, in the same order as [`all_policies`].
    pub fn all() -> Vec<PolicyKind> {
        vec![
            PolicyKind::static_equal(),
            PolicyKind::round_robin(),
            PolicyKind::adaptive(),
            PolicyKind::predictive(),
            PolicyKind::feedback(),
            PolicyKind::critical_path(),
        ]
    }

    /// Resolve a CLI/report name (same aliases as [`policy_by_name`]).
    pub fn by_name(name: &str) -> Option<PolicyKind> {
        match name {
            "static" | "static_equal" => Some(PolicyKind::static_equal()),
            "round_robin" | "rr" => Some(PolicyKind::round_robin()),
            "adaptive" => Some(PolicyKind::adaptive()),
            "predictive" => Some(PolicyKind::predictive()),
            "feedback" => Some(PolicyKind::feedback()),
            "critical_path" | "cp" => Some(PolicyKind::critical_path()),
            _ => None,
        }
    }

    /// Stable identifier (inherent so callers need no trait import).
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::StaticEqual(p) => p.name(),
            PolicyKind::RoundRobin(p) => p.name(),
            PolicyKind::Adaptive(p) => p.name(),
            PolicyKind::Predictive(p) => p.name(),
            PolicyKind::Feedback(p) => p.name(),
            PolicyKind::CriticalPath(p) => p.name(),
        }
    }
}

impl AllocationPolicy for PolicyKind {
    fn name(&self) -> &'static str {
        PolicyKind::name(self)
    }

    fn allocate(&mut self, ctx: &AllocContext<'_>, out: &mut [f64]) {
        match self {
            PolicyKind::StaticEqual(p) => p.allocate(ctx, out),
            PolicyKind::RoundRobin(p) => p.allocate(ctx, out),
            PolicyKind::Adaptive(p) => p.allocate(ctx, out),
            PolicyKind::Predictive(p) => p.allocate(ctx, out),
            PolicyKind::Feedback(p) => p.allocate(ctx, out),
            PolicyKind::CriticalPath(p) => p.allocate(ctx, out),
        }
    }

    fn reset(&mut self) {
        match self {
            PolicyKind::StaticEqual(p) => p.reset(),
            PolicyKind::RoundRobin(p) => p.reset(),
            PolicyKind::Adaptive(p) => p.reset(),
            PolicyKind::Predictive(p) => p.reset(),
            PolicyKind::Feedback(p) => p.reset(),
            PolicyKind::CriticalPath(p) => p.reset(),
        }
    }

    fn idle_fixed_point(&self, n: usize) -> bool {
        match self {
            PolicyKind::StaticEqual(p) => p.idle_fixed_point(n),
            PolicyKind::RoundRobin(p) => p.idle_fixed_point(n),
            PolicyKind::Adaptive(p) => p.idle_fixed_point(n),
            PolicyKind::Predictive(p) => p.idle_fixed_point(n),
            PolicyKind::Feedback(p) => p.idle_fixed_point(n),
            PolicyKind::CriticalPath(p) => p.idle_fixed_point(n),
        }
    }

    fn zero_fixed_point(&self, ctx: &AllocContext<'_>, agent: usize)
                        -> bool {
        match self {
            PolicyKind::StaticEqual(p) => p.zero_fixed_point(ctx, agent),
            PolicyKind::RoundRobin(p) => p.zero_fixed_point(ctx, agent),
            PolicyKind::Adaptive(p) => p.zero_fixed_point(ctx, agent),
            PolicyKind::Predictive(p) => p.zero_fixed_point(ctx, agent),
            PolicyKind::Feedback(p) => p.zero_fixed_point(ctx, agent),
            PolicyKind::CriticalPath(p) => p.zero_fixed_point(ctx, agent),
        }
    }

    fn allocate_active(&mut self, ctx: &AllocContext<'_>,
                       active: &[usize], out: &mut [f64]) {
        match self {
            PolicyKind::StaticEqual(p) => p.allocate_active(ctx, active, out),
            PolicyKind::RoundRobin(p) => p.allocate_active(ctx, active, out),
            PolicyKind::Adaptive(p) => p.allocate_active(ctx, active, out),
            PolicyKind::Predictive(p) => p.allocate_active(ctx, active, out),
            PolicyKind::Feedback(p) => p.allocate_active(ctx, active, out),
            PolicyKind::CriticalPath(p) => {
                p.allocate_active(ctx, active, out)
            }
        }
    }
}

/// Construct every policy this crate ships, for comparison harnesses.
///
/// Delegates to [`PolicyKind::all`] so the policy list is maintained in
/// exactly one place; the boxes dispatch through the enum.
pub fn all_policies() -> Vec<Box<dyn AllocationPolicy>> {
    PolicyKind::all().into_iter()
        .map(|kind| Box::new(kind) as Box<dyn AllocationPolicy>)
        .collect()
}

/// Construct a policy by its CLI/report name (aliases in
/// [`PolicyKind::by_name`]).
pub fn policy_by_name(name: &str) -> Option<Box<dyn AllocationPolicy>> {
    PolicyKind::by_name(name)
        .map(|kind| Box::new(kind) as Box<dyn AllocationPolicy>)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_scales_only_when_over() {
        let mut g = vec![0.5, 0.5, 0.5];
        normalize_to_capacity(&mut g, 1.0);
        let total: f64 = g.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        // Relative proportions preserved.
        assert!((g[0] - g[1]).abs() < 1e-12);

        let mut h = vec![0.2, 0.3];
        normalize_to_capacity(&mut h, 1.0);
        assert_eq!(h, vec![0.2, 0.3]); // under capacity: untouched

        let mut z = vec![0.0, 0.0];
        normalize_to_capacity(&mut z, 1.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn policy_by_name_resolves_aliases() {
        for n in ["static", "static_equal", "rr", "round_robin", "adaptive",
                  "predictive", "feedback", "critical_path", "cp"] {
            assert!(policy_by_name(n).is_some(), "{n}");
        }
        assert!(policy_by_name("nope").is_none());
    }

    #[test]
    fn all_policies_have_unique_names() {
        let ps = all_policies();
        let mut names: Vec<&str> = ps.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ps.len());
    }

    #[test]
    fn policy_kind_mirrors_dyn_registry() {
        // Same count, same names, same order, same alias resolution.
        let kinds = PolicyKind::all();
        let boxed = all_policies();
        assert_eq!(kinds.len(), boxed.len());
        for (k, b) in kinds.iter().zip(&boxed) {
            assert_eq!(k.name(), b.name());
        }
        for n in ["static", "static_equal", "rr", "round_robin", "adaptive",
                  "predictive", "feedback", "critical_path", "cp"] {
            assert_eq!(PolicyKind::by_name(n).is_some(),
                       policy_by_name(n).is_some(), "{n}");
        }
        assert!(PolicyKind::by_name("nope").is_none());
    }

    #[test]
    fn idle_fixed_point_claims_are_honest() {
        // For every policy claiming `idle_fixed_point`, a zero-demand
        // allocate must (a) write all zeros and (b) leave the policy in a
        // state that produces bit-identical output on the next live step
        // as a clone that never saw the idle steps. That is exactly the
        // license the skip-idle engines rely on.
        let reg = AgentRegistry::paper();
        let zero = [0.0; 4];
        let live = [80.0, 40.0, 45.0, 25.0];
        for mut kind in PolicyKind::all() {
            // Warm Predictive onto its zero-EMA fixed point first; the
            // claim is allowed to be state-dependent.
            let warm_ctx = AllocContext {
                registry: &reg,
                arrival_rates: &zero,
                queue_depths: &zero,
                step: 0,
                capacity: 1.0,
            };
            let mut buf = vec![0.0; 4];
            kind.allocate(&warm_ctx, &mut buf);
            if !kind.idle_fixed_point(4) {
                continue; // static_equal, round_robin: dense path only
            }
            let mut skipped = kind.clone();
            for step in 1..=9 {
                let ctx = AllocContext {
                    registry: &reg,
                    arrival_rates: &zero,
                    queue_depths: &zero,
                    step,
                    capacity: 1.0,
                };
                buf.fill(7.0);
                kind.allocate(&ctx, &mut buf);
                assert_eq!(buf, vec![0.0; 4],
                           "{}: idle step wrote nonzero", kind.name());
            }
            let live_ctx = AllocContext {
                registry: &reg,
                arrival_rates: &live,
                queue_depths: &zero,
                step: 10,
                capacity: 1.0,
            };
            let mut after_idle = vec![0.0; 4];
            let mut after_skip = vec![0.0; 4];
            kind.allocate(&live_ctx, &mut after_idle);
            skipped.allocate(&live_ctx, &mut after_skip);
            assert_eq!(after_idle, after_skip,
                       "{}: idle steps perturbed state", kind.name());
        }
        // The claims themselves, pinned: exactly adaptive, feedback, and
        // critical-path (and predictive once seeded) may be skipped.
        assert!(!PolicyKind::static_equal().idle_fixed_point(4));
        assert!(!PolicyKind::round_robin().idle_fixed_point(4));
        assert!(PolicyKind::adaptive().idle_fixed_point(4));
        assert!(PolicyKind::feedback().idle_fixed_point(4));
        assert!(!PolicyKind::predictive().idle_fixed_point(4));
        assert!(PolicyKind::critical_path().idle_fixed_point(4));
    }

    #[test]
    fn zero_fixed_point_claims_are_honest() {
        // For every policy claiming the per-agent fixed point for some
        // idle agent, a dense allocate with the OTHER agents live must
        // write exactly +0.0 for the claimed agent — that is the license
        // the active-set engines rely on when they stop iterating it.
        use crate::agents::{AgentProfile, Priority};
        let profiles: Vec<AgentProfile> = (0..6).map(|i| AgentProfile {
            name: format!("a{i}"),
            model_mb: 1000,
            base_tput: 50.0,
            // Agents 1 and 4 hold reservations; the rest scale to zero.
            min_gpu: if i == 1 || i == 4 { 0.15 } else { 0.0 },
            priority: Priority::Medium,
        }).collect();
        let reg = AgentRegistry::new(profiles).unwrap();
        let n = reg.len();
        let zero = vec![0.0; n];
        // Agents 0 and 3 idle (zero floor), 2 idle but that is
        // incidental; 1, 4, 5 live.
        let mut rates = vec![0.0; n];
        rates[1] = 30.0;
        rates[4] = 55.0;
        rates[5] = 10.0;
        for mut kind in PolicyKind::all() {
            // Warm Predictive onto its seeded zero-EMA state; the claim
            // is allowed to be state-dependent.
            let warm_ctx = AllocContext {
                registry: &reg,
                arrival_rates: &zero,
                queue_depths: &zero,
                step: 0,
                capacity: 1.0,
            };
            let mut buf = vec![0.0; n];
            kind.allocate(&warm_ctx, &mut buf);
            let ctx = AllocContext {
                registry: &reg,
                arrival_rates: &rates,
                queue_depths: &zero,
                step: 1,
                capacity: 1.0,
            };
            let claims: Vec<bool> =
                (0..n).map(|a| kind.zero_fixed_point(&ctx, a)).collect();
            buf.fill(7.0);
            kind.allocate(&ctx, &mut buf);
            for a in [0usize, 2, 3] {
                if claims[a] {
                    assert!(buf[a] == 0.0 && buf[a].is_sign_positive(),
                            "{}: claimed fixed point for idle agent {a} \
                             but allocated {}", kind.name(), buf[a]);
                }
            }
            // A floored idle agent must never be claimed: the floor
            // holds it at a nonzero minimum while others have demand.
            let idle_floored_ctx = AllocContext {
                registry: &reg,
                arrival_rates: &zero,
                queue_depths: &zero,
                step: 2,
                capacity: 1.0,
            };
            assert!(!kind.zero_fixed_point(&idle_floored_ctx, 1),
                    "{}: claimed a floored agent", kind.name());
        }
        // The claims themselves, pinned: the adaptive family claims
        // exactly the zero-floor agents; the globally-coupled baselines
        // claim nobody (dense fallback); predictive claims only once
        // seeded to a zero EMA.
        let ctx = AllocContext {
            registry: &reg,
            arrival_rates: &rates,
            queue_depths: &zero,
            step: 0,
            capacity: 1.0,
        };
        assert!(!PolicyKind::static_equal().zero_fixed_point(&ctx, 0));
        assert!(!PolicyKind::round_robin().zero_fixed_point(&ctx, 0));
        assert!(PolicyKind::adaptive().zero_fixed_point(&ctx, 0));
        assert!(PolicyKind::feedback().zero_fixed_point(&ctx, 0));
        assert!(PolicyKind::critical_path().zero_fixed_point(&ctx, 0));
        assert!(!PolicyKind::adaptive().zero_fixed_point(&ctx, 1));
        assert!(!PolicyKind::predictive().zero_fixed_point(&ctx, 0),
                "fresh predictive has no EMA yet");
        let mut pred = PolicyKind::predictive();
        let mut buf = vec![0.0; n];
        pred.allocate(&AllocContext {
            registry: &reg,
            arrival_rates: &zero,
            queue_depths: &zero,
            step: 0,
            capacity: 1.0,
        }, &mut buf);
        assert!(pred.zero_fixed_point(&ctx, 0));
        assert!(!pred.zero_fixed_point(&ctx, 1), "floor still gates");
    }

    #[test]
    fn policy_kind_allocates_like_inner_policy() {
        let reg = AgentRegistry::paper();
        let rates = [80.0, 40.0, 45.0, 25.0];
        let queues = [0.0; 4];
        for (mut kind, mut boxed) in
            PolicyKind::all().into_iter().zip(all_policies())
        {
            let mut via_kind = vec![0.0; 4];
            let mut via_dyn = vec![0.0; 4];
            for step in 0..6 {
                let ctx = AllocContext {
                    registry: &reg,
                    arrival_rates: &rates,
                    queue_depths: &queues,
                    step,
                    capacity: 1.0,
                };
                kind.allocate(&ctx, &mut via_kind);
                boxed.allocate(&ctx, &mut via_dyn);
                assert_eq!(via_kind, via_dyn, "{} step {step}",
                           kind.name());
            }
            // reset() must restart stateful policies identically.
            kind.reset();
            boxed.reset();
            let ctx = AllocContext {
                registry: &reg,
                arrival_rates: &rates,
                queue_depths: &queues,
                step: 0,
                capacity: 1.0,
            };
            kind.allocate(&ctx, &mut via_kind);
            boxed.allocate(&ctx, &mut via_dyn);
            assert_eq!(via_kind, via_dyn, "{} after reset", kind.name());
        }
    }
}
