//! The PJRT inference engine: compile once, execute batches.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::{AgentManifest, Manifest};

fn xerr(context: &str, e: xla::Error) -> Error {
    Error::Xla(format!("{context}: {e}"))
}

/// One agent, loaded: parameter device buffers plus one compiled
/// executable per batch variant.
struct LoadedAgent {
    manifest: AgentManifest,
    /// Parameters uploaded once; reused by every execution (the perf-
    /// relevant choice — see EXPERIMENTS.md §Perf L3).
    param_buffers: Vec<xla::PjRtBuffer>,
    /// batch size -> compiled executable.
    executables: Vec<(usize, xla::PjRtLoadedExecutable)>,
}

/// Output of one batched forward pass.
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceOutput {
    /// Greedy next-token id per request.
    pub next_tokens: Vec<i32>,
    /// Full last-position logits, row-major (batch × vocab).
    pub logits: Vec<f32>,
    /// Vocabulary size (logits row width).
    pub vocab: usize,
    /// Batch variant actually executed (>= requested batch).
    pub executed_batch: usize,
}

/// Cumulative execution statistics.
#[derive(Debug, Clone, Default)]
pub struct ExecutionStats {
    /// Forward passes executed.
    pub executions: u64,
    /// Requests served (sum of real batch sizes).
    pub requests: u64,
    /// Padding waste: executed slots minus real requests.
    pub padded_slots: u64,
    /// Total wall time in PJRT execute calls (seconds).
    pub execute_seconds: f64,
}

/// Loads `artifacts/` and executes agent forward passes on the PJRT CPU
/// client. Not `Send` (PJRT handles are raw pointers): own it from one
/// thread — [`crate::server::Executor`] wraps it accordingly.
pub struct InferenceEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    agents: HashMap<String, LoadedAgent>,
    stats: ExecutionStats,
    /// Reusable flat token buffer (perf: the serving loop calls
    /// infer() per batch; this removes a per-call allocation).
    token_scratch: Vec<i32>,
}

impl InferenceEngine {
    /// Load every agent in the manifest: read params, upload buffers,
    /// compile all batch variants.
    pub fn load(artifacts_dir: &Path) -> Result<InferenceEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| xerr("create PJRT CPU client", e))?;

        let mut agents = HashMap::new();
        for am in &manifest.agents {
            let loaded = Self::load_agent(&client, artifacts_dir, am)?;
            agents.insert(am.name.clone(), loaded);
        }
        Ok(InferenceEngine {
            manifest,
            client,
            agents,
            stats: ExecutionStats::default(),
            token_scratch: Vec::new(),
        })
    }

    fn load_agent(client: &xla::PjRtClient, dir: &Path, am: &AgentManifest)
                  -> Result<LoadedAgent> {
        // Parameters: one flat little-endian f32 file, sliced per entry.
        let raw = std::fs::read(dir.join(&am.params_file))?;
        if raw.len() % 4 != 0 {
            return Err(Error::Artifact(format!(
                "{}: params file not f32-aligned", am.name)));
        }
        let floats: Vec<f32> = raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut param_buffers = Vec::with_capacity(am.param_entries.len());
        for entry in &am.param_entries {
            let end = entry.offset + entry.len;
            if end > floats.len() {
                return Err(Error::Artifact(format!(
                    "{}: param '{}' overruns params file",
                    am.name, entry.name)));
            }
            let buf = client.buffer_from_host_buffer::<f32>(
                &floats[entry.offset..end], &entry.shape, None)
                .map_err(|e| xerr(&format!("upload param {}", entry.name),
                                  e))?;
            param_buffers.push(buf);
        }

        let mut executables = Vec::with_capacity(am.variants.len());
        for (batch, file) in &am.variants {
            let proto = xla::HloModuleProto::from_text_file(dir.join(file))
                .map_err(|e| xerr(&format!("parse HLO {file}"), e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)
                .map_err(|e| xerr(&format!("compile {file}"), e))?;
            executables.push((*batch, exe));
        }

        Ok(LoadedAgent {
            manifest: am.clone(),
            param_buffers,
            executables,
        })
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Cumulative execution statistics.
    pub fn stats(&self) -> &ExecutionStats {
        &self.stats
    }

    /// Run one batched forward pass for `agent` (owned-row convenience;
    /// see [`InferenceEngine::infer_rows`] for the zero-copy hot path).
    pub fn infer(&mut self, agent: &str, token_rows: &[Vec<i32>])
                 -> Result<InferenceOutput> {
        let refs: Vec<&[i32]> =
            token_rows.iter().map(Vec::as_slice).collect();
        self.infer_rows(agent, &refs)
    }

    /// Run one batched forward pass for `agent`.
    ///
    /// `token_rows` is one row of `seq_len` token ids per request (1 to
    /// max-batch rows), borrowed — the serving loop passes queue-owned
    /// slices without cloning. The engine picks the smallest compiled
    /// variant that fits, pads with the last row, executes, and returns
    /// only the real rows' outputs.
    pub fn infer_rows(&mut self, agent: &str, token_rows: &[&[i32]])
                      -> Result<InferenceOutput> {
        // Split-borrow the engine so the scratch buffer and the agent
        // table can be used simultaneously.
        let Self { manifest, client, agents, stats, token_scratch } =
            self;
        let seq = manifest.seq_len;
        let la = agents.get(agent).ok_or_else(|| Error::Serving(
            format!("unknown agent '{agent}'")))?;
        if token_rows.is_empty() {
            return Err(Error::Serving("empty batch".into()));
        }
        let n = token_rows.len();
        let max_batch = la.manifest.max_batch();
        if n > max_batch {
            return Err(Error::Serving(format!(
                "batch {n} exceeds max compiled variant {max_batch}")));
        }
        for (i, row) in token_rows.iter().enumerate() {
            if row.len() != seq {
                return Err(Error::Serving(format!(
                    "request {i}: expected {seq} tokens, got {}",
                    row.len())));
            }
            let vocab = la.manifest.vocab as i32;
            if row.iter().any(|t| *t < 0 || *t >= vocab) {
                return Err(Error::Serving(format!(
                    "request {i}: token id out of range [0, {vocab})")));
            }
        }

        let batch = la.manifest.variant_for(n);
        let exe = la.executables.iter()
            .find(|(b, _)| *b == batch)
            .map(|(_, e)| e)
            .ok_or_else(|| Error::Serving(format!(
                "no executable for batch {batch}")))?;

        // Flatten + pad with the last real row, into the reusable
        // scratch buffer (no per-call allocation once warm).
        let flat = token_scratch;
        flat.clear();
        flat.reserve(batch * seq);
        for row in token_rows {
            flat.extend_from_slice(row);
        }
        let last = token_rows.last().expect("nonempty");
        for _ in n..batch {
            flat.extend_from_slice(last);
        }
        let token_buf = client
            .buffer_from_host_buffer::<i32>(flat, &[batch, seq], None)
            .map_err(|e| xerr("upload tokens", e))?;

        // Argument order matches aot.py's fn(params, tokens) flattening:
        // params in manifest order, then tokens.
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(la.param_buffers.len() + 1);
        args.extend(la.param_buffers.iter());
        args.push(&token_buf);

        let start = Instant::now();
        let result = exe.execute_b(&args)
            .map_err(|e| xerr(&format!("execute {agent} b{batch}"), e))?;
        let elapsed = start.elapsed().as_secs_f64();

        let out = result[0][0].to_literal_sync()
            .map_err(|e| xerr("fetch output", e))?;
        // aot.py lowers with return_tuple=True: (next_token, logits).
        let (next_lit, logits_lit) = out.to_tuple2()
            .map_err(|e| xerr("untuple output", e))?;
        let mut next_tokens = next_lit.to_vec::<i32>()
            .map_err(|e| xerr("read next tokens", e))?;
        let mut logits = logits_lit.to_vec::<f32>()
            .map_err(|e| xerr("read logits", e))?;
        let vocab = la.manifest.vocab;
        next_tokens.truncate(n);
        logits.truncate(n * vocab);

        stats.executions += 1;
        stats.requests += n as u64;
        stats.padded_slots += (batch - n) as u64;
        stats.execute_seconds += elapsed;

        Ok(InferenceOutput {
            next_tokens,
            logits,
            vocab,
            executed_batch: batch,
        })
    }

    /// Run every agent's golden test vector; returns (agent, batch) pairs
    /// verified. Used by integration tests and `agentsrv verify`.
    pub fn verify_golden(&mut self) -> Result<Vec<(String, usize)>> {
        let mut verified = Vec::new();
        let agents: Vec<String> =
            self.manifest.agents.iter().map(|a| a.name.clone()).collect();
        for name in agents {
            let (vocab, vectors, seq) = {
                let am = self.manifest.agent(&name).expect("agent exists");
                (am.vocab, am.test_vectors.clone(), self.manifest.seq_len)
            };
            for tv in vectors {
                let rows: Vec<Vec<i32>> = (0..tv.batch).map(|b| {
                    (0..seq).map(|i| {
                        (((b * seq + i) as i64 * 7 + 3)
                         % vocab as i64) as i32
                    }).collect()
                }).collect();
                let out = self.infer(&name, &rows)?;
                if out.next_tokens != tv.expected_next {
                    return Err(Error::Artifact(format!(
                        "{name} b{}: next tokens {:?} != golden {:?}",
                        tv.batch, out.next_tokens, tv.expected_next)));
                }
                let l2: f64 = out.logits.iter()
                    .map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
                let rel = (l2 - tv.logits_l2).abs() / tv.logits_l2.max(1e-9);
                if rel > 1e-3 {
                    return Err(Error::Artifact(format!(
                        "{name} b{}: logits L2 {l2} != golden {} \
                         (rel err {rel})", tv.batch, tv.logits_l2)));
                }
                verified.push((name.clone(), tv.batch));
            }
        }
        Ok(verified)
    }
}
