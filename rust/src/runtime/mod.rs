//! PJRT runtime: load the AOT artifacts and execute agent models natively.
//!
//! The build-time pipeline (`python/compile/aot.py`) lowers each agent's
//! JAX forward pass (which calls the Pallas kernels) to **HLO text** under
//! `artifacts/`, plus a `manifest.json` and per-agent `*.params.bin`. This
//! module is the request-path half: parse the manifest ([`Manifest`]), load
//! params once as device buffers, compile one PJRT executable per
//! (agent, batch-size) variant, and execute batches ([`InferenceEngine`]).
//!
//! HLO *text* is the interchange format because the image's xla_extension
//! 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT handles are raw C pointers (not `Send`), so the serving stack runs
//! the engine on a dedicated executor thread (see [`crate::server`]) — which
//! also happens to model the serialized GPU command queue faithfully.

mod engine;
mod manifest;

pub use engine::{ExecutionStats, InferenceEngine, InferenceOutput};
pub use manifest::{AgentManifest, Manifest, ParamEntry, TestVector};
