//! The AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; parsed here with the in-tree JSON
//! module. The manifest fully describes each agent: model hyperparameters,
//! Table I characteristics, parameter layout (name/shape/offset into the
//! params.bin), HLO file per batch variant, FLOP estimates for the GPU
//! governor, and golden test vectors for end-to-end numeric checks.

use std::path::{Path, PathBuf};

use crate::agents::{AgentProfile, Priority};
use crate::error::{Error, Result};
use crate::util::json::Value;

/// One parameter tensor's layout inside `<agent>.params.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    /// Parameter name (e.g. "layer0.wq").
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset into the params file, in f32 elements.
    pub offset: usize,
    /// Element count.
    pub len: usize,
}

/// Golden input/output pair recorded at AOT time.
#[derive(Debug, Clone, PartialEq)]
pub struct TestVector {
    /// Batch size this vector was recorded for.
    pub batch: usize,
    /// Expected greedy next-token ids for the canonical test input.
    pub expected_next: Vec<i32>,
    /// L2 norm of the last-position logits (coarse numeric fingerprint).
    pub logits_l2: f64,
}

/// Everything the runtime needs to serve one agent.
#[derive(Debug, Clone)]
pub struct AgentManifest {
    /// Agent name.
    pub name: String,
    /// Model width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Total trainable parameters.
    pub param_count: usize,
    /// Params file name (relative to the artifacts dir).
    pub params_file: String,
    /// Parameter layout in lowering order.
    pub param_entries: Vec<ParamEntry>,
    /// batch size -> HLO text file name.
    pub variants: Vec<(usize, String)>,
    /// batch size -> estimated FLOPs per forward pass.
    pub flops_per_forward: Vec<(usize, u64)>,
    /// Golden vectors per batch size.
    pub test_vectors: Vec<TestVector>,
    /// Table I characteristics.
    pub profile: AgentProfile,
}

impl AgentManifest {
    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.variants.iter().map(|(b, _)| *b).max().unwrap_or(1)
    }

    /// Smallest compiled variant that fits `n` requests (or the largest
    /// variant if `n` exceeds all).
    pub fn variant_for(&self, n: usize) -> usize {
        self.variants.iter().map(|(b, _)| *b)
            .filter(|b| *b >= n)
            .min()
            .unwrap_or_else(|| self.max_batch())
    }

    /// HLO file for a batch size.
    pub fn hlo_file(&self, batch: usize) -> Option<&str> {
        self.variants.iter().find(|(b, _)| *b == batch)
            .map(|(_, f)| f.as_str())
    }

    /// Estimated FLOPs for one forward at `batch`.
    pub fn flops(&self, batch: usize) -> u64 {
        self.flops_per_forward.iter().find(|(b, _)| *b == batch)
            .map(|(_, f)| *f)
            .unwrap_or(0)
    }
}

/// The parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (file paths are relative).
    pub dir: PathBuf,
    /// Context window length all models were compiled for.
    pub seq_len: usize,
    /// Agents in manifest order.
    pub agents: Vec<AgentManifest>,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let seq_len = v.require("seq_len")?.as_u64()
            .ok_or_else(|| Error::Artifact("seq_len not integer".into()))?
            as usize;
        let format = v.require("format")?.as_str().unwrap_or("");
        if format != "hlo-text-v1" {
            return Err(Error::Artifact(format!(
                "unsupported artifact format '{format}'")));
        }
        let agents_obj = v.require("agents")?.as_object()
            .ok_or_else(|| Error::Artifact("agents not object".into()))?;

        let mut agents = Vec::with_capacity(agents_obj.len());
        for (name, a) in agents_obj {
            agents.push(Self::parse_agent(name, a)?);
        }
        if agents.is_empty() {
            return Err(Error::Artifact("manifest has no agents".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), seq_len, agents })
    }

    fn parse_agent(name: &str, a: &Value) -> Result<AgentManifest> {
        let usize_of = |key: &str| -> Result<usize> {
            a.require(key)?.as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| Error::Artifact(format!(
                    "agent '{name}': '{key}' not an integer")))
        };
        let f64_of = |key: &str| -> Result<f64> {
            a.require(key)?.as_f64().ok_or_else(|| Error::Artifact(
                format!("agent '{name}': '{key}' not a number")))
        };

        let entries = a.require("param_entries")?.as_array()
            .ok_or_else(|| Error::Artifact("param_entries not array".into()))?
            .iter().map(|e| {
                Ok(ParamEntry {
                    name: e.require("name")?.as_str().unwrap_or("").into(),
                    shape: e.require("shape")?.as_array()
                        .ok_or_else(|| Error::Artifact(
                            "shape not array".into()))?
                        .iter()
                        .map(|d| d.as_u64().map(|x| x as usize)
                             .ok_or_else(|| Error::Artifact(
                                 "bad shape dim".into())))
                        .collect::<Result<Vec<_>>>()?,
                    offset: e.require("offset")?.as_u64().unwrap_or(0)
                        as usize,
                    len: e.require("len")?.as_u64().unwrap_or(0) as usize,
                })
            }).collect::<Result<Vec<_>>>()?;

        let mut variants: Vec<(usize, String)> = a.require("variants")?
            .as_object()
            .ok_or_else(|| Error::Artifact("variants not object".into()))?
            .iter().map(|(b, f)| {
                let batch = b.parse::<usize>().map_err(|_| Error::Artifact(
                    format!("bad batch key '{b}'")))?;
                let file = f.as_str().ok_or_else(|| Error::Artifact(
                    "variant file not string".into()))?;
                Ok((batch, file.to_string()))
            }).collect::<Result<Vec<_>>>()?;
        variants.sort_unstable_by_key(|(b, _)| *b);
        if variants.is_empty() {
            return Err(Error::Artifact(format!(
                "agent '{name}' has no compiled variants")));
        }

        let flops = match a.get("flops_per_forward") {
            Some(f) => f.as_object().unwrap_or(&[]).iter()
                .filter_map(|(b, v)| {
                    Some((b.parse::<usize>().ok()?, v.as_u64()?))
                }).collect(),
            None => Vec::new(),
        };

        let vectors = match a.get("test_vectors") {
            Some(tv) => tv.as_object().unwrap_or(&[]).iter().map(|(b, v)| {
                Ok(TestVector {
                    batch: b.parse::<usize>().map_err(|_| Error::Artifact(
                        format!("bad test vector batch '{b}'")))?,
                    expected_next: v.require("expected_next")?.as_array()
                        .unwrap_or(&[])
                        .iter().filter_map(|x| x.as_f64())
                        .map(|x| x as i32).collect(),
                    logits_l2: v.require("logits_l2")?.as_f64()
                        .unwrap_or(0.0),
                })
            }).collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };

        let priority = Priority::try_from(usize_of("priority")? as u8)
            .map_err(Error::Artifact)?;
        let profile = AgentProfile {
            name: name.to_string(),
            model_mb: usize_of("model_mb")? as u32,
            base_tput: f64_of("base_tput")?,
            min_gpu: f64_of("min_gpu")?,
            priority,
        };

        Ok(AgentManifest {
            name: name.to_string(),
            d_model: usize_of("d_model")?,
            n_layers: usize_of("n_layers")?,
            n_heads: usize_of("n_heads")?,
            d_ff: usize_of("d_ff")?,
            vocab: usize_of("vocab")?,
            param_count: usize_of("param_count")?,
            params_file: a.require("params_file")?.as_str()
                .unwrap_or("").to_string(),
            param_entries: entries,
            variants,
            flops_per_forward: flops,
            test_vectors: vectors,
            profile,
        })
    }

    /// Agent entry by name.
    pub fn agent(&self, name: &str) -> Option<&AgentManifest> {
        self.agents.iter().find(|a| a.name == name)
    }

    /// Profiles of all agents (for building a registry).
    pub fn profiles(&self) -> Vec<AgentProfile> {
        self.agents.iter().map(|a| a.profile.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_text() -> &'static str {
        r#"{
          "seq_len": 32, "format": "hlo-text-v1",
          "agents": {
            "coordinator": {
              "d_model": 64, "n_layers": 2, "n_heads": 2, "d_ff": 128,
              "vocab": 256, "model_mb": 500, "base_tput": 100.0,
              "min_gpu": 0.1, "priority": 1, "param_count": 84992,
              "params_file": "coordinator.params.bin",
              "param_entries": [
                {"name": "embed", "shape": [256, 64], "offset": 0,
                 "len": 16384}],
              "variants": {"1": "coordinator_b1.hlo.txt",
                           "4": "coordinator_b4.hlo.txt",
                           "2": "coordinator_b2.hlo.txt"},
              "flops_per_forward": {"1": 5439488, "2": 10878976,
                                    "4": 21757952},
              "test_vectors": {"1": {"expected_next": [42],
                                     "logits_l2": 11.25}}
            }
          }
        }"#
    }

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(sample_text(), Path::new("/tmp/x")).unwrap();
        assert_eq!(m.seq_len, 32);
        assert_eq!(m.agents.len(), 1);
        let a = m.agent("coordinator").unwrap();
        assert_eq!(a.param_entries[0].len, 16384);
        assert_eq!(a.profile.base_tput, 100.0);
        assert_eq!(a.profile.priority, Priority::High);
        // Variants sorted by batch.
        assert_eq!(a.variants.iter().map(|(b, _)| *b).collect::<Vec<_>>(),
                   vec![1, 2, 4]);
        assert_eq!(a.test_vectors[0].expected_next, vec![42]);
        assert_eq!(a.flops(2), 10878976);
        assert!(m.agent("nope").is_none());
    }

    #[test]
    fn variant_selection() {
        let m = Manifest::parse(sample_text(), Path::new("/tmp/x")).unwrap();
        let a = m.agent("coordinator").unwrap();
        assert_eq!(a.variant_for(1), 1);
        assert_eq!(a.variant_for(2), 2);
        assert_eq!(a.variant_for(3), 4);
        assert_eq!(a.variant_for(4), 4);
        assert_eq!(a.variant_for(99), 4); // saturates at max batch
        assert_eq!(a.max_batch(), 4);
        assert_eq!(a.hlo_file(2), Some("coordinator_b2.hlo.txt"));
        assert_eq!(a.hlo_file(3), None);
    }

    #[test]
    fn rejects_wrong_format_or_missing_fields() {
        let bad = r#"{"seq_len": 32, "format": "other", "agents": {}}"#;
        assert!(Manifest::parse(bad, Path::new("/tmp")).is_err());
        let empty = r#"{"seq_len": 32, "format": "hlo-text-v1",
                        "agents": {}}"#;
        assert!(Manifest::parse(empty, Path::new("/tmp")).is_err());
        let missing = r#"{"format": "hlo-text-v1", "agents": {}}"#;
        assert!(Manifest::parse(missing, Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // Integration: when `make artifacts` has run, the real manifest
        // must parse and agree with Table I.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.seq_len, 32);
        assert_eq!(m.agents.len(), 4);
        let reasoning = m.agent("reasoning").unwrap();
        assert_eq!(reasoning.profile.model_mb, 3000);
        assert_eq!(reasoning.profile.min_gpu, 0.35);
        assert!(reasoning.param_count > 1_000_000);
        for a in &m.agents {
            assert!(!a.test_vectors.is_empty(), "{} has no vectors", a.name);
            for (_, f) in &a.variants {
                assert!(dir.join(f).exists(), "missing {f}");
            }
            assert!(dir.join(&a.params_file).exists());
        }
    }
}
