//! Cluster-level placement: agents → GPUs.

use crate::agents::AgentRegistry;
use crate::error::{Error, Result};

/// An assignment of agents to GPUs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// gpu_of[agent] = GPU index.
    pub gpu_of: Vec<usize>,
    /// Number of GPUs in the cluster.
    pub n_gpus: usize,
}

impl Placement {
    /// Agents placed on one GPU, in agent-id order.
    pub fn agents_on(&self, gpu: usize) -> Vec<usize> {
        self.gpu_of.iter().enumerate()
            .filter(|(_, g)| **g == gpu)
            .map(|(i, _)| i)
            .collect()
    }

    /// Sum of minimum fractions placed on each GPU.
    pub fn min_load(&self, registry: &AgentRegistry) -> Vec<f64> {
        let mut load = vec![0.0; self.n_gpus];
        for (agent, gpu) in self.gpu_of.iter().enumerate() {
            load[*gpu] += registry.min_gpu()[agent];
        }
        load
    }

    /// Move one agent to another GPU (used by the rebalancer).
    pub fn migrate(&mut self, agent: usize, to_gpu: usize) {
        assert!(to_gpu < self.n_gpus);
        self.gpu_of[agent] = to_gpu;
    }
}

/// Balanced (worst-fit) decreasing bin packing over minimum GPU
/// fractions: sort agents by `R_i` descending, place each on the
/// *least-loaded* GPU where its minimum still fits under
/// `capacity_per_gpu` — so a multi-GPU cluster spreads agents instead of
/// piling them onto device 0.
///
/// Errors when some agent fits nowhere (the cluster is genuinely
/// undersized).
pub fn first_fit_decreasing(registry: &AgentRegistry, n_gpus: usize,
                            capacity_per_gpu: f64) -> Result<Placement> {
    if n_gpus == 0 {
        return Err(Error::Config("cluster needs >= 1 GPU".into()));
    }
    pack_decreasing(registry, &vec![capacity_per_gpu; n_gpus])
}

/// Per-GPU-capacity generalization of [`first_fit_decreasing`]
/// (heterogeneous devices, §VI): sort agents by `R_i` descending, place
/// each on the GPU with the most remaining *headroom*
/// (`capacity - load`) where its minimum still fits. With uniform
/// capacities the headroom order equals the load order, so this reduces
/// to [`first_fit_decreasing`] exactly (asserted by the tests).
///
/// Errors when the capacity list is empty or some agent fits nowhere.
pub fn pack_decreasing(registry: &AgentRegistry, capacities: &[f64])
                       -> Result<Placement> {
    if capacities.is_empty() {
        return Err(Error::Config("cluster needs >= 1 GPU".into()));
    }
    let n_gpus = capacities.len();
    let mins = registry.min_gpu();
    let mut order: Vec<usize> = (0..registry.len()).collect();
    order.sort_by(|a, b| mins[*b].partial_cmp(&mins[*a])
                  .expect("min_gpu is finite"));

    let mut load = vec![0.0f64; n_gpus];
    let mut gpu_of = vec![usize::MAX; registry.len()];
    for agent in order {
        let mut placed = false;
        let mut gpus: Vec<usize> = (0..n_gpus).collect();
        gpus.sort_by(|a, b| {
            let ha = capacities[*a] - load[*a];
            let hb = capacities[*b] - load[*b];
            hb.partial_cmp(&ha).expect("finite headroom")
        });
        for gpu in gpus {
            if load[gpu] + mins[agent] <= capacities[gpu] + 1e-9 {
                load[gpu] += mins[agent];
                gpu_of[agent] = gpu;
                placed = true;
                break;
            }
        }
        if !placed {
            return Err(Error::Config(format!(
                "agent '{}' (min {:.2}) fits on no GPU \
                 (loads: {load:?}, capacities: {capacities:?})",
                registry.profile(agent).name, mins[agent])));
        }
    }
    Ok(Placement { gpu_of, n_gpus })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::{AgentProfile, AgentRegistry};

    #[test]
    fn paper_agents_pack_onto_two_gpus() {
        let reg = AgentRegistry::paper();
        // Σ mins = 1.0; two GPUs of capacity 0.6 each must fit
        // (0.35+0.25 | 0.30+0.10).
        let p = first_fit_decreasing(&reg, 2, 0.6).unwrap();
        let load = p.min_load(&reg);
        assert!(load.iter().all(|l| *l <= 0.6 + 1e-9), "{load:?}");
        assert_eq!(p.gpu_of.len(), 4);
        // Every agent placed.
        assert!(p.gpu_of.iter().all(|g| *g < 2));
    }

    #[test]
    fn one_big_gpu_holds_everything() {
        let reg = AgentRegistry::paper();
        let p = first_fit_decreasing(&reg, 1, 1.0).unwrap();
        assert_eq!(p.agents_on(0).len(), 4);
    }

    #[test]
    fn undersized_cluster_errors() {
        let reg = AgentRegistry::paper();
        assert!(first_fit_decreasing(&reg, 2, 0.3).is_err());
        assert!(first_fit_decreasing(&reg, 0, 1.0).is_err());
    }

    #[test]
    fn ffd_beats_naive_order_on_adversarial_mins() {
        // Mins {0.5, 0.5, 0.25, 0.25, 0.25, 0.25}: FFD packs into 2 GPUs
        // of 1.0; first-fit in given order would too here, but the
        // decreasing sort is what guarantees the 11/9 OPT bound — assert
        // the packing is tight.
        let agents: Vec<AgentProfile> =
            [0.25, 0.5, 0.25, 0.5, 0.25, 0.25].iter().enumerate()
            .map(|(i, m)| AgentProfile {
                name: format!("a{i}"),
                model_mb: 100,
                base_tput: 10.0,
                min_gpu: *m,
                priority: crate::agents::Priority::Medium,
            }).collect();
        let reg = AgentRegistry::new(agents).unwrap();
        let p = first_fit_decreasing(&reg, 2, 1.0).unwrap();
        let load = p.min_load(&reg);
        assert!((load[0] - 1.0).abs() < 1e-9
                && (load[1] - 1.0).abs() < 1e-9, "{load:?}");
    }

    #[test]
    fn heterogeneous_capacities_pack_by_headroom() {
        let reg = AgentRegistry::paper();
        // A 0.6 device plus a 0.4 device: Σ mins = 1.0 exactly, so the
        // packing must be tight and respect each device's own cap.
        let p = pack_decreasing(&reg, &[0.6, 0.4]).unwrap();
        let load = p.min_load(&reg);
        assert!(load[0] <= 0.6 + 1e-9 && load[1] <= 0.4 + 1e-9,
                "{load:?}");
        assert!(p.gpu_of.iter().all(|g| *g < 2));
        // reasoning (largest min, 0.35) lands on the big device first.
        assert_eq!(p.gpu_of[3], 0);
        // Undersized heterogeneous mixes error instead of panicking.
        assert!(pack_decreasing(&reg, &[0.5, 0.3]).is_err());
        assert!(pack_decreasing(&reg, &[]).is_err());
    }

    #[test]
    fn uniform_capacities_reduce_to_first_fit_decreasing() {
        let reg = AgentRegistry::paper();
        for (n, cap) in [(2usize, 0.6), (2, 1.0), (4, 1.0)] {
            let uniform = pack_decreasing(&reg, &vec![cap; n]).unwrap();
            let ffd = first_fit_decreasing(&reg, n, cap).unwrap();
            assert_eq!(uniform, ffd, "{n} gpus @ {cap}");
        }
    }

    #[test]
    fn migrate_updates_assignment() {
        let reg = AgentRegistry::paper();
        let mut p = first_fit_decreasing(&reg, 2, 1.0).unwrap();
        let from = p.gpu_of[0];
        p.migrate(0, 1 - from);
        assert_eq!(p.gpu_of[0], 1 - from);
    }
}
