//! Cluster-level placement: agents → GPUs, under a pluggable strategy.
//!
//! [`PlacementStrategy`] is the placement counterpart of the allocator's
//! `PolicyKind`: an enum-dispatched family of packers that all solve the
//! same problem — assign every agent to one device such that the sum of
//! minimum fractions on each device fits its capacity — but optimize for
//! different things. [`PlacementStrategy::place_into`] is the
//! scratch-reusing core (no per-agent allocations, no per-agent sorts);
//! [`PlacementStrategy::place`] is the fresh-buffer convenience the
//! constructors use.

use crate::agents::{AgentRegistry, Priority};
use crate::error::{Error, Result};

/// An assignment of agents to GPUs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// gpu_of[agent] = GPU index.
    pub gpu_of: Vec<usize>,
    /// Number of GPUs in the cluster.
    pub n_gpus: usize,
}

impl Placement {
    /// Agents placed on one GPU, in agent-id order.
    ///
    /// Allocates a fresh `Vec` — fine at construction/migration time, but
    /// per-step consumers (the cluster hot loop) should iterate `gpu_of`
    /// directly or cache the lists, as `ClusterAllocator` does.
    pub fn agents_on(&self, gpu: usize) -> Vec<usize> {
        self.gpu_of.iter().enumerate()
            .filter(|(_, g)| **g == gpu)
            .map(|(i, _)| i)
            .collect()
    }

    /// Sum of minimum fractions placed on each GPU.
    pub fn min_load(&self, registry: &AgentRegistry) -> Vec<f64> {
        let mut load = vec![0.0; self.n_gpus];
        for (agent, gpu) in self.gpu_of.iter().enumerate() {
            load[*gpu] += registry.min_gpu()[agent];
        }
        load
    }

    /// Move one agent to another GPU (used by the rebalancers). Panics
    /// when `to_gpu` is not a device of this cluster.
    pub fn migrate(&mut self, agent: usize, to_gpu: usize) {
        assert!(to_gpu < self.n_gpus,
                "migrate target GPU {to_gpu} out of bounds \
                 ({} GPUs)", self.n_gpus);
        self.gpu_of[agent] = to_gpu;
    }
}

/// Reusable buffers for [`PlacementStrategy::place_into`]: the agent
/// ordering plus per-GPU min-fraction and expected-demand loads. One
/// scratch lives in each `ClusterArena`, so mid-run re-packs allocate
/// nothing once warmed up.
#[derive(Debug, Clone, Default)]
pub struct PlacementScratch {
    order: Vec<usize>,
    min_load: Vec<f64>,
    demand_load: Vec<f64>,
    group_load: Vec<f64>,
}

impl PlacementScratch {
    /// Empty scratch; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        PlacementScratch::default()
    }
}

/// How agents are packed onto devices.
///
/// Every strategy is deterministic: agent orderings are stable sorts
/// (ties keep agent-id order) and device picks break score ties toward
/// the lowest GPU index. Feasibility is always judged on `min_gpu` sums
/// against per-device capacity; strategies differ only in *which*
/// feasible packing they prefer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementStrategy {
    /// Balanced (worst-fit) decreasing over minimum fractions: sort
    /// agents by `R_i` descending, place each on the device with the
    /// most remaining headroom. This is the packer the repo historically
    /// (and wrongly) called `first_fit_decreasing` — it spreads load
    /// instead of consolidating it.
    #[default]
    HeadroomDecreasing,
    /// Classic best-fit decreasing: sort by `R_i` descending, place each
    /// on the device with the *least* remaining headroom that still
    /// fits — consolidates agents onto few devices, leaving whole
    /// devices empty for scale-to-zero or spares.
    BestFitDecreasing,
    /// Priority spread: non-High agents are consolidated by best-fit
    /// decreasing first, then High-priority agents are placed (largest
    /// minimum first) on whatever device has the most headroom left —
    /// keeping them on the least-contended device.
    PrioritySpread,
    /// Demand-aware: order and balance by each agent's *expected GPU
    /// load* `rate_i / base_tput_i` rather than its minimum fraction,
    /// picking the device with the smallest resulting load-to-capacity
    /// ratio that still fits the minimums. With no expected rates
    /// supplied it falls back to `min_gpu` as the load proxy.
    DemandAware,
    /// In-order first-fit baseline: agents in registry order, each on
    /// the lowest-index device that fits — the naive packing the
    /// decreasing strategies are measured against.
    InOrder,
    /// Workflow co-location: the agents marked in the co-location mask
    /// (a workflow DAG's participants) are placed first, each preferring
    /// the device already holding the most co-located mass — pulling a
    /// workflow's stages onto one device so stage hand-offs never cross
    /// the interconnect. Remaining agents (and the whole placement when
    /// no mask is supplied) fall back to headroom-decreasing exactly.
    WorkflowColocate,
}

impl PlacementStrategy {
    /// Every built-in strategy, in a stable order (grid axes iterate
    /// this).
    pub fn all() -> Vec<PlacementStrategy> {
        vec![
            PlacementStrategy::HeadroomDecreasing,
            PlacementStrategy::BestFitDecreasing,
            PlacementStrategy::PrioritySpread,
            PlacementStrategy::DemandAware,
            PlacementStrategy::InOrder,
            PlacementStrategy::WorkflowColocate,
        ]
    }

    /// Short stable identifier used in sweep-cell labels and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::HeadroomDecreasing => "headroom",
            PlacementStrategy::BestFitDecreasing => "bestfit",
            PlacementStrategy::PrioritySpread => "spread",
            PlacementStrategy::DemandAware => "demand",
            PlacementStrategy::InOrder => "inorder",
            PlacementStrategy::WorkflowColocate => "colocate",
        }
    }

    /// Solve a placement with fresh buffers. `expected_rates` feeds
    /// [`PlacementStrategy::DemandAware`] (one rate per agent, in id
    /// order); the other strategies ignore it, and an empty slice makes
    /// demand-aware fall back to packing by `min_gpu`.
    ///
    /// Errors when the capacity list is empty or some agent fits
    /// nowhere (the cluster is genuinely undersized).
    pub fn place(&self, registry: &AgentRegistry, capacities: &[f64],
                 expected_rates: &[f64]) -> Result<Placement> {
        self.place_colocated(registry, capacities, expected_rates, &[])
    }

    /// [`PlacementStrategy::place`] with a workflow co-location mask:
    /// `colocate[i]` marks agent `i` as a participant of the workflow
    /// DAG that [`PlacementStrategy::WorkflowColocate`] pulls onto one
    /// device. The other strategies ignore the mask, and an empty mask
    /// makes co-location degrade to headroom-decreasing exactly.
    pub fn place_colocated(&self, registry: &AgentRegistry,
                           capacities: &[f64], expected_rates: &[f64],
                           colocate: &[bool]) -> Result<Placement> {
        let mut scratch = PlacementScratch::new();
        let mut gpu_of = Vec::new();
        self.place_into_colocated(registry, capacities, expected_rates,
                                  colocate, &mut scratch, &mut gpu_of)?;
        Ok(Placement { gpu_of, n_gpus: capacities.len() })
    }

    /// [`PlacementStrategy::place`] through caller-owned buffers: the
    /// ordering and per-device load rows live in `scratch` and the
    /// assignment is written into `gpu_of`, so repeated solves (the
    /// repack rebalancer, placement sweeps) allocate nothing once the
    /// buffers are warm.
    pub fn place_into(&self, registry: &AgentRegistry,
                      capacities: &[f64], expected_rates: &[f64],
                      scratch: &mut PlacementScratch,
                      gpu_of: &mut Vec<usize>) -> Result<()> {
        self.place_into_colocated(registry, capacities, expected_rates,
                                  &[], scratch, gpu_of)
    }

    /// [`PlacementStrategy::place_into`] with a workflow co-location
    /// mask (see [`PlacementStrategy::place_colocated`]).
    pub fn place_into_colocated(&self, registry: &AgentRegistry,
                                capacities: &[f64], expected_rates: &[f64],
                                colocate: &[bool],
                                scratch: &mut PlacementScratch,
                                gpu_of: &mut Vec<usize>) -> Result<()> {
        if capacities.is_empty() {
            return Err(Error::Config("cluster needs >= 1 GPU".into()));
        }
        let n = registry.len();
        let n_gpus = capacities.len();
        let mins = registry.min_gpu();
        let base_tput = registry.base_tput();
        // Expected per-agent GPU load for the demand-aware axes;
        // min_gpu is the proxy when no rates are supplied.
        let demand_of = |i: usize| -> f64 {
            if expected_rates.len() == n {
                expected_rates[i] / base_tput[i]
            } else {
                mins[i]
            }
        };
        // Workflow membership for the co-location strategy; no mask
        // means nobody is grouped and co-location degrades to
        // headroom-decreasing.
        let in_group = |i: usize| -> bool {
            colocate.get(i).copied().unwrap_or(false)
        };

        let PlacementScratch { order, min_load, demand_load, group_load }
            = scratch;
        order.clear();
        order.extend(0..n);
        match self {
            // Registry order is the whole point of the baseline.
            PlacementStrategy::InOrder => {}
            PlacementStrategy::HeadroomDecreasing
            | PlacementStrategy::BestFitDecreasing => {
                order.sort_by(|a, b| mins[*b].partial_cmp(&mins[*a])
                              .expect("min_gpu is finite"));
            }
            PlacementStrategy::PrioritySpread => {
                // Non-High agents first (consolidated by best fit),
                // High agents last (spread onto whatever stayed
                // least contended); decreasing minimums within each
                // group.
                order.sort_by(|a, b| {
                    let ha =
                        registry.profile(*a).priority == Priority::High;
                    let hb =
                        registry.profile(*b).priority == Priority::High;
                    ha.cmp(&hb).then(
                        mins[*b].partial_cmp(&mins[*a])
                            .expect("min_gpu is finite"))
                });
            }
            PlacementStrategy::DemandAware => {
                order.sort_by(|a, b| {
                    demand_of(*b).partial_cmp(&demand_of(*a))
                        .expect("expected load is finite")
                });
            }
            PlacementStrategy::WorkflowColocate => {
                // Workflow participants first (so the group anchors on
                // the emptiest device before loose agents fragment it),
                // decreasing minimums within each half.
                order.sort_by(|a, b| {
                    in_group(*b).cmp(&in_group(*a)).then(
                        mins[*b].partial_cmp(&mins[*a])
                            .expect("min_gpu is finite"))
                });
            }
        }

        min_load.clear();
        min_load.resize(n_gpus, 0.0);
        demand_load.clear();
        demand_load.resize(n_gpus, 0.0);
        group_load.clear();
        group_load.resize(n_gpus, 0.0);
        gpu_of.clear();
        gpu_of.resize(n, usize::MAX);

        for &agent in order.iter() {
            let is_high =
                registry.profile(agent).priority == Priority::High;
            let d_agent = demand_of(agent);
            // Linear scan instead of a per-agent sort: strict `>` keeps
            // the first (lowest-index) device among score ties. Scores
            // compare lexicographically; every strategy except workflow
            // co-location leaves the secondary component at 0.0, which
            // reduces the comparison to the primary exactly.
            let mut chosen: Option<usize> = None;
            let mut best = (f64::NEG_INFINITY, f64::NEG_INFINITY);
            for g in 0..n_gpus {
                if min_load[g] + mins[agent] > capacities[g] + 1e-9 {
                    continue;
                }
                let headroom = capacities[g] - min_load[g];
                let score = match self {
                    PlacementStrategy::HeadroomDecreasing =>
                        (headroom, 0.0),
                    PlacementStrategy::BestFitDecreasing =>
                        (-headroom, 0.0),
                    // Constant score: the first fitting device wins.
                    PlacementStrategy::InOrder => (0.0, 0.0),
                    PlacementStrategy::PrioritySpread => {
                        (if is_high { headroom } else { -headroom }, 0.0)
                    }
                    PlacementStrategy::DemandAware => {
                        (-((demand_load[g] + d_agent) / capacities[g]),
                         0.0)
                    }
                    PlacementStrategy::WorkflowColocate => {
                        // Grouped agents chase the device already
                        // holding the most workflow mass (headroom
                        // breaks fresh-device ties); loose agents pack
                        // by headroom as usual.
                        if in_group(agent) {
                            (group_load[g], headroom)
                        } else {
                            (headroom, 0.0)
                        }
                    }
                };
                if chosen.is_none() || score > best {
                    chosen = Some(g);
                    best = score;
                }
            }
            let Some(g) = chosen else {
                return Err(Error::Config(format!(
                    "agent '{}' (min {:.2}) fits on no GPU \
                     (loads: {min_load:?}, capacities: {capacities:?})",
                    registry.profile(agent).name, mins[agent])));
            };
            min_load[g] += mins[agent];
            demand_load[g] += d_agent;
            if in_group(agent) {
                group_load[g] += mins[agent];
            }
            gpu_of[agent] = g;
        }
        Ok(())
    }
}

/// Balanced (worst-fit) decreasing bin packing over minimum GPU
/// fractions across `n_gpus` uniform devices — the construction-time
/// default ([`PlacementStrategy::HeadroomDecreasing`] as a free
/// function).
///
/// Errors when `n_gpus` is zero or some agent fits nowhere (the cluster
/// is genuinely undersized).
pub fn headroom_decreasing(registry: &AgentRegistry, n_gpus: usize,
                           capacity_per_gpu: f64) -> Result<Placement> {
    if n_gpus == 0 {
        return Err(Error::Config("cluster needs >= 1 GPU".into()));
    }
    pack_decreasing(registry, &vec![capacity_per_gpu; n_gpus])
}

/// Per-GPU-capacity form of [`headroom_decreasing`] (heterogeneous
/// devices, §VI): sort agents by `R_i` descending, place each on the
/// GPU with the most remaining *headroom* (`capacity - load`) where its
/// minimum still fits. With uniform capacities the headroom order
/// equals the load order, so this reduces to [`headroom_decreasing`]
/// exactly (asserted by the tests).
///
/// Errors when the capacity list is empty or some agent fits nowhere.
pub fn pack_decreasing(registry: &AgentRegistry, capacities: &[f64])
                       -> Result<Placement> {
    PlacementStrategy::HeadroomDecreasing.place(registry, capacities, &[])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::{AgentProfile, AgentRegistry};

    fn uniform_agents(mins: &[f64]) -> AgentRegistry {
        let agents: Vec<AgentProfile> = mins.iter().enumerate()
            .map(|(i, m)| AgentProfile {
                name: format!("a{i}"),
                model_mb: 100,
                base_tput: 10.0,
                min_gpu: *m,
                priority: crate::agents::Priority::Medium,
            }).collect();
        AgentRegistry::new(agents).unwrap()
    }

    #[test]
    fn paper_agents_pack_onto_two_gpus() {
        let reg = AgentRegistry::paper();
        // Σ mins = 1.0; two GPUs of capacity 0.6 each must fit
        // (0.35+0.25 | 0.30+0.10).
        let p = headroom_decreasing(&reg, 2, 0.6).unwrap();
        let load = p.min_load(&reg);
        assert!(load.iter().all(|l| *l <= 0.6 + 1e-9), "{load:?}");
        assert_eq!(p.gpu_of.len(), 4);
        // Every agent placed.
        assert!(p.gpu_of.iter().all(|g| *g < 2));
    }

    #[test]
    fn one_big_gpu_holds_everything() {
        let reg = AgentRegistry::paper();
        let p = headroom_decreasing(&reg, 1, 1.0).unwrap();
        assert_eq!(p.agents_on(0).len(), 4);
    }

    #[test]
    fn undersized_cluster_errors() {
        let reg = AgentRegistry::paper();
        assert!(headroom_decreasing(&reg, 2, 0.3).is_err());
        assert!(headroom_decreasing(&reg, 0, 1.0).is_err());
        // Every strategy surfaces the same construction-time error.
        for strategy in PlacementStrategy::all() {
            assert!(strategy.place(&reg, &[0.3, 0.3], &[]).is_err(),
                    "{}", strategy.name());
            assert!(strategy.place(&reg, &[], &[]).is_err(),
                    "{}", strategy.name());
        }
    }

    #[test]
    fn headroom_decreasing_balances_adversarial_mins() {
        // Mins {0.5, 0.5, 0.25, 0.25, 0.25, 0.25} on 2 GPUs of 1.0.
        // This packer is *worst-fit* decreasing (most-headroom device
        // first) — not FFD, so the classic 11/9 OPT bound does not
        // apply — but the decreasing sort still packs this instance
        // tight: both devices land exactly full.
        let reg = uniform_agents(&[0.25, 0.5, 0.25, 0.5, 0.25, 0.25]);
        let p = headroom_decreasing(&reg, 2, 1.0).unwrap();
        let load = p.min_load(&reg);
        assert!((load[0] - 1.0).abs() < 1e-9
                && (load[1] - 1.0).abs() < 1e-9, "{load:?}");
    }

    #[test]
    fn heterogeneous_capacities_pack_by_headroom() {
        let reg = AgentRegistry::paper();
        // A 0.6 device plus a 0.4 device: Σ mins = 1.0 exactly, so the
        // packing must be tight and respect each device's own cap.
        let p = pack_decreasing(&reg, &[0.6, 0.4]).unwrap();
        let load = p.min_load(&reg);
        assert!(load[0] <= 0.6 + 1e-9 && load[1] <= 0.4 + 1e-9,
                "{load:?}");
        assert!(p.gpu_of.iter().all(|g| *g < 2));
        // reasoning (largest min, 0.35) lands on the big device first.
        assert_eq!(p.gpu_of[3], 0);
        // Undersized heterogeneous mixes error instead of panicking.
        assert!(pack_decreasing(&reg, &[0.5, 0.3]).is_err());
        assert!(pack_decreasing(&reg, &[]).is_err());
    }

    #[test]
    fn uniform_capacities_reduce_to_headroom_decreasing() {
        let reg = AgentRegistry::paper();
        for (n, cap) in [(2usize, 0.6), (2, 1.0), (4, 1.0)] {
            let uniform = pack_decreasing(&reg, &vec![cap; n]).unwrap();
            let hd = headroom_decreasing(&reg, n, cap).unwrap();
            assert_eq!(uniform, hd, "{n} gpus @ {cap}");
        }
    }

    #[test]
    fn equal_headroom_ties_break_to_lowest_gpu_index() {
        // Four identical agents on three identical devices: the packer
        // must be deterministic — agent 0 to device 0, agent 1 to
        // device 1 (device 0 now has less headroom), agent 2 to device
        // 2, and agent 3 back to device 0 (three-way tie again).
        let reg = uniform_agents(&[0.25, 0.25, 0.25, 0.25]);
        let p = pack_decreasing(&reg, &[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(p.gpu_of, vec![0, 1, 2, 0]);
        // Best-fit ties the same way — and then sticks to device 0,
        // since a part-filled bin always beats an empty one.
        let b = PlacementStrategy::BestFitDecreasing
            .place(&reg, &[1.0, 1.0, 1.0], &[]).unwrap();
        assert_eq!(b.gpu_of, vec![0, 0, 0, 0]);
    }

    #[test]
    fn in_order_is_naive_first_fit() {
        let reg = AgentRegistry::paper();
        // Mins .10/.30/.25/.35 in registry order all fit device 0.
        let p = PlacementStrategy::InOrder
            .place(&reg, &[1.0, 1.0], &[]).unwrap();
        assert_eq!(p.gpu_of, vec![0, 0, 0, 0]);
        // With 0.6 devices the naive order spills as it goes.
        let p = PlacementStrategy::InOrder
            .place(&reg, &[0.6, 0.6], &[]).unwrap();
        assert_eq!(p.gpu_of, vec![0, 0, 1, 1]);
    }

    #[test]
    fn priority_spread_parks_high_agents_on_least_contended_device() {
        // Paper registry on mixed devices: the Medium pair is
        // consolidated by best fit, then the two High agents take the
        // emptiest devices.
        let reg = AgentRegistry::paper();
        let p = PlacementStrategy::PrioritySpread
            .place(&reg, &[1.0, 0.75, 0.5, 0.25], &[]).unwrap();
        assert_eq!(p.gpu_of[1], 2, "nlp consolidated on the 0.5 device");
        assert_eq!(p.gpu_of[2], 3,
                   "vision consolidated on the 0.25 device");
        assert_eq!(p.gpu_of[3], 0,
                   "reasoning (High) takes the emptiest device");
        assert_eq!(p.gpu_of[0], 1,
                   "coordinator (High) takes the next-emptiest");
    }

    #[test]
    fn demand_aware_balances_expected_load_not_minimums() {
        // Agent 0 has a tiny minimum but dominates the traffic; agent 1
        // has the largest minimum and almost none. Min-based packing
        // pairs them; demand-aware isolates the hot agent.
        let reg = uniform_agents(&[0.1, 0.4, 0.2, 0.2]);
        let rates = [20.0, 1.0, 1.0, 1.0];
        let p = PlacementStrategy::DemandAware
            .place(&reg, &[1.0, 1.0], &rates).unwrap();
        assert_eq!(p.gpu_of, vec![0, 1, 1, 1],
                   "hot agent isolated on its own device");
        // Without rates it degrades to the min-based packing, which on
        // uniform capacities equals headroom-decreasing exactly.
        let fallback = PlacementStrategy::DemandAware
            .place(&reg, &[1.0, 1.0], &[]).unwrap();
        assert_eq!(fallback, pack_decreasing(&reg, &[1.0, 1.0]).unwrap());
    }

    #[test]
    fn place_into_reuses_scratch_bit_identically() {
        // One scratch replayed across strategies, registries, and
        // cluster shapes must leave no state behind.
        let mut scratch = PlacementScratch::new();
        let mut gpu_of = Vec::new();
        let paper = AgentRegistry::paper();
        let wide = uniform_agents(&[0.2, 0.1, 0.3, 0.2, 0.1]);
        for _ in 0..2 {
            for strategy in PlacementStrategy::all() {
                for (reg, caps) in [
                    (&paper, vec![1.0, 0.75, 0.5, 0.25]),
                    (&paper, vec![0.6, 0.6]),
                    (&wide, vec![1.0, 0.5]),
                ] {
                    let fresh =
                        strategy.place(reg, &caps, &[]).unwrap();
                    strategy.place_into(reg, &caps, &[], &mut scratch,
                                        &mut gpu_of).unwrap();
                    assert_eq!(gpu_of, fresh.gpu_of,
                               "{} on {caps:?}", strategy.name());
                }
            }
        }
    }

    #[test]
    fn strategy_names_are_unique_and_stable() {
        let mut names: Vec<&str> = PlacementStrategy::all().iter()
            .map(PlacementStrategy::name).collect();
        assert_eq!(names.len(), 6);
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6, "duplicate strategy names");
        assert_eq!(PlacementStrategy::default(),
                   PlacementStrategy::HeadroomDecreasing);
    }

    #[test]
    fn colocate_without_a_mask_matches_headroom_decreasing() {
        let reg = AgentRegistry::paper();
        for caps in [vec![1.0], vec![0.6, 0.6], vec![1.0, 0.75, 0.5]] {
            let hd = PlacementStrategy::HeadroomDecreasing
                .place(&reg, &caps, &[]).unwrap();
            let co = PlacementStrategy::WorkflowColocate
                .place(&reg, &caps, &[]).unwrap();
            assert_eq!(co, hd, "{caps:?}");
        }
    }

    #[test]
    fn colocate_pulls_masked_agents_onto_one_device() {
        // Paper mins .10/.30/.25/.35 on two 0.75 devices: headroom
        // packing splits agents 0 and 3 across devices; with 0 and 3
        // masked as one workflow, co-location pairs them (0.45 fits)
        // and the loose pair lands on the other device.
        let reg = AgentRegistry::paper();
        let mask = [true, false, false, true];
        let p = PlacementStrategy::WorkflowColocate
            .place_colocated(&reg, &[0.75, 0.75], &[], &mask).unwrap();
        assert_eq!(p.gpu_of[0], p.gpu_of[3],
                   "workflow participants share a device: {:?}", p.gpu_of);
        assert_eq!(p.gpu_of[1], p.gpu_of[2],
                   "loose agents pack the other device: {:?}", p.gpu_of);
        assert_ne!(p.gpu_of[0], p.gpu_of[1]);
        // When the group cannot fit on one device it spills but still
        // places everyone.
        let tight = PlacementStrategy::WorkflowColocate
            .place_colocated(&reg, &[0.4, 0.4, 0.4], &[],
                             &[true, true, true, true]).unwrap();
        assert!(tight.gpu_of.iter().all(|g| *g < 3));
        // Non-colocating strategies ignore the mask entirely.
        let hd_masked = PlacementStrategy::HeadroomDecreasing
            .place_colocated(&reg, &[0.75, 0.75], &[], &mask).unwrap();
        let hd = PlacementStrategy::HeadroomDecreasing
            .place(&reg, &[0.75, 0.75], &[]).unwrap();
        assert_eq!(hd_masked, hd);
    }

    #[test]
    fn migrate_updates_assignment() {
        let reg = AgentRegistry::paper();
        let mut p = headroom_decreasing(&reg, 2, 1.0).unwrap();
        let from = p.gpu_of[0];
        p.migrate(0, 1 - from);
        assert_eq!(p.gpu_of[0], 1 - from);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn migrate_rejects_out_of_bounds_target() {
        let reg = AgentRegistry::paper();
        let mut p = headroom_decreasing(&reg, 2, 1.0).unwrap();
        p.migrate(0, 2);
    }
}
