//! Node-level allocation within a placement: Algorithm 1 per GPU.

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::{AdaptivePolicy, AllocContext, AllocationPolicy};
use crate::cluster::Placement;

/// Hierarchical allocator: cluster placement outside, the paper's
/// Algorithm 1 independently inside each GPU.
///
/// Each GPU's sub-problem is a registry slice of the agents placed there,
/// with the full per-GPU capacity; the output is a *global* fraction
/// vector where agent i's share is of **its own GPU** (execution always
/// happens on the placed device).
#[derive(Debug)]
pub struct ClusterAllocator {
    placement: Placement,
    /// One Algorithm 1 instance per GPU (stateless today, but keeping
    /// them separate lets stateful node policies slot in).
    node_policies: Vec<AdaptivePolicy>,
    /// Per-GPU sub-registries, rebuilt when placement changes.
    sub_registries: Vec<AgentRegistry>,
    /// Per-GPU agent ids (registry ids), rebuilt when placement
    /// changes — the per-step allocate path reads these instead of
    /// collecting fresh `Placement::agents_on` vectors every step.
    ids: Vec<Vec<usize>>,
    /// Scratch: per-GPU dense rate/queue/out buffers.
    scratch_rates: Vec<Vec<f64>>,
    scratch_queues: Vec<Vec<f64>>,
    scratch_out: Vec<Vec<f64>>,
}

impl ClusterAllocator {
    /// Build over a registry and placement.
    pub fn new(registry: &AgentRegistry, placement: Placement)
               -> ClusterAllocator {
        let mut a = ClusterAllocator {
            node_policies: (0..placement.n_gpus)
                .map(|_| AdaptivePolicy::default()).collect(),
            sub_registries: Vec::new(),
            ids: Vec::new(),
            scratch_rates: Vec::new(),
            scratch_queues: Vec::new(),
            scratch_out: Vec::new(),
            placement,
        };
        a.rebuild(registry);
        a
    }

    /// Current placement.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Apply a migration and rebuild node state.
    pub fn migrate(&mut self, registry: &AgentRegistry, agent: usize,
                   to_gpu: usize) {
        self.placement.migrate(agent, to_gpu);
        self.rebuild(registry);
    }

    /// Replace the whole placement (the repack rebalancer's path) and
    /// rebuild node state once, rather than once per moved agent.
    pub fn set_placement(&mut self, registry: &AgentRegistry,
                         placement: Placement) {
        self.placement = placement;
        self.rebuild(registry);
    }

    fn rebuild(&mut self, registry: &AgentRegistry) {
        // A replacement placement may span a different device count
        // (set_placement is public): keep one node policy per GPU.
        self.node_policies.resize_with(self.placement.n_gpus,
                                       AdaptivePolicy::default);
        self.sub_registries.clear();
        self.ids.clear();
        self.scratch_rates.clear();
        self.scratch_queues.clear();
        self.scratch_out.clear();
        for gpu in 0..self.placement.n_gpus {
            let ids = self.placement.agents_on(gpu);
            let profiles: Vec<AgentProfile> =
                ids.iter().map(|i| registry.profile(*i).clone()).collect();
            // An empty GPU gets a placeholder registry of zero agents —
            // represent with an empty scratch and skip at allocate time.
            if profiles.is_empty() {
                // AgentRegistry requires >= 1 agent; store a marker via
                // Option-like empty scratch vectors.
                self.sub_registries.push(AgentRegistry::paper());
                self.ids.push(Vec::new());
                self.scratch_rates.push(Vec::new());
                self.scratch_queues.push(Vec::new());
                self.scratch_out.push(Vec::new());
                continue;
            }
            self.sub_registries.push(
                AgentRegistry::new(profiles).expect("valid sub-registry"));
            self.scratch_rates.push(vec![0.0; ids.len()]);
            self.scratch_queues.push(vec![0.0; ids.len()]);
            self.scratch_out.push(vec![0.0; ids.len()]);
            self.ids.push(ids);
        }
    }

    /// Allocate: `out[i]` = agent i's fraction *of its placed GPU*.
    /// `capacities[gpu]` is that device's capacity (uniform clusters pass
    /// the same value per GPU). Global GPU-time conservation:
    /// Σ_{i on gpu} out[i] <= capacities[gpu] for every gpu.
    pub fn allocate(&mut self, registry: &AgentRegistry,
                    arrival_rates: &[f64], queue_depths: &[f64],
                    step: u64, capacities: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        self.allocate_masked(registry, arrival_rates, queue_depths, step,
                             capacities, None, out);
    }

    /// [`ClusterAllocator::allocate`] restricted to the devices marked
    /// live in `gpu_live` (`None` = all live) — the active-set tier's
    /// sparse entry. Skipped devices' `out` cells are left untouched
    /// rather than zero-filled; the caller guarantees every agent on a
    /// skipped device already holds exactly `0.0` there (the settle
    /// invariant), which is bit-for-bit what the dense path would
    /// rewrite: each per-GPU Algorithm 1 instance is stateless and
    /// writes `+0.0` for every agent at zero demand, so skipping a
    /// fully-settled device changes no bit of output or allocator
    /// state. Devices with at least one live agent run the full
    /// sub-problem over *all* their placed agents (settled ones
    /// contribute `+0.0` demand and are rewritten `+0.0`), so
    /// within-device normalization is unchanged.
    pub fn allocate_masked(&mut self, registry: &AgentRegistry,
                           arrival_rates: &[f64], queue_depths: &[f64],
                           step: u64, capacities: &[f64],
                           gpu_live: Option<&[bool]>, out: &mut [f64]) {
        debug_assert_eq!(capacities.len(), self.placement.n_gpus);
        for gpu in 0..self.placement.n_gpus {
            if self.ids[gpu].is_empty()
                || gpu_live.is_some_and(|live| !live[gpu])
            {
                continue;
            }
            let ids = &self.ids[gpu];
            let rates = &mut self.scratch_rates[gpu];
            let queues = &mut self.scratch_queues[gpu];
            for (slot, agent) in ids.iter().enumerate() {
                rates[slot] = arrival_rates[*agent];
                queues[slot] = queue_depths[*agent];
            }
            let ctx = AllocContext {
                registry: &self.sub_registries[gpu],
                arrival_rates: rates,
                queue_depths: queues,
                step,
                capacity: capacities[gpu],
            };
            let sub_out = &mut self.scratch_out[gpu];
            self.node_policies[gpu].allocate(&ctx, sub_out);
            for (slot, agent) in ids.iter().enumerate() {
                out[*agent] = sub_out[slot];
            }
        }
        let _ = registry; // placement ids are registry ids by construction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::headroom_decreasing;

    #[test]
    fn per_gpu_capacity_respected() {
        let reg = AgentRegistry::paper();
        let placement = headroom_decreasing(&reg, 2, 0.6).unwrap();
        let mut alloc = ClusterAllocator::new(&reg, placement);
        let mut out = vec![0.0; 4];
        alloc.allocate(&reg, &[80.0, 40.0, 45.0, 25.0], &[0.0; 4], 0,
                       &[1.0, 1.0], &mut out);
        for gpu in 0..2 {
            let total: f64 = alloc.placement().agents_on(gpu).iter()
                .map(|i| out[*i]).sum();
            assert!(total <= 1.0 + 1e-9, "gpu {gpu}: {total}");
        }
        // Every active agent got something.
        assert!(out.iter().all(|g| *g > 0.0), "{out:?}");
    }

    #[test]
    fn two_gpus_double_aggregate_throughput_capacity() {
        // With 2 GPUs each agent pair shares a whole device, so shares
        // are larger than the single-GPU run's.
        let reg = AgentRegistry::paper();
        let single = headroom_decreasing(&reg, 1, 1.0).unwrap();
        let dual = headroom_decreasing(&reg, 2, 0.6).unwrap();
        let rates = [80.0, 40.0, 45.0, 25.0];
        let mut out1 = vec![0.0; 4];
        let mut out2 = vec![0.0; 4];
        ClusterAllocator::new(&reg, single)
            .allocate(&reg, &rates, &[0.0; 4], 0, &[1.0], &mut out1);
        ClusterAllocator::new(&reg, dual)
            .allocate(&reg, &rates, &[0.0; 4], 0, &[1.0, 1.0], &mut out2);
        let cap1: f64 = (0..4).map(|i| out1[i] * reg.base_tput()[i]).sum();
        let cap2: f64 = (0..4).map(|i| out2[i] * reg.base_tput()[i]).sum();
        assert!(cap2 > 1.5 * cap1, "single {cap1} vs dual {cap2}");
    }

    #[test]
    fn migration_moves_allocation_mass() {
        let reg = AgentRegistry::paper();
        let placement = headroom_decreasing(&reg, 2, 1.0).unwrap();
        let mut alloc = ClusterAllocator::new(&reg, placement);
        let rates = [80.0, 40.0, 45.0, 25.0];
        let mut out = vec![0.0; 4];
        alloc.allocate(&reg, &rates, &[0.0; 4], 0, &[1.0, 1.0], &mut out);
        let coord_before = out[0];
        // Move the coordinator to the other GPU; shares re-equilibrate.
        let to = 1 - alloc.placement().gpu_of[0];
        alloc.migrate(&reg, 0, to);
        alloc.allocate(&reg, &rates, &[0.0; 4], 1, &[1.0, 1.0], &mut out);
        assert!(out[0] > 0.0);
        assert_ne!(out[0], coord_before);
    }

    #[test]
    fn masked_allocate_matches_dense_when_idle_gpus_are_skipped() {
        use crate::agents::Priority;
        // Two zero-floor idle agents alone on GPU 1: the mask skips
        // their whole device and must reproduce the dense output (and
        // leave their pre-zeroed cells holding exactly +0.0).
        let profiles: Vec<AgentProfile> = (0..4)
            .map(|i| AgentProfile {
                name: format!("a{i}"),
                model_mb: 800,
                base_tput: 50.0,
                min_gpu: if i < 2 { 0.2 } else { 0.0 },
                priority: Priority::Medium,
            })
            .collect();
        let reg = AgentRegistry::new(profiles).unwrap();
        let placement = Placement { gpu_of: vec![0, 0, 1, 1], n_gpus: 2 };
        let rates = [80.0, 40.0, 0.0, 0.0];
        let queues = [3.0, 0.0, 0.0, 0.0];

        let mut dense_out = vec![0.0; 4];
        ClusterAllocator::new(&reg, placement.clone()).allocate(
            &reg, &rates, &queues, 7, &[1.0, 1.0], &mut dense_out);

        let mut masked_out = vec![0.0; 4];
        ClusterAllocator::new(&reg, placement).allocate_masked(
            &reg, &rates, &queues, 7, &[1.0, 1.0],
            Some(&[true, false]), &mut masked_out);

        assert_eq!(dense_out, masked_out);
        assert!(masked_out[2] == 0.0 && masked_out[2].is_sign_positive());
        assert!(masked_out[0] > 0.0 && masked_out[1] > 0.0);
    }

    #[test]
    fn set_placement_replaces_the_whole_assignment() {
        let reg = AgentRegistry::paper();
        let mut alloc = ClusterAllocator::new(
            &reg, headroom_decreasing(&reg, 2, 1.0).unwrap());
        // Everyone onto GPU 1 in one rebuild.
        let all_on_one = Placement { gpu_of: vec![1; 4], n_gpus: 2 };
        alloc.set_placement(&reg, all_on_one.clone());
        assert_eq!(alloc.placement(), &all_on_one);
        let mut out = vec![0.0; 4];
        alloc.allocate(&reg, &[80.0, 40.0, 45.0, 25.0], &[0.0; 4], 0,
                       &[1.0, 1.0], &mut out);
        // GPU 1 holds the full population within its capacity; GPU 0
        // serves nobody.
        let total: f64 = out.iter().sum();
        assert!(total <= 1.0 + 1e-9, "{out:?}");
        assert!(out.iter().all(|g| *g > 0.0), "{out:?}");
    }
}
