//! Multi-GPU, hierarchical allocation — the paper's §VI future work,
//! built out: "multi-GPU scheduling with inter-GPU communication overhead
//! modeling, and hierarchical allocation strategies across cluster and
//! node levels".
//!
//! Two levels:
//!
//! * **Cluster level** ([`first_fit_decreasing`]): agents are packed
//!   onto GPUs by first-fit-decreasing over their minimum fractions; a
//!   rebalancer
//!   migrates an agent when inter-GPU demand imbalance exceeds a
//!   threshold, paying a model-size-dependent transfer penalty during
//!   which the agent cannot serve (the "inter-GPU communication
//!   overhead" model).
//! * **Node level** ([`ClusterAllocator`]): the paper's Algorithm 1 runs
//!   independently *within* each GPU over the agents placed there.
//!
//! [`ClusterSimulator`] extends the §IV.B discrete-time methodology to M
//! GPUs so placement/migration policies can be evaluated with the same
//! metrics as the single-GPU experiments (bench `robustness` prints the
//! comparison; `cluster_sim.rs` integration tests assert the invariants).

mod hierarchical;
mod placement;
mod sim;

pub use hierarchical::ClusterAllocator;
pub use placement::{first_fit_decreasing, pack_decreasing, Placement};
pub use sim::{ClusterArena, ClusterResult, ClusterSimulator,
              MigrationModel};
