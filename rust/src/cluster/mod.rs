//! Multi-GPU, hierarchical allocation — the paper's §VI future work,
//! built out: "multi-GPU scheduling with inter-GPU communication overhead
//! modeling, and hierarchical allocation strategies across cluster and
//! node levels".
//!
//! Four pluggable layers, outermost first:
//!
//! ```text
//!   PlacementStrategy      agents -> GPUs at construction time
//!        |                 (headroom- / best-fit-decreasing,
//!        v                  priority-spread, demand-aware, in-order,
//!                           workflow-colocate)
//!   Placement              the assignment itself (gpu_of, migrate)
//!        |
//!        v
//!   ClusterAllocator       the paper's Algorithm 1 run independently
//!        |                 *within* each GPU over the agents placed
//!        v                 there, against that device's own capacity
//!   Rebalancer             runtime reaction to demand imbalance:
//!        |                 static / hottest-agent-off-hottest-GPU /
//!        v                 re-pack-from-scratch — every migration pays
//!   Fault layer            a model-size-dependent transfer stall (the
//!                          "inter-GPU communication overhead" model)
//!
//!                          seeded FaultPlan evictions mark devices
//!                          offline mid-run; displaced agents recover
//!                          through the SAME Rebalancer, with one bound
//!                          on top — the repack throttle caps the agent
//!                          fraction a single recovery repack may move,
//!                          so the failure response is itself bounded.
//!                          Re-hosted agents optionally pay a rewarm
//!                          cold start; the outage's cost surfaces as
//!                          ClusterResult::resilience (zero-cost None
//!                          when no faults are configured)
//! ```
//!
//! [`ClusterSimulator`] extends the §IV.B discrete-time methodology to M
//! GPUs so placement/rebalancing policies can be evaluated with the same
//! metrics as the single-GPU experiments: `repro::cluster_grid` sweeps
//! strategy × rebalancer (plus synthetic large-N registries) as grid
//! axes, `repro::fault_grid` layers seeded spot-eviction plans on top
//! (`agentsrv repro --exp faults`), `agentsrv repro --exp placement`
//! prints the head-to-head comparison, and the property suite asserts
//! parallel sweep runs bit-identical to sequential ones — faulted cells
//! included.

mod hierarchical;
mod placement;
mod sim;

pub use hierarchical::ClusterAllocator;
pub use placement::{headroom_decreasing, pack_decreasing, Placement,
                    PlacementScratch, PlacementStrategy};
pub use sim::{ClusterArena, ClusterBuilder, ClusterResult,
              ClusterSimulator, MigrationModel, Rebalancer};
