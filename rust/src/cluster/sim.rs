//! Cluster-level discrete-time simulation (multi-GPU §VI extension).

use crate::agents::AgentRegistry;
use crate::cluster::{pack_decreasing, ClusterAllocator, Placement};
use crate::error::Result;
use crate::metrics::Streaming;
use crate::serverless::{EconInstruments, EconomicsReport};
use crate::sim::SimConfig;
use crate::workload::WorkloadGenerator;

/// Inter-GPU migration cost model (the §VI "inter-GPU communication
/// overhead"): transferring a checkpoint takes `model_mb / mb_per_s`
/// seconds, during which the agent serves nothing.
#[derive(Debug, Clone)]
pub struct MigrationModel {
    /// Effective transfer bandwidth (NVLink/PCIe), MB/s.
    pub mb_per_s: f64,
    /// Demand-imbalance ratio (max/min GPU demand) that triggers a
    /// rebalance attempt.
    pub imbalance_threshold: f64,
    /// Minimum seconds between migrations — prevents thrash when the
    /// imbalance persists structurally (e.g. one dominant agent).
    pub cooldown_s: f64,
}

impl Default for MigrationModel {
    fn default() -> Self {
        // ~12 GB/s effective PCIe gen4 x16.
        MigrationModel {
            mb_per_s: 12_000.0,
            imbalance_threshold: 2.0,
            cooldown_s: 10.0,
        }
    }
}

/// Dense per-step buffers reused across cluster simulation runs.
///
/// The cluster loop used to allocate ~12 `Vec`s per run (three of them per
/// *step*, inside the migration and utilization blocks); a sweep worker
/// now constructs one `ClusterArena` and replays every cluster cell
/// through [`ClusterSimulator::run_with_arena`] with the buffer set —
/// per-agent rows, per-GPU rows, and the Streaming accumulators —
/// `clear()`-ed and re-sized instead of re-allocated (capacity is
/// retained across same-shaped runs).
#[derive(Debug, Clone, Default)]
pub struct ClusterArena {
    // Per-agent rows.
    queues: Vec<f64>,
    rates: Vec<f64>,
    counts: Vec<f64>,
    observed: Vec<f64>,
    alloc: Vec<f64>,
    stalled_until: Vec<f64>,
    // Model-size cache for the serverless lifecycle.
    model_mb: Vec<u32>,
    // Per-GPU rows (previously re-allocated every step).
    demand: Vec<f64>,
    gpu_cap: Vec<f64>,
    gpu_done: Vec<f64>,
    // Streaming accumulators (per-agent, per-agent, per-GPU).
    latency: Vec<Streaming>,
    throughput: Vec<Streaming>,
    gpu_util: Vec<Streaming>,
}

impl ClusterArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        ClusterArena::default()
    }

    /// Size every buffer for `n_agents` × `n_gpus` and reset its contents.
    fn reset(&mut self, n_agents: usize, n_gpus: usize) {
        for buf in [
            &mut self.queues,
            &mut self.rates,
            &mut self.counts,
            &mut self.observed,
            &mut self.alloc,
            &mut self.stalled_until,
        ] {
            buf.clear();
            buf.resize(n_agents, 0.0);
        }
        self.model_mb.clear();
        for buf in [&mut self.demand, &mut self.gpu_cap, &mut self.gpu_done]
        {
            buf.clear();
            buf.resize(n_gpus, 0.0);
        }
        for (streams, n) in [
            (&mut self.latency, n_agents),
            (&mut self.throughput, n_agents),
            (&mut self.gpu_util, n_gpus),
        ] {
            streams.clear();
            streams.resize_with(n, Streaming::new);
        }
    }
}

/// Result of one cluster simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterResult {
    /// GPUs simulated.
    pub n_gpus: usize,
    /// Mean backlog-wait latency per agent (same estimator as §IV.B).
    pub agent_latencies: Vec<f64>,
    /// Mean throughput per agent (rps).
    pub agent_throughputs: Vec<f64>,
    /// Per-GPU mean utilization (processed / allocated capacity).
    pub gpu_utilization: Vec<f64>,
    /// Migrations performed.
    pub migrations: u64,
    /// Total seconds of serving lost to migrations.
    pub migration_stall_s: f64,
    /// Billed cost (all GPUs).
    pub cost_dollars: f64,
    /// Per-agent cost, cold-start, and warm-fraction breakdown, present
    /// when the run's config enabled an
    /// [`EconomicsModel`](crate::serverless::EconomicsModel).
    pub economics: Option<EconomicsReport>,
}

impl ClusterResult {
    /// Mean of per-agent mean latencies.
    pub fn mean_latency(&self) -> f64 {
        crate::util::mean(&self.agent_latencies)
    }

    /// Aggregate throughput.
    pub fn total_throughput(&self) -> f64 {
        self.agent_throughputs.iter().sum()
    }
}

/// Multi-GPU simulator: headroom-decreasing placement, per-GPU
/// Algorithm 1 (each GPU with its own capacity), optional
/// imbalance-triggered migration with transfer stalls.
#[derive(Debug, Clone)]
pub struct ClusterSimulator {
    cfg: SimConfig,
    registry: AgentRegistry,
    /// One capacity per GPU (uniform clusters repeat one value).
    capacities: Vec<f64>,
    migration: Option<MigrationModel>,
    placement: Placement,
}

impl ClusterSimulator {
    /// Build a uniform cluster (`n_gpus` devices of `capacity_per_gpu`
    /// each); errors if the agents cannot be placed.
    pub fn new(cfg: SimConfig, registry: AgentRegistry, n_gpus: usize,
               capacity_per_gpu: f64, migration: Option<MigrationModel>)
               -> Result<ClusterSimulator> {
        if n_gpus == 0 {
            return Err(crate::error::Error::Config(
                "cluster needs >= 1 GPU".into()));
        }
        ClusterSimulator::heterogeneous(
            cfg, registry, vec![capacity_per_gpu; n_gpus], migration)
    }

    /// Build a cluster of mixed per-GPU capacities (§VI heterogeneous
    /// devices): one entry per GPU. The validated placement is stored,
    /// so every `run()` starts from it directly instead of re-solving
    /// the bin-packing.
    pub fn heterogeneous(cfg: SimConfig, registry: AgentRegistry,
                         capacities: Vec<f64>,
                         migration: Option<MigrationModel>)
                         -> Result<ClusterSimulator> {
        let placement = pack_decreasing(&registry, &capacities)?;
        Ok(ClusterSimulator {
            cfg, registry, capacities, migration, placement,
        })
    }

    /// The initial (construction-time) agent→GPU placement.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Per-GPU capacities, in device order.
    pub fn capacities(&self) -> &[f64] {
        &self.capacities
    }

    /// Run the hierarchical allocator over the configured workload.
    pub fn run(&self) -> Result<ClusterResult> {
        self.run_with_arena(&mut ClusterArena::new())
    }

    /// [`ClusterSimulator::run`], but with caller-owned buffers: repeated
    /// runs (cluster-grid sweeps, batch workers) reuse the arena instead
    /// of re-allocating the per-step buffer set on every run. Results are
    /// bit-identical to [`ClusterSimulator::run`] (asserted by the
    /// property suite).
    pub fn run_with_arena(&self, arena: &mut ClusterArena)
                          -> Result<ClusterResult> {
        let n = self.registry.len();
        let n_gpus = self.capacities.len();
        let cfg = &self.cfg;
        let mut allocator =
            ClusterAllocator::new(&self.registry, self.placement.clone());
        let mut workload = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        // Billing, per-agent metering, and the scale-to-zero lifecycle,
        // shared with the single-GPU engine via EconInstruments (the
        // economics model's pricing replaces the config meter for the
        // run).
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);

        arena.reset(n, n_gpus);
        let ClusterArena {
            queues, rates, counts, observed, alloc, stalled_until,
            model_mb, demand, gpu_cap, gpu_done, latency, throughput,
            gpu_util,
        } = arena;
        model_mb.extend(self.registry.profiles().iter().map(|p| p.model_mb));
        let base_tput = self.registry.base_tput();

        let mut migrations = 0u64;
        let mut migration_stall_s = 0.0f64;
        let mut last_migration_at = f64::NEG_INFINITY;

        for step in 0..cfg.steps {
            let now = step as f64 * cfg.dt;
            workload.step(step, cfg.dt, &mut rates[..], &mut counts[..]);
            for i in 0..n {
                queues[i] += counts[i];
                observed[i] = counts[i] / cfg.dt;
            }

            // Cluster-level rebalance: migrate the hottest agent off the
            // most demand-loaded GPU when imbalance exceeds threshold.
            let cooled_down = self.migration.as_ref().is_some_and(|m| {
                now >= last_migration_at + m.cooldown_s
                    || migrations == 0
            });
            if let (Some(mig), true) = (&self.migration, cooled_down) {
                demand.fill(0.0);
                for i in 0..n {
                    demand[allocator.placement().gpu_of[i]] +=
                        observed[i] / base_tput[i];
                }
                let (max_g, max_d) = demand.iter().cloned().enumerate()
                    .fold((0, f64::MIN), |acc, (g, d)| {
                        if d > acc.1 { (g, d) } else { acc }
                    });
                let (min_g, min_d) = demand.iter().cloned().enumerate()
                    .fold((0, f64::MAX), |acc, (g, d)| {
                        if d < acc.1 { (g, d) } else { acc }
                    });
                if max_d > mig.imbalance_threshold * min_d.max(1e-9)
                    && max_g != min_g {
                    // Smallest-min agent on the hot GPU that still fits.
                    let candidates = allocator.placement().agents_on(max_g);
                    let target_load: f64 = allocator.placement()
                        .agents_on(min_g).iter()
                        .map(|i| self.registry.min_gpu()[*i]).sum();
                    let movable = candidates.into_iter()
                        .filter(|i| candidates_fit(
                            self.registry.min_gpu()[*i], target_load,
                            self.capacities[min_g]))
                        .min_by(|a, b| self.registry.min_gpu()[*a]
                                .partial_cmp(&self.registry.min_gpu()[*b])
                                .expect("finite"));
                    if let Some(agent) = movable {
                        let transfer_s = self.registry.profile(agent)
                            .model_mb as f64 / mig.mb_per_s;
                        stalled_until[agent] = now + transfer_s;
                        migration_stall_s += transfer_s;
                        migrations += 1;
                        last_migration_at = now;
                        allocator.migrate(&self.registry, agent, min_g);
                    }
                }
            }

            allocator.allocate(&self.registry, &observed[..], &queues[..],
                               step, &self.capacities[..],
                               &mut alloc[..]);

            // Agents that cannot serve this step forfeit their allocation
            // (and are not billed for it): a migrating agent's model is
            // in flight; a scaled-to-zero agent is cold or still warming.
            // (warm_fraction tracks instance warmth only — migration
            // stalls are reported via migration_stall_s.)
            for i in 0..n {
                if now < stalled_until[i] {
                    alloc[i] = 0.0;
                }
            }
            econ.apply_lifecycle(step, cfg.dt, &queues[..], &model_mb[..],
                                 &mut alloc[..]);

            gpu_cap.fill(0.0);
            gpu_done.fill(0.0);
            let mut total_alloc = 0.0;
            for i in 0..n {
                let g = alloc[i];
                total_alloc += g;
                let rate = base_tput[i] * g;
                let cap = rate * cfg.dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                let w = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                latency[i].push(w);
                throughput[i].push(processed / cfg.dt);
                let gpu = allocator.placement().gpu_of[i];
                gpu_cap[gpu] += cap;
                gpu_done[gpu] += processed;
            }
            for g in 0..n_gpus {
                if gpu_cap[g] > 0.0 {
                    gpu_util[g].push(gpu_done[g] / gpu_cap[g]);
                }
            }
            econ.charge_step(total_alloc, &alloc[..], cfg.dt);
        }

        let (cost_dollars, _gpu_seconds, economics) =
            econ.finish(cfg.steps);

        Ok(ClusterResult {
            n_gpus,
            agent_latencies: latency.iter().map(Streaming::mean).collect(),
            agent_throughputs:
                throughput.iter().map(Streaming::mean).collect(),
            gpu_utilization: gpu_util.iter().map(Streaming::mean).collect(),
            migrations,
            migration_stall_s,
            cost_dollars,
            economics,
        })
    }
}

fn candidates_fit(min_gpu: f64, target_load: f64, capacity: f64) -> bool {
    target_load + min_gpu <= capacity + 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cluster(n_gpus: usize, cap: f64) -> ClusterSimulator {
        ClusterSimulator::new(SimConfig::paper(), AgentRegistry::paper(),
                              n_gpus, cap, None).unwrap()
    }

    #[test]
    fn one_gpu_cluster_matches_single_gpu_simulator() {
        let cluster = paper_cluster(1, 1.0).run().unwrap();
        let single = crate::sim::Simulator::new(
            SimConfig::paper(),
            crate::agents::AgentProfile::paper_agents())
            .run(&mut crate::allocator::AdaptivePolicy::default());
        assert!((cluster.mean_latency() - single.mean_latency()).abs()
                < 1e-9);
        assert!((cluster.total_throughput()
                 - single.total_throughput()).abs() < 1e-9);
        assert!((cluster.cost_dollars - single.cost_dollars).abs() < 1e-12);
    }

    #[test]
    fn two_gpus_cut_latency_and_raise_throughput() {
        let one = paper_cluster(1, 1.0).run().unwrap();
        let two = paper_cluster(2, 1.0).run().unwrap();
        assert!(two.total_throughput() > 1.5 * one.total_throughput(),
                "{} vs {}", two.total_throughput(), one.total_throughput());
        assert!(two.mean_latency() < 0.7 * one.mean_latency(),
                "{} vs {}", two.mean_latency(), one.mean_latency());
        // Cost doubles with the second device at full allocation.
        assert!(two.cost_dollars > 1.8 * one.cost_dollars);
    }

    #[test]
    fn migration_triggers_under_skew_and_costs_stall_time() {
        let mut cfg = SimConfig::paper();
        // Skew all demand onto agent 0 mid-run.
        cfg.workload_kind = crate::workload::WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Some(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        assert!(r.migrations >= 1, "no migration under 90% skew");
        assert!(r.migration_stall_s > 0.0);
        // System keeps serving everyone.
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn stored_placement_matches_ffd_and_runs_are_repeatable() {
        let sim = paper_cluster(2, 1.0);
        let expected = crate::cluster::first_fit_decreasing(
            &AgentRegistry::paper(), 2, 1.0).unwrap();
        assert_eq!(sim.placement(), &expected);
        // run() starts from the stored placement every time.
        let a = sim.run().unwrap();
        let b = sim.run().unwrap();
        assert_eq!(a.agent_latencies, b.agent_latencies);
        assert_eq!(a.migrations, b.migrations);
    }

    #[test]
    fn arena_reuse_is_bit_identical_across_cluster_shapes() {
        // One arena replayed across clusters of different GPU counts,
        // capacities, and migration settings must leave no state behind.
        let mut arena = ClusterArena::new();
        let mut skew_cfg = SimConfig::paper();
        skew_cfg.workload_kind = crate::workload::WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let migrating = ClusterSimulator::new(
            skew_cfg, AgentRegistry::paper(), 2, 1.0,
            Some(MigrationModel::default())).unwrap();
        for _ in 0..2 {
            for (gpus, cap) in [(1usize, 1.0), (2, 0.6), (4, 1.0)] {
                let sim = paper_cluster(gpus, cap);
                let reused = sim.run_with_arena(&mut arena).unwrap();
                let fresh = sim.run().unwrap();
                assert_eq!(reused, fresh, "{gpus} gpus @ {cap}");
            }
            let reused = migrating.run_with_arena(&mut arena).unwrap();
            let fresh = migrating.run().unwrap();
            assert!(fresh.migrations >= 1, "skew must trigger migration");
            assert_eq!(reused, fresh, "migrating cluster");
        }
    }

    #[test]
    fn all_warm_economics_matches_plain_cluster_billing() {
        // Enabling the paper's all-warm economics must not change the
        // cluster's total bill — it only adds the per-agent breakdown.
        let mut cfg = SimConfig::paper();
        let plain = ClusterSimulator::new(
            cfg.clone(), AgentRegistry::paper(), 2, 1.0, None)
            .unwrap().run().unwrap();
        cfg.economics =
            Some(crate::serverless::EconomicsModel::paper_all_warm());
        let econ_run = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0, None)
            .unwrap().run().unwrap();
        assert!((econ_run.cost_dollars - plain.cost_dollars).abs() < 1e-12);
        let econ = econ_run.economics.as_ref().expect("economics enabled");
        assert!((econ.total_cost() - econ_run.cost_dollars).abs() < 1e-12);
        assert_eq!(econ.cold_starts, vec![0; 4]);
        assert_eq!(econ.warm_fraction, vec![1.0; 4]);
        assert_eq!(plain.economics, None);
    }

    #[test]
    fn cluster_scale_to_zero_reclaims_idle_gpu_spend() {
        // NLP and reasoning hard-idle outside a mid-run burst: with
        // scale-to-zero their instances are torn down, the cluster bill
        // drops, and the burst pays cold starts — all visible in the
        // report.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = crate::workload::WorkloadKind::Burst {
            agents: vec![1, 3], start: 40, end: 60,
        };
        cfg.economics =
            Some(crate::serverless::EconomicsModel::paper_all_warm());
        let warm = ClusterSimulator::new(
            cfg.clone(), AgentRegistry::paper(), 2, 1.0, None)
            .unwrap().run().unwrap();
        cfg.economics = Some(
            crate::serverless::EconomicsModel::with_idle_timeout(5.0));
        let s2z = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0, None)
            .unwrap().run().unwrap();

        assert!(s2z.cost_dollars < warm.cost_dollars,
                "s2z {} vs warm {}", s2z.cost_dollars, warm.cost_dollars);
        let econ = s2z.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.cold_starts[1], 1, "{:?}", econ.cold_starts);
        assert_eq!(econ.cold_starts[3], 1, "{:?}", econ.cold_starts);
        assert!(econ.warm_fraction[1] < 1.0);
        assert_eq!(econ.warm_fraction[0], 1.0, "busy agent stays warm");
        // Everyone is eventually served.
        assert!(s2z.agent_throughputs.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn arena_reuse_is_bit_identical_with_economics_enabled() {
        let mut arena = ClusterArena::new();
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = crate::workload::WorkloadKind::Burst {
            agents: vec![1, 3], start: 40, end: 60,
        };
        cfg.economics = Some(
            crate::serverless::EconomicsModel::with_idle_timeout(5.0));
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0, None).unwrap();
        for _ in 0..2 {
            let reused = sim.run_with_arena(&mut arena).unwrap();
            let fresh = sim.run().unwrap();
            assert!(fresh.economics.is_some());
            assert_eq!(reused, fresh);
        }
    }

    #[test]
    fn infeasible_cluster_is_rejected_at_construction() {
        assert!(ClusterSimulator::new(
            SimConfig::paper(), AgentRegistry::paper(), 2, 0.3, None)
                .is_err());
        assert!(ClusterSimulator::new(
            SimConfig::paper(), AgentRegistry::paper(), 0, 1.0, None)
                .is_err());
        assert!(ClusterSimulator::heterogeneous(
            SimConfig::paper(), AgentRegistry::paper(), vec![0.5, 0.3],
            None).is_err());
    }

    #[test]
    fn heterogeneous_cluster_runs_with_per_gpu_capacities() {
        // A tight 0.6 + 0.4 mix: placement respects each device's own
        // cap, the run serves everyone, and a wider 1.0 + 0.5 mix beats
        // the single-GPU deployment on throughput.
        let sim = ClusterSimulator::heterogeneous(
            SimConfig::paper(), AgentRegistry::paper(), vec![0.6, 0.4],
            None).unwrap();
        assert_eq!(sim.capacities(), &[0.6, 0.4]);
        let expected =
            pack_decreasing(&AgentRegistry::paper(), &[0.6, 0.4]).unwrap();
        assert_eq!(sim.placement(), &expected);
        let r = sim.run().unwrap();
        assert_eq!(r.n_gpus, 2);
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0), "{r:?}");

        let one = paper_cluster(1, 1.0).run().unwrap();
        let wide = ClusterSimulator::heterogeneous(
            SimConfig::paper(), AgentRegistry::paper(), vec![1.0, 0.5],
            None).unwrap().run().unwrap();
        assert!(wide.total_throughput() > one.total_throughput(),
                "wide {} vs one {}", wide.total_throughput(),
                one.total_throughput());
    }

    #[test]
    fn uniform_heterogeneous_constructor_matches_new() {
        let a = paper_cluster(2, 1.0).run().unwrap();
        let b = ClusterSimulator::heterogeneous(
            SimConfig::paper(), AgentRegistry::paper(), vec![1.0, 1.0],
            None).unwrap().run().unwrap();
        assert_eq!(a, b);
    }
}
