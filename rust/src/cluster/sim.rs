//! Cluster-level discrete-time simulation (multi-GPU §VI extension):
//! pluggable [`PlacementStrategy`] at construction, pluggable
//! [`Rebalancer`] in the hot loop.

use crate::agents::AgentRegistry;
use crate::cluster::{ClusterAllocator, Placement, PlacementScratch,
                     PlacementStrategy};
use crate::error::Result;
use crate::metrics::Streaming;
use crate::serverless::{EconInstruments, EconomicsReport};
use crate::sim::arena::ActiveSet;
use crate::sim::fault::{ClusterFaultTracker, ResilienceReport};
use crate::sim::SimConfig;
use crate::workload::{TraceSource, WorkflowStats, WorkflowTracker,
                      WorkloadGenerator};

/// Inter-GPU migration cost model (the §VI "inter-GPU communication
/// overhead"): transferring a checkpoint takes `model_mb / mb_per_s`
/// seconds, during which the agent serves nothing.
#[derive(Debug, Clone)]
pub struct MigrationModel {
    /// Effective transfer bandwidth (NVLink/PCIe), MB/s.
    pub mb_per_s: f64,
    /// Demand-imbalance ratio (max/min GPU demand) that triggers a
    /// rebalance attempt.
    pub imbalance_threshold: f64,
    /// Minimum seconds between migrations — prevents thrash when the
    /// imbalance persists structurally (e.g. one dominant agent).
    pub cooldown_s: f64,
}

impl Default for MigrationModel {
    fn default() -> Self {
        // ~12 GB/s effective PCIe gen4 x16.
        MigrationModel {
            mb_per_s: 12_000.0,
            imbalance_threshold: 2.0,
            cooldown_s: 10.0,
        }
    }
}

/// How the cluster reacts to inter-GPU demand imbalance at runtime —
/// the rebalancing layer the hot loop dispatches on, extracted from
/// what used to be a hardwired migration block.
///
/// Both active variants share one trigger: the max/min per-GPU demand
/// ratio exceeding the model's `imbalance_threshold`, subject to its
/// cooldown. They differ in *what moves*.
#[derive(Debug, Clone)]
pub enum Rebalancer {
    /// Never migrate: the construction-time placement is final.
    Static,
    /// The original §VI heuristic: move the smallest-minimum agent off
    /// the hottest GPU onto the coolest one that can hold it, paying
    /// that agent's transfer stall. Ties on the minimum break toward
    /// the lowest agent id.
    HottestAgent(MigrationModel),
    /// Re-run the construction [`PlacementStrategy`] from scratch over
    /// the *live observed* arrival rates and migrate every agent whose
    /// device changed, each paying its own transfer stall. Only a
    /// demand-aware strategy can produce a different packing mid-run
    /// (the min-based strategies re-derive their construction placement
    /// and move nobody), which is exactly the contrast the placement
    /// grid sweeps.
    Repack(MigrationModel),
}

impl Rebalancer {
    /// Short stable identifier used in sweep-cell labels and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            Rebalancer::Static => "static",
            Rebalancer::HottestAgent(_) => "hottest",
            Rebalancer::Repack(_) => "repack",
        }
    }

    /// One rebalancer of each kind (default migration model), in a
    /// stable order — the grid axis `repro::placement_grid` sweeps.
    pub fn all() -> Vec<Rebalancer> {
        vec![
            Rebalancer::Static,
            Rebalancer::HottestAgent(MigrationModel::default()),
            Rebalancer::Repack(MigrationModel::default()),
        ]
    }

    /// The migration model of an active rebalancer.
    fn model(&self) -> Option<&MigrationModel> {
        match self {
            Rebalancer::Static => None,
            Rebalancer::HottestAgent(m) | Rebalancer::Repack(m) => Some(m),
        }
    }
}

/// Dense per-step buffers reused across cluster simulation runs.
///
/// The cluster loop used to allocate ~12 `Vec`s per run (three of them per
/// *step*, inside the migration and utilization blocks); a sweep worker
/// now constructs one `ClusterArena` and replays every cluster cell
/// through [`ClusterSimulator::run_with_arena`] with the buffer set —
/// per-agent rows, per-GPU rows, the Streaming accumulators, and the
/// repack rebalancer's placement scratch — `clear()`-ed and re-sized
/// instead of re-allocated (capacity is retained across same-shaped
/// runs).
#[derive(Debug, Clone, Default)]
pub struct ClusterArena {
    // Per-agent rows.
    queues: Vec<f64>,
    rates: Vec<f64>,
    counts: Vec<f64>,
    observed: Vec<f64>,
    alloc: Vec<f64>,
    stalled_until: Vec<f64>,
    // Model-size cache for the serverless lifecycle.
    model_mb: Vec<u32>,
    // Per-GPU rows (previously re-allocated every step).
    demand: Vec<f64>,
    gpu_cap: Vec<f64>,
    gpu_done: Vec<f64>,
    // Streaming accumulators (per-agent, per-agent, per-GPU).
    latency: Vec<Streaming>,
    throughput: Vec<Streaming>,
    gpu_util: Vec<Streaming>,
    // Mid-run placement re-solve buffers (the repack rebalancer).
    placement_scratch: PlacementScratch,
    repack_gpu_of: Vec<usize>,
    // Active-set membership for the sparse stepping tier (untouched
    // beyond reset on the dense and skip-idle paths).
    active_set: ActiveSet,
    woken: Vec<usize>,
    gpu_live: Vec<bool>,
}

impl ClusterArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        ClusterArena::default()
    }

    /// Size every buffer for `n_agents` × `n_gpus` and reset its contents.
    /// (The placement scratch needs no reset: every re-solve overwrites
    /// it fully.)
    fn reset(&mut self, n_agents: usize, n_gpus: usize) {
        for buf in [
            &mut self.queues,
            &mut self.rates,
            &mut self.counts,
            &mut self.observed,
            &mut self.alloc,
            &mut self.stalled_until,
        ] {
            buf.clear();
            buf.resize(n_agents, 0.0);
        }
        self.model_mb.clear();
        for buf in [&mut self.demand, &mut self.gpu_cap, &mut self.gpu_done]
        {
            buf.clear();
            buf.resize(n_gpus, 0.0);
        }
        for (streams, n) in [
            (&mut self.latency, n_agents),
            (&mut self.throughput, n_agents),
            (&mut self.gpu_util, n_gpus),
        ] {
            streams.clear();
            streams.resize_with(n, Streaming::new);
        }
        self.active_set.reset(n_agents);
        self.gpu_live.clear();
        self.gpu_live.resize(n_gpus, true);
    }
}

/// Result of one cluster simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterResult {
    /// GPUs simulated.
    pub n_gpus: usize,
    /// Mean backlog-wait latency per agent (same estimator as §IV.B).
    pub agent_latencies: Vec<f64>,
    /// Mean throughput per agent (rps).
    pub agent_throughputs: Vec<f64>,
    /// Per-GPU mean utilization (processed / allocated capacity).
    pub gpu_utilization: Vec<f64>,
    /// Migrations performed.
    pub migrations: u64,
    /// Total seconds of serving lost to migrations.
    pub migration_stall_s: f64,
    /// Billed cost (all GPUs).
    pub cost_dollars: f64,
    /// Per-agent cost, cold-start, and warm-fraction breakdown, present
    /// when the run's config enabled an
    /// [`EconomicsModel`](crate::serverless::EconomicsModel).
    pub economics: Option<EconomicsReport>,
    /// Eviction recovery accounting (degraded time, recovery
    /// migrations, throttled-repack disruption), present when the run's
    /// config set a non-inert
    /// [`FaultConfig`](crate::sim::fault::FaultConfig).
    pub resilience: Option<ResilienceReport>,
    /// End-to-end workflow latency stats, present when the run's config
    /// carried a [`WorkflowWorkload`](crate::workload::WorkflowWorkload).
    pub workflow: Option<WorkflowStats>,
}

impl ClusterResult {
    /// Mean of per-agent mean latencies.
    pub fn mean_latency(&self) -> f64 {
        crate::util::mean(&self.agent_latencies)
    }

    /// Aggregate throughput.
    pub fn total_throughput(&self) -> f64 {
        self.agent_throughputs.iter().sum()
    }
}

/// Multi-GPU simulator: a [`PlacementStrategy`] solved at construction,
/// per-GPU Algorithm 1 (each GPU with its own capacity), and a
/// [`Rebalancer`] reacting to demand imbalance with transfer stalls.
#[derive(Debug, Clone)]
pub struct ClusterSimulator {
    cfg: SimConfig,
    registry: AgentRegistry,
    /// One capacity per GPU (uniform clusters repeat one value).
    capacities: Vec<f64>,
    strategy: PlacementStrategy,
    rebalancer: Rebalancer,
    placement: Placement,
    /// Workflow-participant mask (empty without a workflow), fed to the
    /// co-location strategy at construction and on mid-run repacks.
    colocate: Vec<bool>,
}

/// The one construction path for [`ClusterSimulator`]: every axis —
/// device shape, placement strategy, rebalancer — is a chainable
/// setter, and `build()` validates the placement once. The remaining
/// named constructors ([`ClusterSimulator::new`],
/// [`ClusterSimulator::with_policies`]) are thin wrappers over this.
#[derive(Debug, Clone)]
pub struct ClusterBuilder {
    cfg: SimConfig,
    registry: AgentRegistry,
    capacities: Vec<f64>,
    strategy: PlacementStrategy,
    rebalancer: Rebalancer,
}

impl ClusterBuilder {
    /// A uniform device shape: `n_gpus` devices of `capacity_per_gpu`.
    pub fn gpus(mut self, n_gpus: usize, capacity_per_gpu: f64) -> Self {
        self.capacities = vec![capacity_per_gpu; n_gpus];
        self
    }

    /// A heterogeneous device shape: one capacity per GPU.
    pub fn capacities(mut self, capacities: Vec<f64>) -> Self {
        self.capacities = capacities;
        self
    }

    /// The construction-time [`PlacementStrategy`] (default
    /// headroom-decreasing). Demand-aware placement reads the config's
    /// arrival rates as the expected per-agent demand; workflow
    /// co-location reads the config's workflow spec as the group mask.
    pub fn placement(mut self, strategy: PlacementStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// The runtime [`Rebalancer`] (default [`Rebalancer::Static`]).
    pub fn rebalancer(mut self, rebalancer: Rebalancer) -> Self {
        self.rebalancer = rebalancer;
        self
    }

    /// Validate and solve the placement. Errors when no device was
    /// configured, some agent fits nowhere, or the config's workflow
    /// spec names an agent outside the registry. The solved placement
    /// is stored, so every `run()` starts from it directly instead of
    /// re-solving the bin-packing.
    pub fn build(self) -> Result<ClusterSimulator> {
        let ClusterBuilder {
            cfg, registry, capacities, strategy, rebalancer,
        } = self;
        if capacities.is_empty() {
            return Err(crate::error::Error::Config(
                "cluster needs >= 1 GPU".into()));
        }
        let colocate = match &cfg.workflow {
            Some(w) => {
                w.spec.validate_for(registry.len())?;
                let mut mask = vec![false; registry.len()];
                for stage in w.spec.stages() {
                    mask[stage.agent] = true;
                }
                mask
            }
            None => Vec::new(),
        };
        let placement = strategy.place_colocated(
            &registry, &capacities, &cfg.arrival_rates, &colocate)?;
        Ok(ClusterSimulator {
            cfg, registry, capacities, strategy, rebalancer, placement,
            colocate,
        })
    }
}

impl ClusterSimulator {
    /// Start a [`ClusterBuilder`] — the construction path every other
    /// constructor funnels through. Defaults: no devices (configure via
    /// [`ClusterBuilder::gpus`] or [`ClusterBuilder::capacities`]),
    /// headroom-decreasing placement, static rebalancer.
    pub fn builder(cfg: SimConfig, registry: AgentRegistry)
                   -> ClusterBuilder {
        ClusterBuilder {
            cfg,
            registry,
            capacities: Vec::new(),
            strategy: PlacementStrategy::HeadroomDecreasing,
            rebalancer: Rebalancer::Static,
        }
    }

    /// Build a uniform cluster (`n_gpus` devices of `capacity_per_gpu`
    /// each) under the default headroom-decreasing placement and an
    /// explicit [`Rebalancer`]; errors if the agents cannot be placed.
    pub fn new(cfg: SimConfig, registry: AgentRegistry, n_gpus: usize,
               capacity_per_gpu: f64, rebalancer: Rebalancer)
               -> Result<ClusterSimulator> {
        ClusterSimulator::builder(cfg, registry)
            .gpus(n_gpus, capacity_per_gpu)
            .rebalancer(rebalancer)
            .build()
    }

    /// Full-control constructor: an explicit [`PlacementStrategy`] ×
    /// [`Rebalancer`] over per-GPU capacities — a thin wrapper over
    /// [`ClusterSimulator::builder`].
    pub fn with_policies(cfg: SimConfig, registry: AgentRegistry,
                         capacities: Vec<f64>,
                         strategy: PlacementStrategy,
                         rebalancer: Rebalancer)
                         -> Result<ClusterSimulator> {
        ClusterSimulator::builder(cfg, registry)
            .capacities(capacities)
            .placement(strategy)
            .rebalancer(rebalancer)
            .build()
    }

    /// The initial (construction-time) agent→GPU placement.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Per-GPU capacities, in device order.
    pub fn capacities(&self) -> &[f64] {
        &self.capacities
    }

    /// The placement strategy solved at construction.
    pub fn strategy(&self) -> PlacementStrategy {
        self.strategy
    }

    /// The runtime rebalancing policy.
    pub fn rebalancer(&self) -> &Rebalancer {
        &self.rebalancer
    }

    /// Run the hierarchical allocator over the configured workload at
    /// the fastest eligible tier of the event core: the active-set
    /// sparse stepper when the config permits it (no workflow coupling,
    /// no serverless economics — the cluster's per-GPU Algorithm 1
    /// instances are stateless, so no policy gate is needed), otherwise
    /// the skip-idle core. Either way the result is bit-exact with
    /// [`ClusterSimulator::run_dense`] (asserted by the property suite).
    pub fn run(&self) -> Result<ClusterResult> {
        self.run_with_arena(&mut ClusterArena::new())
    }

    /// [`ClusterSimulator::run`] with every fast tier disabled: the
    /// dense reference path for the bit-exactness properties and the
    /// scaling bench.
    pub fn run_dense(&self) -> Result<ClusterResult> {
        self.run_inner(&mut ClusterArena::new(), false, None)
    }

    /// [`ClusterSimulator::run`] pinned to the whole-sim skip-idle tier
    /// (active-set stepping disabled): the middle rung of the
    /// dense / skip-idle / active-set ladder, kept addressable so the
    /// scaling bench and the property suite can separate the two
    /// optimizations.
    pub fn run_skip_idle(&self) -> Result<ClusterResult> {
        self.run_inner(&mut ClusterArena::new(), true, None)
    }

    /// [`ClusterSimulator::run_skip_idle`] with caller-owned buffers.
    pub fn run_skip_idle_with_arena(&self, arena: &mut ClusterArena)
                                    -> Result<ClusterResult> {
        self.run_inner(arena, true, None)
    }

    /// [`ClusterSimulator::run`], but with caller-owned buffers: repeated
    /// runs (cluster-grid sweeps, batch workers) reuse the arena instead
    /// of re-allocating the per-step buffer set on every run. Results are
    /// bit-identical to [`ClusterSimulator::run`] (asserted by the
    /// property suite).
    pub fn run_with_arena(&self, arena: &mut ClusterArena)
                          -> Result<ClusterResult> {
        if self.cfg.workflow.is_none() && self.cfg.economics.is_none() {
            self.run_active_inner(arena)
        } else {
            self.run_inner(arena, true, None)
        }
    }

    /// Replay a recorded arrival source — the in-memory CSV
    /// [`Trace`](crate::workload::trace::Trace) or the zero-copy
    /// binary [`BinTrace`](crate::workload::BinTrace) — through the
    /// cluster engine instead of the configured generator. Burst
    /// microstructure collapses by summation
    /// ([`TraceSource::fill_row`]); the source's `dt` and length
    /// override the config's. Economics and fault layers compose as in
    /// generator runs; a configured workflow conflicts (it replaces the
    /// arrival stream) and returns [`Error::Config`].
    ///
    /// [`Error::Config`]: crate::error::Error::Config
    pub fn run_source(&self, source: &dyn TraceSource)
                      -> Result<ClusterResult> {
        self.run_source_with_arena(source, &mut ClusterArena::new())
    }

    /// [`ClusterSimulator::run_source`] with caller-owned buffers.
    pub fn run_source_with_arena(&self, source: &dyn TraceSource,
                                 arena: &mut ClusterArena)
                                 -> Result<ClusterResult> {
        self.check_source(source)?;
        self.run_inner(arena, true, Some(source))
    }

    /// [`ClusterSimulator::run_source`] with the skip-idle core
    /// disabled — the dense reference for source replay, bit-identical
    /// by construction.
    pub fn run_source_dense(&self, source: &dyn TraceSource)
                            -> Result<ClusterResult> {
        self.check_source(source)?;
        self.run_inner(&mut ClusterArena::new(), false, Some(source))
    }

    fn check_source(&self, source: &dyn TraceSource) -> Result<()> {
        if self.cfg.workflow.is_some() {
            return Err(crate::error::Error::Config(
                "a workflow workload replaces the arrival stream; \
                 it cannot replay a trace".into()));
        }
        if source.agent_names().len() != self.registry.len() {
            return Err(crate::error::Error::Trace(format!(
                "trace has {} agent columns, registry has {}",
                source.agent_names().len(), self.registry.len())));
        }
        if !(source.dt() > 0.0) || !source.dt().is_finite() {
            return Err(crate::error::Error::Trace(format!(
                "trace dt must be positive and finite, got {}",
                source.dt())));
        }
        Ok(())
    }

    fn run_inner(&self, arena: &mut ClusterArena, skip_idle: bool,
                 trace: Option<&dyn TraceSource>)
                 -> Result<ClusterResult> {
        let n = self.registry.len();
        let n_gpus = self.capacities.len();
        let cfg = &self.cfg;
        // A replay source overrides the config's horizon and step size.
        let steps = trace.map(|t| t.steps()).unwrap_or(cfg.steps);
        let dt = trace.map(|t| t.dt()).unwrap_or(cfg.dt);
        let mut allocator =
            ClusterAllocator::new(&self.registry, self.placement.clone());
        let mut workload = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        // Billing, per-agent metering, and the scale-to-zero lifecycle,
        // shared with the single-GPU engine via EconInstruments (the
        // economics model's pricing replaces the config meter for the
        // run).
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);

        arena.reset(n, n_gpus);
        let ClusterArena {
            queues, rates, counts, observed, alloc, stalled_until,
            model_mb, demand, gpu_cap, gpu_done, latency, throughput,
            gpu_util, placement_scratch, repack_gpu_of, ..
        } = arena;
        model_mb.extend(self.registry.profiles().iter().map(|p| p.model_mb));
        let base_tput = self.registry.base_tput();
        let min_gpu = self.registry.min_gpu();

        let mut migrations = 0u64;
        let mut migration_stall_s = 0.0f64;
        let mut last_migration_at = f64::NEG_INFINITY;

        // Optional fault injection — evictions mark devices offline,
        // stalls extend stalled_until; zero-cost when no faults are
        // configured (every hook no-ops, same as EconInstruments).
        let mut fault = ClusterFaultTracker::new(
            cfg.faults.as_ref(), n_gpus, cfg.seed);
        let mut processed_sum = 0.0f64;

        // Optional workflow-DAG coupling: the tracker replaces the
        // workload generator as the arrival process (stage-coupled
        // injection) and meters end-to-end instance latency. A replay
        // source replaces the arrival stream outright, so the two are
        // mutually exclusive (check_source rejects the combination
        // before run_inner is reached).
        let mut wf = if trace.is_none() {
            cfg.workflow.as_ref().map(|w| WorkflowTracker::new(
                w, cfg.arrival_process, cfg.seed, n))
        } else {
            None
        };

        let mut step = 0u64;
        while step < steps {
            let now = step as f64 * dt;

            // Skip-idle fast path (same contract as the single-GPU
            // engine): with empty queues, no in-flight stall, a workload
            // window guaranteed arrival-free, no device offline and no
            // fault event due, and economics at a zero-demand fixed
            // point, every dense step in the window records exactly 0.0
            // latency/throughput, allocates nothing (each per-GPU
            // Algorithm 1 instance is stateless and zero-fills at zero
            // demand), never fires the rebalancer (zero demand cannot
            // exceed the imbalance threshold), skips GPU utilization
            // (recorded only when capacity was allocated), and bills
            // +0.0. Batch-account the window instead.
            if skip_idle
                && queues.iter().all(|q| *q == 0.0)
                && stalled_until.iter().all(|s| *s <= now)
                && econ.idle_fixed_point()
            {
                let arrivals_idle = match (trace, wf.as_ref()) {
                    (Some(src), _) => src.idle_until(step),
                    (None, Some(t)) => t.idle().then_some(u64::MAX),
                    (None, None) => workload.idle_until(step),
                };
                if let (Some(w), Some(f)) = (arrivals_idle,
                                             fault.quiet_until(step, dt))
                {
                    let until = w.min(f).min(steps);
                    if until > step {
                        let k = until - step;
                        for s in latency.iter_mut() {
                            s.push_zeros(k);
                        }
                        for s in throughput.iter_mut() {
                            s.push_zeros(k);
                        }
                        step = until;
                        continue;
                    }
                }
            }

            match (trace, wf.as_mut()) {
                (Some(src), _) => {
                    // Replay: burst microstructure collapses by
                    // summation into the per-step totals.
                    src.fill_row(step, &mut counts[..]);
                }
                (None, Some(t)) => {
                    counts.fill(0.0);
                    t.begin_step(step, dt, &mut counts[..]);
                }
                (None, None) => {
                    workload.step(step, dt, &mut rates[..],
                                  &mut counts[..]);
                }
            }
            for i in 0..n {
                queues[i] += counts[i];
                observed[i] = counts[i] / dt;
            }

            // Fault recovery: agents sitting on an evicted device
            // re-place through the Repack rebalancer against the
            // surviving capacities, throttled so one recovery repack
            // never moves more than the configured agent fraction
            // (leftover agents retry on later steps). Other rebalancers
            // wait the outage out — their agents forfeit until the
            // device returns. Each recovery move pays its transfer
            // stall plus an optional serverless rewarm cold start.
            fault.advance(now, &mut stalled_until[..]);
            if fault.any_offline(now) {
                if let Rebalancer::Repack(mig) = &self.rebalancer {
                    let needs_recovery = (0..n).any(|i| fault.gpu_offline(
                        allocator.placement().gpu_of[i], now));
                    let max_moves = fault.max_moves(n);
                    if needs_recovery && max_moves > 0 {
                        let eff =
                            fault.effective_caps(&self.capacities, now);
                        if self.strategy.place_into_colocated(
                            &self.registry, eff, &observed[..],
                            &self.colocate, placement_scratch,
                            repack_gpu_of).is_ok()
                        {
                            let mut moves = 0usize;
                            for agent in 0..n {
                                if moves >= max_moves {
                                    break;
                                }
                                let cur =
                                    allocator.placement().gpu_of[agent];
                                if !fault.gpu_offline(cur, now)
                                    || repack_gpu_of[agent] == cur {
                                    continue;
                                }
                                let transfer_s =
                                    model_mb[agent] as f64 / mig.mb_per_s;
                                let rewarm_s =
                                    fault.rewarm_s(model_mb[agent]);
                                stalled_until[agent] =
                                    now + transfer_s + rewarm_s;
                                migration_stall_s += transfer_s;
                                migrations += 1;
                                allocator.migrate(&self.registry, agent,
                                                  repack_gpu_of[agent]);
                                moves += 1;
                            }
                            if moves > 0 {
                                fault.note_recovery(moves, n);
                                last_migration_at = now;
                            }
                        }
                    }
                }
            }

            // Cluster-level rebalance, dispatched on the Rebalancer.
            // Both active variants share the trigger: per-GPU demand
            // imbalance above threshold, subject to cooldown. The check
            // path is allocation-free — demand lives in the arena and
            // the candidate scans walk `gpu_of` directly.
            if let Some(mig) = self.rebalancer.model() {
                // While a device is offline the recovery path above owns
                // placement — an imbalance repack would re-solve against
                // the full capacities and move agents back onto the
                // evicted device.
                let cooled_down = (now >= last_migration_at + mig.cooldown_s
                    || migrations == 0)
                    && !fault.any_offline(now);
                let mut triggered = (false, 0usize, 0usize);
                if cooled_down {
                    demand.fill(0.0);
                    for i in 0..n {
                        demand[allocator.placement().gpu_of[i]] +=
                            observed[i] / base_tput[i];
                    }
                    let (max_g, max_d) = demand.iter().cloned().enumerate()
                        .fold((0, f64::MIN), |acc, (g, d)| {
                            if d > acc.1 { (g, d) } else { acc }
                        });
                    let (min_g, min_d) = demand.iter().cloned().enumerate()
                        .fold((0, f64::MAX), |acc, (g, d)| {
                            if d < acc.1 { (g, d) } else { acc }
                        });
                    if max_d > mig.imbalance_threshold * min_d.max(1e-9)
                        && max_g != min_g {
                        triggered = (true, max_g, min_g);
                    }
                }
                let (fire, max_g, min_g) = triggered;
                if fire && matches!(self.rebalancer,
                                    Rebalancer::Repack(_)) {
                    // Re-solve the construction strategy over the live
                    // observed rates; every agent whose device changes
                    // pays its own transfer stall. An attempt consumes
                    // the cooldown whether or not anything moved.
                    last_migration_at = now;
                    if self.strategy.place_into_colocated(
                        &self.registry, &self.capacities,
                        &observed[..], &self.colocate, placement_scratch,
                        repack_gpu_of).is_ok()
                    {
                        let mut moved = false;
                        for agent in 0..n {
                            if repack_gpu_of[agent]
                                == allocator.placement().gpu_of[agent] {
                                continue;
                            }
                            let transfer_s =
                                model_mb[agent] as f64 / mig.mb_per_s;
                            stalled_until[agent] = now + transfer_s;
                            migration_stall_s += transfer_s;
                            migrations += 1;
                            moved = true;
                        }
                        if moved {
                            allocator.set_placement(
                                &self.registry,
                                Placement {
                                    gpu_of: repack_gpu_of.clone(),
                                    n_gpus,
                                });
                        }
                    }
                } else if fire {
                    // Hottest-agent heuristic: the smallest-minimum
                    // agent on the hot GPU that still fits on the cool
                    // one (ties toward the lowest agent id).
                    let mut target_load = 0.0;
                    for i in 0..n {
                        if allocator.placement().gpu_of[i] == min_g {
                            target_load += min_gpu[i];
                        }
                    }
                    let mut movable: Option<usize> = None;
                    for i in 0..n {
                        if allocator.placement().gpu_of[i] != max_g
                            || target_load + min_gpu[i]
                                > self.capacities[min_g] + 1e-9 {
                            continue;
                        }
                        let better = match movable {
                            None => true,
                            Some(m) => min_gpu[i] < min_gpu[m],
                        };
                        if better {
                            movable = Some(i);
                        }
                    }
                    if let Some(agent) = movable {
                        let transfer_s =
                            model_mb[agent] as f64 / mig.mb_per_s;
                        stalled_until[agent] = now + transfer_s;
                        migration_stall_s += transfer_s;
                        migrations += 1;
                        last_migration_at = now;
                        allocator.migrate(&self.registry, agent, min_g);
                    }
                }
            }

            allocator.allocate(&self.registry, &observed[..], &queues[..],
                               step, &self.capacities[..],
                               &mut alloc[..]);

            // Agents that cannot serve this step forfeit their allocation
            // (and are not billed for it): a migrating agent's model is
            // in flight; a scaled-to-zero agent is cold or still warming.
            // (warm_fraction tracks instance warmth only — migration
            // stalls are reported via migration_stall_s.)
            let mut on_offline_device = false;
            for i in 0..n {
                let offline = fault.gpu_offline(
                    allocator.placement().gpu_of[i], now);
                on_offline_device |= offline;
                if now < stalled_until[i] || offline {
                    alloc[i] = 0.0;
                }
            }
            if on_offline_device {
                fault.note_degraded(dt);
            }
            econ.apply_lifecycle(step, dt, &queues[..], &model_mb[..],
                                 &mut alloc[..]);

            gpu_cap.fill(0.0);
            gpu_done.fill(0.0);
            let mut total_alloc = 0.0;
            for i in 0..n {
                let g = alloc[i];
                total_alloc += g;
                let rate = base_tput[i] * g;
                let cap = rate * dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                processed_sum += processed;
                if processed > 0.0 {
                    if let Some(t) = wf.as_mut() {
                        t.consume(i, processed,
                                  (step as f64 + 1.0) * dt);
                    }
                }
                let w = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                latency[i].push(w);
                throughput[i].push(processed / dt);
                let gpu = allocator.placement().gpu_of[i];
                gpu_cap[gpu] += cap;
                gpu_done[gpu] += processed;
            }
            for g in 0..n_gpus {
                if gpu_cap[g] > 0.0 {
                    gpu_util[g].push(gpu_done[g] / gpu_cap[g]);
                }
            }
            econ.charge_step(total_alloc, &alloc[..], dt);
            step += 1;
        }

        let (cost_dollars, _gpu_seconds, economics) =
            econ.finish(steps);
        let resilience = fault.finish(
            processed_sum / (steps as f64 * dt).max(1e-9));

        Ok(ClusterResult {
            n_gpus,
            agent_latencies: latency.iter().map(Streaming::mean).collect(),
            agent_throughputs:
                throughput.iter().map(Streaming::mean).collect(),
            gpu_utilization: gpu_util.iter().map(Streaming::mean).collect(),
            migrations,
            migration_stall_s,
            cost_dollars,
            economics,
            resilience,
            workflow: wf.map(WorkflowTracker::finish),
        })
    }

    /// The active-set tier: per-agent sparse stepping inside busy
    /// cluster ticks.
    ///
    /// Same contract as the fluid engine's active-set stepper, with the
    /// cluster's extra machinery folded in. An active agent *settles*
    /// (leaves the iterated list) at the end of a fault-quiet step when
    /// its realized state is exactly zero (`queue == alloc == observed
    /// == 0.0`), its GPU floor is zero (`min_gpu == 0.0` — its per-GPU
    /// Algorithm 1 instance then writes exactly `+0.0` for it at zero
    /// demand regardless of the other agents' state, the cluster analog
    /// of the fluid engine's per-agent policy fixed point), any
    /// migration stall has expired by the next step, and the workload
    /// oracle ([`WorkloadGenerator::agent_idle_until`]) promises it
    /// zero arrivals until a known wake step. A settled agent's dense
    /// steps
    /// would each push exactly `0.0` latency and throughput and
    /// contribute `+0.0` to every ascending fold (rebalancer demand,
    /// per-GPU capacity/processed, billing), so its whole span is
    /// batch-accounted with one deferred `push_zeros` flush when it
    /// wakes or the run ends.
    ///
    /// Fault windows step densely: the moment
    /// [`ClusterFaultTracker::quiet_until`] stops promising quiet,
    /// every settled agent is flushed and woken and the step runs the
    /// full advance / recovery / rebalance machinery over all agents.
    /// During quiet windows the same promise licenses skipping
    /// `advance` (it would admit no event), the recovery block
    /// (`any_offline` is false), and the per-device offline checks. A
    /// firing rebalancer trigger also wakes everyone first: the
    /// hottest-agent heuristic may legally migrate a formerly-settled
    /// zero-floor agent, which must be live (and stall-accounted) when
    /// the move lands. Stalls can only be *acquired* while live — fault
    /// stalls are admitted on non-quiet steps and migration stalls
    /// behind the trigger's wake — so no settled agent ever holds one.
    ///
    /// Caller (`run_with_arena`) guarantees: no workflow, no economics.
    fn run_active_inner(&self, arena: &mut ClusterArena)
                        -> Result<ClusterResult> {
        debug_assert!(self.cfg.workflow.is_none()
                      && self.cfg.economics.is_none());
        let n = self.registry.len();
        let n_gpus = self.capacities.len();
        let cfg = &self.cfg;
        let mut allocator =
            ClusterAllocator::new(&self.registry, self.placement.clone());
        let mut workload = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        let mut econ = EconInstruments::new(
            cfg.economics.as_ref(), cfg.pricing, n, cfg.seed);

        arena.reset(n, n_gpus);
        let ClusterArena {
            queues, rates, counts, observed, alloc, stalled_until,
            model_mb, demand, gpu_cap, gpu_done, latency, throughput,
            gpu_util, placement_scratch, repack_gpu_of, active_set,
            woken, gpu_live,
        } = arena;
        model_mb.extend(self.registry.profiles().iter().map(|p| p.model_mb));
        let base_tput = self.registry.base_tput();
        let min_gpu = self.registry.min_gpu();

        let mut migrations = 0u64;
        let mut migration_stall_s = 0.0f64;
        let mut last_migration_at = f64::NEG_INFINITY;
        let mut fault = ClusterFaultTracker::new(
            cfg.faults.as_ref(), n_gpus, cfg.seed);
        let mut processed_sum = 0.0f64;

        // Flush-and-wake every settled agent: a fault transition or a
        // firing rebalancer trigger hands the step to the dense blocks,
        // which must see all n agents live.
        fn wake_all(active_set: &mut ActiveSet, latency: &mut [Streaming],
                    throughput: &mut [Streaming], step: u64, n: usize) {
            for i in 0..n {
                if active_set.stamp[i] != active_set.epoch {
                    let k = step - active_set.settled_at[i];
                    latency[i].push_zeros(k);
                    throughput[i].push_zeros(k);
                    active_set.stamp[i] = active_set.epoch;
                }
            }
            active_set.active.clear();
            active_set.active.extend(0..n);
        }

        let mut step = 0u64;
        while step < cfg.steps {
            let now = step as f64 * cfg.dt;

            // 0. Reactivate agents whose scheduled wake is due, flushing
            //    the zeros their settled span deferred.
            active_set.drain_due(step, woken);
            if !woken.is_empty() {
                for &i in woken.iter() {
                    let k = step - active_set.settled_at[i];
                    latency[i].push_zeros(k);
                    throughput[i].push_zeros(k);
                }
                active_set.active.extend_from_slice(woken);
                active_set.active.sort_unstable();
            }

            // 1. Fault gate: `Some(f)` (with f > step) licenses running
            //    this step without the fault machinery; `None` means a
            //    transition may fire, so wake everyone and step densely
            //    until the tracker goes quiet again (stale wake-heap
            //    entries are skipped on pop).
            let fault_quiet = fault.quiet_until(step, cfg.dt)
                .filter(|&f| f > step);
            if fault_quiet.is_none() && active_set.active.len() < n {
                wake_all(active_set, latency, throughput, step, n);
            }

            // 2. Whole-idle jump (the skip-idle tier, kept inside this
            //    loop): settled agents are drained and stall-free by
            //    invariant, so the cluster is provably idle as soon as
            //    every ACTIVE agent is too and the schedule-level
            //    oracles agree; zero demand can never fire the
            //    rebalancer trigger. Active agents' windows are
            //    batch-accounted here; the settled stay deferred.
            if let Some(fq) = fault_quiet {
                if active_set.active.iter()
                    .all(|&i| queues[i] == 0.0 && stalled_until[i] <= now)
                {
                    if let Some(w) = workload.idle_until(step) {
                        let until = w.min(fq).min(cfg.steps);
                        if until > step {
                            let k = until - step;
                            for &i in active_set.active.iter() {
                                latency[i].push_zeros(k);
                                throughput[i].push_zeros(k);
                            }
                            step = until;
                            continue;
                        }
                    }
                }
            }

            // 3. Arrivals, active agents only — bit-the-same draws as
            //    dense (settled agents' zero-rate steps consume no RNG,
            //    and their stale rate/count cells are never read:
            //    `observed` holds 0.0 for them by the settle condition).
            workload.step_active(step, cfg.dt, &active_set.active,
                                 &mut rates[..], &mut counts[..]);
            for &i in active_set.active.iter() {
                queues[i] += counts[i];
                observed[i] = counts[i] / cfg.dt;
            }

            // 4. Fault advance + eviction recovery, non-quiet steps only
            //    (everyone is live there). On quiet steps `advance`
            //    would admit no event and `any_offline` is false, so
            //    the whole block is a dense no-op.
            if fault_quiet.is_none() {
                fault.advance(now, &mut stalled_until[..]);
                if fault.any_offline(now) {
                    if let Rebalancer::Repack(mig) = &self.rebalancer {
                        let needs_recovery = (0..n).any(
                            |i| fault.gpu_offline(
                                allocator.placement().gpu_of[i], now));
                        let max_moves = fault.max_moves(n);
                        if needs_recovery && max_moves > 0 {
                            let eff = fault.effective_caps(
                                &self.capacities, now);
                            if self.strategy.place_into_colocated(
                                &self.registry, eff, &observed[..],
                                &self.colocate, placement_scratch,
                                repack_gpu_of).is_ok()
                            {
                                let mut moves = 0usize;
                                for agent in 0..n {
                                    if moves >= max_moves {
                                        break;
                                    }
                                    let cur =
                                        allocator.placement().gpu_of[agent];
                                    if !fault.gpu_offline(cur, now)
                                        || repack_gpu_of[agent] == cur {
                                        continue;
                                    }
                                    let transfer_s = model_mb[agent] as f64
                                        / mig.mb_per_s;
                                    let rewarm_s =
                                        fault.rewarm_s(model_mb[agent]);
                                    stalled_until[agent] =
                                        now + transfer_s + rewarm_s;
                                    migration_stall_s += transfer_s;
                                    migrations += 1;
                                    allocator.migrate(
                                        &self.registry, agent,
                                        repack_gpu_of[agent]);
                                    moves += 1;
                                }
                                if moves > 0 {
                                    fault.note_recovery(moves, n);
                                    last_migration_at = now;
                                }
                            }
                        }
                    }
                }
            }

            // 5. Rebalancer trigger scan over active agents only — the
            //    settled contribute `observed / base_tput == +0.0` to
            //    the dense demand fold. A firing trigger wakes everyone
            //    before the migration blocks run, exactly as dense sees
            //    them.
            if let Some(mig) = self.rebalancer.model() {
                let cooled_down = (now >= last_migration_at + mig.cooldown_s
                    || migrations == 0)
                    && !fault.any_offline(now);
                let mut triggered = (false, 0usize, 0usize);
                if cooled_down {
                    demand.fill(0.0);
                    for &i in active_set.active.iter() {
                        demand[allocator.placement().gpu_of[i]] +=
                            observed[i] / base_tput[i];
                    }
                    let (max_g, max_d) = demand.iter().cloned().enumerate()
                        .fold((0, f64::MIN), |acc, (g, d)| {
                            if d > acc.1 { (g, d) } else { acc }
                        });
                    let (min_g, min_d) = demand.iter().cloned().enumerate()
                        .fold((0, f64::MAX), |acc, (g, d)| {
                            if d < acc.1 { (g, d) } else { acc }
                        });
                    if max_d > mig.imbalance_threshold * min_d.max(1e-9)
                        && max_g != min_g {
                        triggered = (true, max_g, min_g);
                    }
                }
                let (fire, max_g, min_g) = triggered;
                if fire && active_set.active.len() < n {
                    wake_all(active_set, latency, throughput, step, n);
                }
                if fire && matches!(self.rebalancer,
                                    Rebalancer::Repack(_)) {
                    last_migration_at = now;
                    if self.strategy.place_into_colocated(
                        &self.registry, &self.capacities,
                        &observed[..], &self.colocate, placement_scratch,
                        repack_gpu_of).is_ok()
                    {
                        let mut moved = false;
                        for agent in 0..n {
                            if repack_gpu_of[agent]
                                == allocator.placement().gpu_of[agent] {
                                continue;
                            }
                            let transfer_s =
                                model_mb[agent] as f64 / mig.mb_per_s;
                            stalled_until[agent] = now + transfer_s;
                            migration_stall_s += transfer_s;
                            migrations += 1;
                            moved = true;
                        }
                        if moved {
                            allocator.set_placement(
                                &self.registry,
                                Placement {
                                    gpu_of: repack_gpu_of.clone(),
                                    n_gpus,
                                });
                        }
                    }
                } else if fire {
                    let mut target_load = 0.0;
                    for i in 0..n {
                        if allocator.placement().gpu_of[i] == min_g {
                            target_load += min_gpu[i];
                        }
                    }
                    let mut movable: Option<usize> = None;
                    for i in 0..n {
                        if allocator.placement().gpu_of[i] != max_g
                            || target_load + min_gpu[i]
                                > self.capacities[min_g] + 1e-9 {
                            continue;
                        }
                        let better = match movable {
                            None => true,
                            Some(m) => min_gpu[i] < min_gpu[m],
                        };
                        if better {
                            movable = Some(i);
                        }
                    }
                    if let Some(agent) = movable {
                        let transfer_s =
                            model_mb[agent] as f64 / mig.mb_per_s;
                        stalled_until[agent] = now + transfer_s;
                        migration_stall_s += transfer_s;
                        migrations += 1;
                        last_migration_at = now;
                        allocator.migrate(&self.registry, agent, min_g);
                    }
                }
            }

            // 6. Allocation, masked to the devices hosting at least one
            //    active agent. A fully-settled device's cells keep
            //    their exact `+0.0` — bit-for-bit what dense would
            //    rewrite ([`ClusterAllocator::allocate_masked`]).
            gpu_live.fill(false);
            for &i in active_set.active.iter() {
                gpu_live[allocator.placement().gpu_of[i]] = true;
            }
            allocator.allocate_masked(
                &self.registry, &observed[..], &queues[..], step,
                &self.capacities[..], Some(&gpu_live[..]),
                &mut alloc[..]);

            // 7. Forfeiture. Quiet steps: no device is offline and no
            //    settled agent holds a live stall, so only active
            //    agents' stalls matter (`note_degraded` can't fire).
            //    Non-quiet steps: the full dense loop over all n.
            match fault_quiet {
                Some(_) => {
                    for &i in active_set.active.iter() {
                        if now < stalled_until[i] {
                            alloc[i] = 0.0;
                        }
                    }
                }
                None => {
                    let mut on_offline_device = false;
                    for i in 0..n {
                        let offline = fault.gpu_offline(
                            allocator.placement().gpu_of[i], now);
                        on_offline_device |= offline;
                        if now < stalled_until[i] || offline {
                            alloc[i] = 0.0;
                        }
                    }
                    if on_offline_device {
                        fault.note_degraded(cfg.dt);
                    }
                }
            }
            econ.apply_lifecycle(step, cfg.dt, &queues[..],
                                 &model_mb[..], &mut alloc[..]);

            // 8. Processing, active agents only; the per-GPU and billing
            //    folds equal the dense 0..n folds with the settled
            //    agents' `+0.0` terms elided.
            gpu_cap.fill(0.0);
            gpu_done.fill(0.0);
            let mut total_alloc = 0.0;
            for &i in active_set.active.iter() {
                let g = alloc[i];
                total_alloc += g;
                let rate = base_tput[i] * g;
                let cap = rate * cfg.dt;
                let processed = queues[i].min(cap);
                queues[i] -= processed;
                processed_sum += processed;
                let w = if rate > 0.0 {
                    (queues[i] / rate).min(cfg.latency_cap_s)
                } else if queues[i] > 0.0 {
                    cfg.latency_cap_s
                } else {
                    0.0
                };
                latency[i].push(w);
                throughput[i].push(processed / cfg.dt);
                let gpu = allocator.placement().gpu_of[i];
                gpu_cap[gpu] += cap;
                gpu_done[gpu] += processed;
            }
            for g in 0..n_gpus {
                if gpu_cap[g] > 0.0 {
                    gpu_util[g].push(gpu_done[g] / gpu_cap[g]);
                }
            }
            econ.charge_step(total_alloc, &alloc[..], cfg.dt);

            // 9. Settle scan, quiet steps only (fault windows wake
            //    everyone anyway, so settling inside one is churn).
            //    `observed == 0.0` guards the stale-buffer hazard: the
            //    allocator and the rebalancer read the full slices, so
            //    a settled agent must hold exact zeros in every cell a
            //    later step sees.
            if fault_quiet.is_some() {
                let next = step + 1;
                let next_now = next as f64 * cfg.dt;
                let mut any_settled = false;
                let mut idx = 0;
                while idx < active_set.active.len() {
                    let i = active_set.active[idx];
                    idx += 1;
                    if queues[i] != 0.0 || alloc[i] != 0.0
                        || observed[i] != 0.0 || min_gpu[i] != 0.0
                        || stalled_until[i] > next_now
                    {
                        continue;
                    }
                    let Some(w) = workload.agent_idle_until(i, next)
                    else {
                        continue;
                    };
                    if w <= next {
                        continue;
                    }
                    active_set.settle(i, next, w);
                    any_settled = true;
                }
                if any_settled {
                    let epoch = active_set.epoch;
                    let stamp = &active_set.stamp;
                    active_set.active.retain(|&i| stamp[i] == epoch);
                }
            }

            step += 1;
        }

        // Flush every still-settled agent's deferred zero span to the
        // end of the run.
        for i in 0..n {
            if active_set.stamp[i] != active_set.epoch {
                let k = cfg.steps - active_set.settled_at[i];
                latency[i].push_zeros(k);
                throughput[i].push_zeros(k);
            }
        }

        let (cost_dollars, _gpu_seconds, economics) =
            econ.finish(cfg.steps);
        let resilience = fault.finish(
            processed_sum / (cfg.steps as f64 * cfg.dt).max(1e-9));

        Ok(ClusterResult {
            n_gpus,
            agent_latencies: latency.iter().map(Streaming::mean).collect(),
            agent_throughputs:
                throughput.iter().map(Streaming::mean).collect(),
            gpu_utilization: gpu_util.iter().map(Streaming::mean).collect(),
            migrations,
            migration_stall_s,
            cost_dollars,
            economics,
            resilience,
            workflow: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadKind;

    fn paper_cluster(n_gpus: usize, cap: f64) -> ClusterSimulator {
        ClusterSimulator::new(SimConfig::paper(), AgentRegistry::paper(),
                              n_gpus, cap, Rebalancer::Static).unwrap()
    }

    #[test]
    fn one_gpu_cluster_matches_single_gpu_simulator() {
        let cluster = paper_cluster(1, 1.0).run().unwrap();
        let single = crate::sim::Simulator::new(
            SimConfig::paper(),
            crate::agents::AgentProfile::paper_agents())
            .run(&mut crate::allocator::AdaptivePolicy::default());
        assert!((cluster.mean_latency() - single.mean_latency()).abs()
                < 1e-9);
        assert!((cluster.total_throughput()
                 - single.total_throughput()).abs() < 1e-9);
        assert!((cluster.cost_dollars - single.cost_dollars).abs() < 1e-12);
    }

    #[test]
    fn two_gpus_cut_latency_and_raise_throughput() {
        let one = paper_cluster(1, 1.0).run().unwrap();
        let two = paper_cluster(2, 1.0).run().unwrap();
        assert!(two.total_throughput() > 1.5 * one.total_throughput(),
                "{} vs {}", two.total_throughput(), one.total_throughput());
        assert!(two.mean_latency() < 0.7 * one.mean_latency(),
                "{} vs {}", two.mean_latency(), one.mean_latency());
        // Cost doubles with the second device at full allocation.
        assert!(two.cost_dollars > 1.8 * one.cost_dollars);
    }

    #[test]
    fn migration_triggers_under_skew_and_costs_stall_time() {
        let mut cfg = SimConfig::paper();
        // Skew all demand onto agent 0 mid-run.
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::HottestAgent(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        assert!(r.migrations >= 1, "no migration under 90% skew");
        assert!(r.migration_stall_s > 0.0);
        // System keeps serving everyone.
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn repack_rebalancer_moves_agents_under_skew() {
        // Demand-aware placement solved on the base rates, then 90 %
        // dominance at runtime: the repack re-solve sees the skewed
        // observed rates, isolates the hot agent, and charges every
        // moved agent its transfer stall.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let sim = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.0, 1.0],
            PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        assert!(r.migrations >= 1, "repack never moved anyone");
        assert!(r.migration_stall_s > 0.0);
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn repack_is_inert_for_min_based_strategies() {
        // A min-based strategy re-derives its construction placement at
        // every repack attempt, so nothing ever moves — Repack degrades
        // to Static for it, bit for bit.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let repack = ClusterSimulator::with_policies(
            cfg.clone(), AgentRegistry::paper(), vec![1.0, 1.0],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap()
            .run().unwrap();
        let fixed = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.0, 1.0],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Static).unwrap().run().unwrap();
        assert_eq!(repack.migrations, 0);
        assert_eq!(repack, fixed);
    }

    #[test]
    fn builder_is_the_single_construction_path() {
        // Defaults: headroom placement, static rebalancer.
        let built = ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
            .gpus(2, 1.0).build().unwrap();
        assert_eq!(built.rebalancer().name(), "static");
        assert_eq!(built.strategy(),
                   PlacementStrategy::HeadroomDecreasing);
        // The named constructors are thin wrappers: same placement,
        // same run, bit for bit.
        let named = paper_cluster(2, 1.0);
        assert_eq!(built.placement(), named.placement());
        assert_eq!(built.run().unwrap(), named.run().unwrap());
        // Every axis is a chainable setter.
        let full = ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
            .capacities(vec![1.0, 0.75])
            .placement(PlacementStrategy::DemandAware)
            .rebalancer(Rebalancer::HottestAgent(
                MigrationModel::default()))
            .build().unwrap();
        assert_eq!(full.rebalancer().name(), "hottest");
        assert_eq!(full.strategy(), PlacementStrategy::DemandAware);
        assert_eq!(full.capacities(), &[1.0, 0.75]);
        let twin = ClusterSimulator::with_policies(
            SimConfig::paper(), AgentRegistry::paper(),
            vec![1.0, 0.75], PlacementStrategy::DemandAware,
            Rebalancer::HottestAgent(MigrationModel::default()))
            .unwrap();
        assert_eq!(full.run().unwrap(), twin.run().unwrap());
        // No devices configured is a construction error.
        assert!(ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper()).build().is_err());
    }

    #[test]
    fn stored_placement_matches_packer_and_runs_are_repeatable() {
        let sim = paper_cluster(2, 1.0);
        let expected = crate::cluster::headroom_decreasing(
            &AgentRegistry::paper(), 2, 1.0).unwrap();
        assert_eq!(sim.placement(), &expected);
        // run() starts from the stored placement every time.
        let a = sim.run().unwrap();
        let b = sim.run().unwrap();
        assert_eq!(a.agent_latencies, b.agent_latencies);
        assert_eq!(a.migrations, b.migrations);
    }

    #[test]
    fn every_strategy_runs_and_serves_everyone() {
        for strategy in PlacementStrategy::all() {
            let sim = ClusterSimulator::with_policies(
                SimConfig::paper(), AgentRegistry::paper(),
                vec![1.0, 0.75, 0.5, 0.25], strategy,
                Rebalancer::Static).unwrap();
            assert_eq!(sim.strategy(), strategy);
            let expected = strategy.place(
                &AgentRegistry::paper(), &[1.0, 0.75, 0.5, 0.25],
                &SimConfig::paper().arrival_rates).unwrap();
            assert_eq!(sim.placement(), &expected, "{}", strategy.name());
            let r = sim.run().unwrap();
            assert_eq!(r.n_gpus, 4);
            assert!(r.agent_throughputs.iter().all(|t| *t > 0.0),
                    "{}: {r:?}", strategy.name());
        }
    }

    #[test]
    fn arena_reuse_is_bit_identical_across_cluster_shapes() {
        // One arena replayed across clusters of different GPU counts,
        // capacities, and migration settings must leave no state behind.
        let mut arena = ClusterArena::new();
        let mut skew_cfg = SimConfig::paper();
        skew_cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let migrating = ClusterSimulator::new(
            skew_cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::HottestAgent(MigrationModel::default())).unwrap();
        for _ in 0..2 {
            for (gpus, cap) in [(1usize, 1.0), (2, 0.6), (4, 1.0)] {
                let sim = paper_cluster(gpus, cap);
                let reused = sim.run_with_arena(&mut arena).unwrap();
                let fresh = sim.run().unwrap();
                assert_eq!(reused, fresh, "{gpus} gpus @ {cap}");
            }
            let reused = migrating.run_with_arena(&mut arena).unwrap();
            let fresh = migrating.run().unwrap();
            assert!(fresh.migrations >= 1, "skew must trigger migration");
            assert_eq!(reused, fresh, "migrating cluster");
        }
    }

    #[test]
    fn arena_reuse_is_bit_identical_across_strategies_and_rebalancers() {
        // The full placement axis through one arena: every strategy ×
        // every rebalancer kind, under skew so the active rebalancers
        // fire.
        let mut arena = ClusterArena::new();
        for strategy in PlacementStrategy::all() {
            for rebalancer in Rebalancer::all() {
                let mut cfg = SimConfig::paper();
                cfg.workload_kind = WorkloadKind::Dominance {
                    agent: 0, share: 0.9,
                };
                let sim = ClusterSimulator::with_policies(
                    cfg, AgentRegistry::paper(),
                    vec![1.0, 0.75, 0.5, 0.25], strategy, rebalancer)
                    .unwrap();
                let name = format!("{}/{}", strategy.name(),
                                   sim.rebalancer().name());
                let reused = sim.run_with_arena(&mut arena).unwrap();
                let fresh = sim.run().unwrap();
                assert_eq!(reused, fresh, "{name}");
            }
        }
    }

    #[test]
    fn all_warm_economics_matches_plain_cluster_billing() {
        // Enabling the paper's all-warm economics must not change the
        // cluster's total bill — it only adds the per-agent breakdown.
        let mut cfg = SimConfig::paper();
        let plain = ClusterSimulator::new(
            cfg.clone(), AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap().run().unwrap();
        cfg.economics =
            Some(crate::serverless::EconomicsModel::paper_all_warm());
        let econ_run = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap().run().unwrap();
        assert!((econ_run.cost_dollars - plain.cost_dollars).abs() < 1e-12);
        let econ = econ_run.economics.as_ref().expect("economics enabled");
        assert!((econ.total_cost() - econ_run.cost_dollars).abs() < 1e-12);
        assert_eq!(econ.cold_starts, vec![0; 4]);
        assert_eq!(econ.warm_fraction, vec![1.0; 4]);
        assert_eq!(plain.economics, None);
    }

    #[test]
    fn cluster_scale_to_zero_reclaims_idle_gpu_spend() {
        // NLP and reasoning hard-idle outside a mid-run burst: with
        // scale-to-zero their instances are torn down, the cluster bill
        // drops, and the burst pays cold starts — all visible in the
        // report.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1, 3], start: 40, end: 60,
        };
        cfg.economics =
            Some(crate::serverless::EconomicsModel::paper_all_warm());
        let warm = ClusterSimulator::new(
            cfg.clone(), AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap().run().unwrap();
        cfg.economics = Some(
            crate::serverless::EconomicsModel::with_idle_timeout(5.0));
        let s2z = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap().run().unwrap();

        assert!(s2z.cost_dollars < warm.cost_dollars,
                "s2z {} vs warm {}", s2z.cost_dollars, warm.cost_dollars);
        let econ = s2z.economics.as_ref().expect("economics enabled");
        assert_eq!(econ.cold_starts[1], 1, "{:?}", econ.cold_starts);
        assert_eq!(econ.cold_starts[3], 1, "{:?}", econ.cold_starts);
        assert!(econ.warm_fraction[1] < 1.0);
        assert_eq!(econ.warm_fraction[0], 1.0, "busy agent stays warm");
        // Everyone is eventually served.
        assert!(s2z.agent_throughputs.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn arena_reuse_is_bit_identical_with_economics_enabled() {
        let mut arena = ClusterArena::new();
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1, 3], start: 40, end: 60,
        };
        cfg.economics = Some(
            crate::serverless::EconomicsModel::with_idle_timeout(5.0));
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap();
        for _ in 0..2 {
            let reused = sim.run_with_arena(&mut arena).unwrap();
            let fresh = sim.run().unwrap();
            assert!(fresh.economics.is_some());
            assert_eq!(reused, fresh);
        }
    }

    #[test]
    fn eviction_of_high_priority_host_recovers_via_throttled_repack() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Find the device hosting the High-priority reasoning agent.
        let base = ClusterSimulator::with_policies(
            SimConfig::paper(), AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let victim_gpu = base.placement().gpu_of[3];
        let displaced = base.placement().gpu_of.iter()
            .filter(|g| **g == victim_gpu).count();
        assert!(displaced < 4, "placement must use both devices");

        // Throttle to one move per repack (⌊0.25 · 4⌋ = 1): recovery
        // spreads over several steps instead of one big shuffle.
        let throttle = 0.25;
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction {
                t: 20.0, gpu: victim_gpu, duration: 40.0,
            },
        ])).with_repack_throttle(throttle));
        let sim = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        let rep = r.resilience.as_ref().expect("faults configured");
        // Every displaced agent eventually re-placed (min-GPU
        // feasibility held: the surviving 1.2 device fits all four).
        assert!(r.migrations >= displaced as u64,
                "{} recovery moves for {displaced} displaced agents",
                r.migrations);
        assert!(rep.retried >= displaced as u64);
        // No single recovery repack exceeded the configured fraction.
        assert!(rep.disruption <= throttle + 1e-9,
                "disruption {} vs throttle {throttle}", rep.disruption);
        assert!(rep.disruption > 0.0);
        assert!(rep.recovery_time_s < 40.0,
                "recovery must beat the outage, got {}",
                rep.recovery_time_s);
        // Everyone — including the High-priority agent — keeps serving.
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0), "{r:?}");
    }

    #[test]
    fn recovery_repack_is_fully_throttleable() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // A fraction below 1/n disables recovery: agents wait the
        // outage out exactly like the static rebalancer.
        let base = ClusterSimulator::with_policies(
            SimConfig::paper(), AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let victim_gpu = base.placement().gpu_of[3];
        let mut cfg = SimConfig::paper();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction {
                t: 20.0, gpu: victim_gpu, duration: 40.0,
            },
        ])).with_repack_throttle(0.1));
        let sim = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        assert_eq!(r.migrations, 0, "⌊0.1 · 4⌋ = 0 moves allowed");
        let rep = r.resilience.as_ref().expect("faults configured");
        assert_eq!(rep.disruption, 0.0);
        assert!((rep.recovery_time_s - 40.0).abs() < 1e-9,
                "agents sat out the whole outage, got {}",
                rep.recovery_time_s);
    }

    #[test]
    fn throttled_repack_beats_static_under_eviction() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let fault_cfg = |rebalancer: Rebalancer| {
            let base = ClusterSimulator::with_policies(
                SimConfig::paper(), AgentRegistry::paper(),
                vec![1.2, 1.2], PlacementStrategy::HeadroomDecreasing,
                rebalancer.clone()).unwrap();
            let victim = base.placement().gpu_of[3];
            let mut cfg = SimConfig::paper();
            cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
                FaultEvent::GpuEviction {
                    t: 20.0, gpu: victim, duration: 40.0,
                },
            ])).with_repack_throttle(0.5));
            ClusterSimulator::with_policies(
                cfg, AgentRegistry::paper(), vec![1.2, 1.2],
                PlacementStrategy::HeadroomDecreasing, rebalancer)
                .unwrap().run().unwrap()
        };
        let repack =
            fault_cfg(Rebalancer::Repack(MigrationModel::default()));
        let fixed = fault_cfg(Rebalancer::Static);
        let r_rep = repack.resilience.as_ref().unwrap();
        let r_fix = fixed.resilience.as_ref().unwrap();
        assert!(r_rep.goodput > r_fix.goodput,
                "recovery must out-serve waiting: {} vs {}",
                r_rep.goodput, r_fix.goodput);
        assert!(r_rep.recovery_time_s < r_fix.recovery_time_s,
                "recovery shortens degraded time: {} vs {}",
                r_rep.recovery_time_s, r_fix.recovery_time_s);
        assert_eq!(fixed.migrations, 0);
    }

    #[test]
    fn eviction_on_rebalance_window_is_deterministic() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Eviction landing exactly on the imbalance-rebalance window
        // (t = cooldown_s = 10.0) while dominance skew has the repack
        // rebalancer firing: replays and arena reuse stay bit-identical.
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 10.0, gpu: 0, duration: 15.0 },
        ])).with_repack_throttle(0.5));
        let sim = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let a = sim.run().unwrap();
        let b = sim.run().unwrap();
        assert_eq!(a, b);
        let mut arena = ClusterArena::new();
        let c = sim.run_with_arena(&mut arena).unwrap();
        assert_eq!(a, c);
        assert!(a.resilience.is_some());
    }

    #[test]
    fn rewarm_cold_start_costs_recovery_goodput() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        let run = |rewarm: bool| {
            let base = ClusterSimulator::with_policies(
                SimConfig::paper(), AgentRegistry::paper(),
                vec![1.2, 1.2], PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Repack(MigrationModel::default())).unwrap();
            let victim = base.placement().gpu_of[3];
            let mut fc = FaultConfig::new(FaultPlan::new(vec![
                FaultEvent::GpuEviction {
                    t: 20.0, gpu: victim, duration: 40.0,
                },
            ])).with_repack_throttle(0.5);
            if rewarm {
                fc = fc.with_rewarm(
                    crate::serverless::ColdStartModel::default_platform());
            }
            let mut cfg = SimConfig::paper();
            cfg.faults = Some(fc);
            ClusterSimulator::with_policies(
                cfg, AgentRegistry::paper(), vec![1.2, 1.2],
                PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Repack(MigrationModel::default()))
                .unwrap().run().unwrap()
        };
        let cold = run(true);
        let warm = run(false);
        assert!(cold.resilience.as_ref().unwrap().goodput
                < warm.resilience.as_ref().unwrap().goodput,
                "rewarm must cost serving time");
        // The rewarm draw is seeded: the run replays identically.
        assert_eq!(cold, run(true));
    }

    #[test]
    fn empty_fault_plan_cluster_is_bit_identical_to_plain() {
        use crate::sim::fault::{FaultConfig, FaultPlan};
        let mut cfg = SimConfig::paper();
        cfg.workload_kind = WorkloadKind::Dominance {
            agent: 0, share: 0.9,
        };
        let plain = ClusterSimulator::with_policies(
            cfg.clone(), AgentRegistry::paper(), vec![1.0, 1.0],
            PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default()))
            .unwrap().run().unwrap();
        cfg.faults = Some(FaultConfig::new(FaultPlan::empty()));
        let gated = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.0, 1.0],
            PlacementStrategy::DemandAware,
            Rebalancer::Repack(MigrationModel::default()))
            .unwrap().run().unwrap();
        assert_eq!(plain, gated);
        assert!(gated.resilience.is_none());
    }

    /// Burst-only workload (the only traffic is two agents' mid-run
    /// burst) — the shape where the cluster skip-idle core fires.
    fn cluster_burst_cfg() -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0, 40.0, 0.0, 30.0];
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1, 3], start: 40, end: 60,
        };
        cfg
    }

    #[test]
    fn cluster_skip_idle_is_bit_exact_with_dense() {
        use crate::workload::ArrivalProcess;
        // Every rebalancer, deterministic and Poisson arrivals: run()
        // (skip-idle on) must equal run_dense() exactly — ClusterResult
        // PartialEq is bit-exact.
        for poisson in [false, true] {
            for rebalancer in Rebalancer::all() {
                let mut cfg = cluster_burst_cfg();
                if poisson {
                    cfg.arrival_process = ArrivalProcess::Poisson;
                }
                let sim = ClusterSimulator::with_policies(
                    cfg, AgentRegistry::paper(), vec![1.0, 0.75],
                    PlacementStrategy::HeadroomDecreasing, rebalancer)
                    .unwrap();
                let name = sim.rebalancer().name();
                assert_eq!(sim.run().unwrap(), sim.run_dense().unwrap(),
                           "{name} poisson={poisson}");
            }
        }
        // All-zero workload: the whole run is one skipped window.
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = vec![0.0; 4];
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap();
        let skip = sim.run().unwrap();
        assert_eq!(skip, sim.run_dense().unwrap());
        assert_eq!(skip.cost_dollars, 0.0);
    }

    #[test]
    fn cluster_skip_idle_is_bit_exact_under_economics_and_faults() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // Scale-to-zero: the pre-burst window is dense until every
        // instance goes cold, then skipped; wakes must land identically.
        let mut cfg = cluster_burst_cfg();
        cfg.economics = Some(
            crate::serverless::EconomicsModel::with_idle_timeout(3.0));
        let sim = ClusterSimulator::new(
            cfg, AgentRegistry::paper(), 2, 1.0,
            Rebalancer::Static).unwrap();
        let skip = sim.run().unwrap();
        assert_eq!(skip, sim.run_dense().unwrap());
        assert!(skip.economics.is_some());

        // Faults inside the idle windows: the quiet cursor must stop
        // the skip at each event's first step (eviction at t=10 while
        // everything idles; a stall overlapping the burst).
        let mut cfg = cluster_burst_cfg();
        cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 10.0, gpu: 0, duration: 5.0 },
            FaultEvent::AgentStall {
                t: 45.0, agent: 1, factor: 3.0, duration: 10.0,
            },
        ])).with_repack_throttle(0.5));
        let sim = ClusterSimulator::with_policies(
            cfg, AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Repack(MigrationModel::default())).unwrap();
        let skip = sim.run().unwrap();
        assert_eq!(skip, sim.run_dense().unwrap());
        assert!(skip.resilience.is_some());
    }

    #[test]
    fn infeasible_cluster_is_rejected_at_construction() {
        assert!(ClusterSimulator::new(
            SimConfig::paper(), AgentRegistry::paper(), 2, 0.3,
            Rebalancer::Static).is_err());
        assert!(ClusterSimulator::new(
            SimConfig::paper(), AgentRegistry::paper(), 0, 1.0,
            Rebalancer::Static).is_err());
        assert!(ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
                .capacities(vec![0.5, 0.3]).build().is_err());
        assert!(ClusterSimulator::with_policies(
            SimConfig::paper(), AgentRegistry::paper(), vec![0.5, 0.3],
            PlacementStrategy::BestFitDecreasing, Rebalancer::Static)
                .is_err());
    }

    #[test]
    fn heterogeneous_cluster_runs_with_per_gpu_capacities() {
        // A tight 0.6 + 0.4 mix: placement respects each device's own
        // cap, the run serves everyone, and a wider 1.0 + 0.5 mix beats
        // the single-GPU deployment on throughput.
        let sim = ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
            .capacities(vec![0.6, 0.4]).build().unwrap();
        assert_eq!(sim.capacities(), &[0.6, 0.4]);
        let expected = crate::cluster::pack_decreasing(
            &AgentRegistry::paper(), &[0.6, 0.4]).unwrap();
        assert_eq!(sim.placement(), &expected);
        let r = sim.run().unwrap();
        assert_eq!(r.n_gpus, 2);
        assert!(r.agent_throughputs.iter().all(|t| *t > 0.0), "{r:?}");

        let one = paper_cluster(1, 1.0).run().unwrap();
        let wide = ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
            .capacities(vec![1.0, 0.5]).build().unwrap().run().unwrap();
        assert!(wide.total_throughput() > one.total_throughput(),
                "wide {} vs one {}", wide.total_throughput(),
                one.total_throughput());
    }

    #[test]
    fn uniform_builder_capacities_match_new() {
        let a = paper_cluster(2, 1.0).run().unwrap();
        let b = ClusterSimulator::builder(
            SimConfig::paper(), AgentRegistry::paper())
            .capacities(vec![1.0, 1.0]).build().unwrap().run().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn workflow_cluster_surfaces_stats_and_stays_bit_exact() {
        use crate::workload::WorkflowWorkload;
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::paper());
        let sim = ClusterSimulator::builder(cfg, AgentRegistry::paper())
            .gpus(2, 1.0)
            .placement(PlacementStrategy::WorkflowColocate)
            .build().unwrap();
        let r = sim.run().unwrap();
        let wf = r.workflow.as_ref().expect("workflow configured");
        assert!(wf.started > 0);
        assert!(wf.completed > 0);
        assert!(wf.mean_s() > 0.0);
        // Skip-idle twin is bit-identical (ClusterResult PartialEq).
        assert_eq!(r, sim.run_dense().unwrap());
        // Plain clusters report no workflow stats.
        assert!(paper_cluster(2, 1.0).run().unwrap().workflow.is_none());
    }

    #[test]
    fn colocate_builder_masks_workflow_participants() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        // An nlp -> reasoning chain on two 0.75 devices: headroom
        // packing splits the pair (0.35 anchors device 0, 0.30 takes
        // the emptier device 1); co-location hosts both on one device.
        let spec = WorkflowSpec::chain("pair", &[1, 3]);
        let mut cfg = SimConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(spec, 0.5));
        let hd = ClusterSimulator::builder(
            cfg.clone(), AgentRegistry::paper())
            .capacities(vec![0.75, 0.75])
            .build().unwrap();
        assert_ne!(hd.placement().gpu_of[1], hd.placement().gpu_of[3],
                   "headroom splits the pair: {:?}", hd.placement().gpu_of);
        let co = ClusterSimulator::builder(
            cfg.clone(), AgentRegistry::paper())
            .capacities(vec![0.75, 0.75])
            .placement(PlacementStrategy::WorkflowColocate)
            .build().unwrap();
        assert_eq!(co.placement().gpu_of[1], co.placement().gpu_of[3],
                   "chain agents co-hosted: {:?}", co.placement().gpu_of);
        // A spec naming an agent outside the registry is a
        // construction error, not a mid-run panic.
        let wide = WorkflowSpec::chain("wide", &[0, 9]);
        cfg.workflow = Some(WorkflowWorkload::new(wide, 0.5));
        assert!(ClusterSimulator::builder(cfg, AgentRegistry::paper())
                .gpus(2, 1.0).build().is_err());
    }

    /// Zero-floor profiles (serverless scale-to-zero): the active-set
    /// tier can really settle idle agents. Agent 0 keeps a floor to pin
    /// that floored agents never settle yet stay bit-exact.
    fn sparse_cluster_agents(n: usize) -> AgentRegistry {
        use crate::agents::{AgentProfile, Priority};
        let profiles: Vec<AgentProfile> = (0..n)
            .map(|i| AgentProfile {
                name: format!("a{i}"),
                model_mb: 800,
                base_tput: 40.0 + (i % 3) as f64 * 10.0,
                min_gpu: if i == 0 { 0.1 } else { 0.0 },
                priority: match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Medium,
                    _ => Priority::Low,
                },
            })
            .collect();
        AgentRegistry::new(profiles).unwrap()
    }

    /// Only `hot` ever receives arrivals, and only inside a mid-run
    /// burst window — the canonical active-set shape: the zero-floor
    /// herd settles at the first quiet step and is batch-accounted
    /// until its wake (or the end of the run).
    fn sparse_cluster_cfg(n: usize, hot: &[usize]) -> SimConfig {
        let mut cfg = SimConfig::paper();
        cfg.arrival_rates = (0..n)
            .map(|i| if hot.contains(&i) { 30.0 } else { 0.0 })
            .collect();
        cfg.workload_kind = WorkloadKind::Burst {
            agents: hot.to_vec(), start: 40, end: 60,
        };
        cfg
    }

    #[test]
    fn cluster_active_set_is_bit_exact_on_sparse_bursts() {
        use crate::workload::ArrivalProcess;
        // All three tiers, every rebalancer, deterministic and Poisson:
        // full ClusterResult bit identity. Poisson holds because the
        // settled agents' zero-rate draws consume no RNG state.
        for poisson in [false, true] {
            for rebalancer in Rebalancer::all() {
                let mut cfg = sparse_cluster_cfg(16, &[3, 11]);
                if poisson {
                    cfg.arrival_process = ArrivalProcess::Poisson;
                }
                let sim = ClusterSimulator::with_policies(
                    cfg, sparse_cluster_agents(16), vec![1.0, 0.75],
                    PlacementStrategy::HeadroomDecreasing, rebalancer)
                    .unwrap();
                let name = sim.rebalancer().name();
                let active = sim.run().unwrap();
                assert_eq!(active, sim.run_dense().unwrap(),
                           "{name} poisson={poisson} vs dense");
                assert_eq!(active, sim.run_skip_idle().unwrap(),
                           "{name} poisson={poisson} vs skip-idle");
                // The burst really happened and was served.
                assert!(active.agent_throughputs[3] > 0.0);
                assert!(active.agent_throughputs[11] > 0.0);
            }
        }
    }

    #[test]
    fn cluster_active_set_is_bit_exact_under_steady_sparse_load() {
        // Steady traffic on 2 of 16 agents: the zero-floor herd settles
        // after the first step and sleeps to the end of the run while
        // the hot pair (and the floored straggler) step live throughout.
        let mut cfg = sparse_cluster_cfg(16, &[3, 11]);
        cfg.workload_kind = WorkloadKind::Steady;
        let sim = ClusterSimulator::with_policies(
            cfg, sparse_cluster_agents(16), vec![1.0, 1.0],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Static).unwrap();
        let r = sim.run().unwrap();
        assert_eq!(r, sim.run_dense().unwrap());
        assert!(r.agent_throughputs[3] > 0.0);
        assert_eq!(r.agent_throughputs[5], 0.0);
    }

    #[test]
    fn cluster_active_set_is_bit_exact_under_mid_window_faults() {
        use crate::sim::fault::{FaultConfig, FaultEvent, FaultPlan};
        // An eviction inside the pre-burst idle window (wakes the whole
        // settled herd for the dense fault steps) and a stall landing
        // inside the burst: every rebalancer replays bit-identically,
        // including recovery migrations and resilience accounting.
        for rebalancer in Rebalancer::all() {
            let mut cfg = sparse_cluster_cfg(12, &[2, 7]);
            cfg.faults = Some(FaultConfig::new(FaultPlan::new(vec![
                FaultEvent::GpuEviction {
                    t: 10.0, gpu: 0, duration: 5.0,
                },
                FaultEvent::AgentStall {
                    t: 45.0, agent: 2, factor: 3.0, duration: 10.0,
                },
            ])).with_repack_throttle(0.5));
            let sim = ClusterSimulator::with_policies(
                cfg, sparse_cluster_agents(12), vec![1.2, 1.2],
                PlacementStrategy::HeadroomDecreasing, rebalancer)
                .unwrap();
            let name = sim.rebalancer().name();
            let r = sim.run().unwrap();
            assert_eq!(r, sim.run_dense().unwrap(), "{name}");
            assert!(r.resilience.is_some(), "{name}");
        }
    }

    #[test]
    fn cluster_active_set_handles_migration_of_settled_agents() {
        // Burst demand on the floored agent only: the zero-floor herd
        // settles on the first quiet step, then the burst's demand
        // imbalance fires the hottest-agent trigger mid-run. The
        // trigger wakes everyone before the move (the smallest-minimum
        // victim is a formerly-settled zero-floor agent), the victim
        // pays its stall live, re-settles once it expires — all
        // bit-exact with dense.
        let cfg = sparse_cluster_cfg(8, &[0]);
        let sim = ClusterSimulator::with_policies(
            cfg, sparse_cluster_agents(8), vec![1.0, 1.0],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::HottestAgent(MigrationModel::default())).unwrap();
        let r = sim.run().unwrap();
        assert_eq!(r, sim.run_dense().unwrap());
        assert!(r.migrations >= 1, "imbalanced burst must trigger a move");
        assert!(r.migration_stall_s > 0.0);
    }

    #[test]
    fn cluster_active_set_wakes_settled_agents_for_late_bursts() {
        // A burst in the run's last ticks: the hot agent settles at the
        // start, sleeps ~90 steps, and its deferred zero-flush plus
        // wake must land exactly where dense would have recorded them.
        let mut cfg = sparse_cluster_cfg(8, &[5]);
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![5], start: 90, end: 95,
        };
        let sim = ClusterSimulator::with_policies(
            cfg, sparse_cluster_agents(8), vec![1.0, 1.0],
            PlacementStrategy::HeadroomDecreasing,
            Rebalancer::Static).unwrap();
        let r = sim.run().unwrap();
        assert_eq!(r, sim.run_dense().unwrap());
        assert!(r.agent_throughputs[5] > 0.0, "late burst was served");
    }

    #[test]
    fn cluster_active_set_arena_reuse_is_bit_identical() {
        // The active path through one arena across shapes and epochs:
        // stale stamps, settled_at cells, and wake-heap entries from a
        // previous run must never leak into the next.
        let mut arena = ClusterArena::new();
        for _ in 0..2 {
            for (n, hot) in [(8usize, vec![0usize]), (16, vec![3, 11])] {
                let sim = ClusterSimulator::with_policies(
                    sparse_cluster_cfg(n, &hot), sparse_cluster_agents(n),
                    vec![1.0, 0.75],
                    PlacementStrategy::HeadroomDecreasing,
                    Rebalancer::Static).unwrap();
                let reused = sim.run_with_arena(&mut arena).unwrap();
                assert_eq!(reused, sim.run().unwrap(), "n={n}");
            }
        }
    }
}
