//! Named time series — the allocation/queue/latency timelines behind
//! Fig 2(c) and the robustness plots.

/// A set of equally-sampled named series.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    names: Vec<String>,
    /// values[series][step]
    values: Vec<Vec<f64>>,
}

impl TimeSeries {
    /// Create with the given series names.
    pub fn new(names: Vec<String>) -> Self {
        let n = names.len();
        TimeSeries { names, values: vec![Vec::new(); n] }
    }

    /// Append one sample per series (lengths must match).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.values.len(), "row width mismatch");
        for (series, &v) in self.values.iter_mut().zip(row) {
            series.push(v);
        }
    }

    /// Series names in column order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// One series by index.
    pub fn series(&self, idx: usize) -> &[f64] {
        &self.values[idx]
    }

    /// One series by name.
    pub fn series_by_name(&self, name: &str) -> Option<&[f64]> {
        self.names.iter().position(|n| n == name)
            .map(|i| self.values[i].as_slice())
    }

    /// Number of samples per series.
    pub fn len(&self) -> usize {
        self.values.first().map_or(0, Vec::len)
    }

    /// True when no samples have been pushed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate rows (step-major) for CSV export.
    pub fn rows(&self) -> impl Iterator<Item = Vec<f64>> + '_ {
        (0..self.len()).map(move |t| {
            self.values.iter().map(|s| s[t]).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_lookup() {
        let mut ts = TimeSeries::new(vec!["a".into(), "b".into()]);
        ts.push_row(&[1.0, 2.0]);
        ts.push_row(&[3.0, 4.0]);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.series(0), &[1.0, 3.0]);
        assert_eq!(ts.series_by_name("b"), Some(&[2.0, 4.0][..]));
        assert_eq!(ts.series_by_name("c"), None);
        let rows: Vec<Vec<f64>> = ts.rows().collect();
        assert_eq!(rows, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn mismatched_row_panics() {
        let mut ts = TimeSeries::new(vec!["a".into()]);
        ts.push_row(&[1.0, 2.0]);
    }
}
