//! CSV/JSON writers used by the `repro` CLI and the bench harnesses.

use std::io::Write;
use std::path::Path;

use crate::error::Result;
use crate::metrics::TimeSeries;
use crate::util::json::Value;

/// Write a time series as CSV with a `step` column.
pub fn timeseries_csv(ts: &TimeSeries, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "step,{}", ts.names().join(","))?;
    for (t, row) in ts.rows().enumerate() {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{t},{}", cells.join(","))?;
    }
    Ok(())
}

/// Write a generic table: header + rows of (label, values...).
pub fn table_csv(path: &Path, header: &[&str],
                 rows: &[(String, Vec<f64>)]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for (label, vals) in rows {
        let cells: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{label},{}", cells.join(","))?;
    }
    Ok(())
}

/// Write a JSON value pretty-printed.
pub fn json_file(value: &Value, path: &Path) -> Result<()> {
    std::fs::write(path, value.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use crate::util::TempDir;

    #[test]
    fn timeseries_roundtrips_as_csv_text() {
        let mut ts = TimeSeries::new(vec!["x".into(), "y".into()]);
        ts.push_row(&[1.5, 2.5]);
        let dir = TempDir::new("exp").unwrap();
        let p = dir.path().join("ts.csv");
        timeseries_csv(&ts, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "step,x,y\n0,1.5,2.5\n");
    }

    #[test]
    fn table_and_json_write() {
        let dir = TempDir::new("exp").unwrap();
        let p = dir.path().join("t.csv");
        table_csv(&p, &["policy", "latency"],
                  &[("adaptive".into(), vec![111.9])]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("adaptive,111.9"));

        let j = dir.path().join("v.json");
        json_file(&json::obj(vec![("a", json::num(1.0))]), &j).unwrap();
        assert!(std::fs::read_to_string(&j).unwrap().contains("\"a\": 1"));
    }
}
