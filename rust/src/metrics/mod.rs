//! Metrics: streaming statistics, latency histograms, time series, export.
//!
//! Everything the evaluation section reports flows through this module:
//! per-agent latency/throughput/queue statistics ([`Streaming`]), latency
//! distributions for the serving path ([`Histogram`] with p50/p99), the
//! allocation timelines behind Fig 2(c) ([`TimeSeries`]), and CSV/JSON
//! writers ([`export`]) used by the `repro` CLI and the benches.

mod histogram;
mod streaming;
mod timeseries;

pub mod export;

pub use histogram::Histogram;
pub use streaming::Streaming;
pub use timeseries::TimeSeries;
