//! Streaming scalar statistics (Welford's online algorithm).

/// Online mean / variance / min / max without storing samples.
#[derive(Debug, Clone, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Streaming {
    /// Empty accumulator.
    pub fn new() -> Self {
        Streaming { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY,
                    max: f64::NEG_INFINITY, sum: 0.0 }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Population standard deviation (0.0 for < 2 observations).
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 { 0.0 } else { (self.m2 / self.n as f64).sqrt() }
    }

    /// Minimum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    /// Maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.mean = (n1 * self.mean + n2 * other.mean) / total;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroed() {
        let s = Streaming::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn matches_batch_formulas() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.sum(), 40.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.std_dev() - whole.std_dev()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());

        // Merging into/from empty.
        let mut e = Streaming::new();
        e.merge(&whole);
        assert!((e.mean() - whole.mean()).abs() < 1e-12);
        let empty = Streaming::new();
        e.merge(&empty);
        assert_eq!(e.count(), whole.count());
    }
}
