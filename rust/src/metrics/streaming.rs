//! Streaming scalar statistics over plain power sums.
//!
//! The accumulator keeps `n`, `Σx`, `Σx²`, min, and max — not Welford's
//! recurrence. The representation is chosen for the skip-idle simulation
//! core: pushing `0.0` leaves every float field bit-unchanged (adding
//! `+0.0` is the identity on any non-`-0.0` float, and `min`/`max`
//! against `0.0` are idempotent after the first zero), so a provably-idle
//! window of `k` steps can be batch-accounted with [`Streaming::push_zeros`]
//! bit-exactly as if the dense loop had pushed `0.0` `k` times
//! ([`Streaming::push_repeat`] is the general constant-series form). At
//! simulation magnitudes (means well under 10⁴ over ≤ 10⁶ steps) the
//! power-sum variance loses nothing detectable against f64's 15–16
//! significant digits.

/// Online mean / variance / min / max without storing samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Streaming {
    n: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

/// Same as [`Streaming::new`] — a derived zeroed default would seed
/// `min`/`max` at `0.0` and silently clamp every later observation.
impl Default for Streaming {
    fn default() -> Self {
        Streaming::new()
    }
}

impl Streaming {
    /// Empty accumulator.
    pub fn new() -> Self {
        Streaming { n: 0, sum: 0.0, sumsq: 0.0, min: f64::INFINITY,
                    max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Add `k` zero observations in O(1), bit-exact with calling
    /// [`Streaming::push`]`(0.0)` `k` times: `sum`/`sumsq` gain `+0.0`
    /// once (the identity except for normalizing a `-0.0`, exactly as a
    /// single real push would), and `min`/`max` clamp against `0.0`
    /// idempotently.
    pub fn push_zeros(&mut self, k: u64) {
        self.push_repeat(0.0, k);
    }

    /// Add `k` copies of `v` in O(1) via the closed-form batch update
    /// `n += k`, `sum += v·k`, `sumsq += v²·k`, with one `min`/`max`
    /// clamp. Exact in real arithmetic; in floating point the closed
    /// form is *more* accurate than `k` sequential `push(v)` calls
    /// (which accumulate one rounding per addition — see the
    /// catastrophic-cancellation test), but therefore only
    /// **bit-identical** to them when each partial sum is exact, e.g.
    /// `v == 0.0` (where this reduces to [`Streaming::push_zeros`]) or
    /// dyadic `v` with small `k`. The active-set engines only
    /// batch-account series that are exactly `0.0`, so their deferred
    /// flushes stay bit-exact with the dense reference paths; use the
    /// general form where closed-form accuracy, not bit-replication of
    /// a dense loop, is what is wanted.
    pub fn push_repeat(&mut self, v: f64, k: u64) {
        if k == 0 {
            return;
        }
        self.n += k;
        self.sum += v * k as f64;
        self.sumsq += v * v * k as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Population standard deviation (0.0 for < 2 observations).
    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let mean = self.sum / self.n as f64;
        (self.sumsq / self.n as f64 - mean * mean).max(0.0).sqrt()
    }

    /// Minimum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    /// Maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Merge another accumulator into this one (parallel reduction).
    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroed() {
        let s = Streaming::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn default_is_new_not_zeroed() {
        let mut d = Streaming::default();
        assert_eq!(d, Streaming::new());
        d.push(5.0);
        assert_eq!(d.min(), 5.0, "default must not pre-seed min at 0.0");
        assert_eq!(d.max(), 5.0);
    }

    #[test]
    fn matches_batch_formulas() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std_dev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.sum(), 40.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Streaming::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.std_dev() - whole.std_dev()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());

        // Merging into/from empty.
        let mut e = Streaming::new();
        e.merge(&whole);
        assert!((e.mean() - whole.mean()).abs() < 1e-12);
        let empty = Streaming::new();
        e.merge(&empty);
        assert_eq!(e.count(), whole.count());
    }

    #[test]
    fn push_zeros_is_bit_exact_with_dense_zero_pushes() {
        // Around a nonzero history: Welford could not do this — the
        // power-sum representation makes k zero-pushes a pure n bump.
        for k in [1u64, 2, 7, 1000] {
            let mut dense = Streaming::new();
            let mut batched = Streaming::new();
            for &x in &[3.5, -1.25, 9.0] {
                dense.push(x);
                batched.push(x);
            }
            for _ in 0..k {
                dense.push(0.0);
            }
            batched.push_zeros(k);
            assert_eq!(dense, batched, "k={k}");
        }
        // From empty, too (min/max must clamp to 0.0 exactly once).
        let mut dense = Streaming::new();
        let mut batched = Streaming::new();
        for _ in 0..5 {
            dense.push(0.0);
        }
        batched.push_zeros(5);
        assert_eq!(dense, batched);
        assert_eq!(batched.min(), 0.0);
        assert_eq!(batched.max(), 0.0);
        // push_zeros(0) is a no-op.
        let before = batched;
        batched.push_zeros(0);
        assert_eq!(before, batched);
    }

    #[test]
    fn push_repeat_matches_batch_formulas_exactly() {
        // Mean/std/min/max of k copies of v in closed form, incl. around
        // prior history.
        let mut s = Streaming::new();
        s.push_repeat(3.0, 4);
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 3.0);
        assert_eq!(s.max(), 3.0);
        s.push(9.0);
        // n=5, sum=21, sumsq=117: mean 4.2, var 117/5 - 4.2² = 5.76.
        assert_eq!(s.mean(), 4.2);
        assert!((s.std_dev() - 2.4).abs() < 1e-12);
        assert_eq!(s.max(), 9.0);
        // k=0 is a no-op even with a "new" value.
        let before = s;
        s.push_repeat(-100.0, 0);
        assert_eq!(before, s);
    }

    #[test]
    fn push_repeat_of_zero_is_push_zeros() {
        // v=0.0 reduces bit-exactly to push_zeros (the engines' deferred
        // flush path), history or not.
        for k in [1u64, 3, 1000] {
            let mut a = Streaming::new();
            let mut b = Streaming::new();
            for &x in &[3.5, -1.25, 9.0] {
                a.push(x);
                b.push(x);
            }
            a.push_zeros(k);
            b.push_repeat(0.0, k);
            assert_eq!(a, b, "k={k}");
        }
    }

    #[test]
    fn push_repeat_dyadic_matches_sequential_bitwise() {
        // Dyadic values with small k keep every partial sum exact, so
        // the closed form reproduces the sequential pushes bit-for-bit.
        for v in [0.5, 2.0, -0.25, 1024.0] {
            let mut seq = Streaming::new();
            let mut rep = Streaming::new();
            for _ in 0..8 {
                seq.push(v);
            }
            rep.push_repeat(v, 8);
            assert_eq!(seq, rep, "v={v}");
        }
    }

    #[test]
    fn push_repeat_beats_sequential_at_large_k() {
        // The catastrophic-cancellation edge: k sequential `sum += 0.1`
        // drift by an ulp per add, while the closed form rounds once.
        // At k = 10^7 the sequential mean is measurably off; push_repeat
        // stays exact to the last decimal.
        let (v, k) = (0.1, 10_000_000u64);
        let mut seq = Streaming::new();
        for _ in 0..k {
            seq.push(v);
        }
        let mut rep = Streaming::new();
        rep.push_repeat(v, k);
        let exact_sum = v * k as f64;
        assert_eq!(rep.sum(), exact_sum);
        assert!((rep.mean() - v).abs() < 1e-15, "{}", rep.mean());
        // The closed form is never farther from the true mean than the
        // k-rounding sequential accumulation (in practice the latter
        // has drifted by many ulps at this k).
        assert!((rep.mean() - v).abs() <= (seq.mean() - v).abs());
        // Variance of a constant series: zero up to one rounding of the
        // power-sum difference (sqrt of an ulp-scale residual at worst).
        assert!(rep.std_dev() < 1e-6, "{}", rep.std_dev());
    }

    #[test]
    fn interleaved_zero_windows_match_dense() {
        // The engine's actual usage shape: bursts of real samples
        // separated by zero windows, batched vs dense, compared bit-wise.
        let mut dense = Streaming::new();
        let mut batched = Streaming::new();
        let bursts = [[0.5, 2.0], [110.3, 60.0], [756.1, 0.02]];
        for (i, burst) in bursts.iter().enumerate() {
            for &x in burst {
                dense.push(x);
                batched.push(x);
            }
            let k = (i as u64 + 1) * 13;
            for _ in 0..k {
                dense.push(0.0);
            }
            batched.push_zeros(k);
        }
        assert_eq!(dense, batched);
    }
}
