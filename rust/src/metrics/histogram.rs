//! Log-bucketed latency histogram with quantile estimation.
//!
//! HDR-style: geometric buckets over a configurable range give ~2 % relative
//! quantile error with a few hundred buckets — enough for the p50/p99
//! serving-latency numbers without storing samples.

/// Geometric-bucket histogram over (0, max] with saturating edges.
/// `PartialEq` is exact (bucket counts and geometry), which is what the
/// serving-replay determinism properties compare.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    min_value: f64,
    growth: f64,
    counts: Vec<u64>,
    underflow: u64,
    total: u64,
}

impl Histogram {
    /// Buckets spanning [min_value, max_value] with the given per-bucket
    /// growth factor (e.g. 1.02 → 2 % relative resolution).
    pub fn new(min_value: f64, max_value: f64, growth: f64) -> Self {
        assert!(min_value > 0.0 && max_value > min_value && growth > 1.0);
        let n = ((max_value / min_value).ln() / growth.ln()).ceil() as usize;
        Histogram {
            min_value,
            growth,
            counts: vec![0; n + 1],
            underflow: 0,
            total: 0,
        }
    }

    /// Default latency histogram: 1 µs .. 1 hour, 2 % resolution.
    pub fn latency_seconds() -> Self {
        Histogram::new(1e-6, 3600.0, 1.02)
    }

    fn bucket(&self, x: f64) -> Option<usize> {
        if x < self.min_value {
            return None;
        }
        let idx = ((x / self.min_value).ln() / self.growth.ln()) as usize;
        Some(idx.min(self.counts.len() - 1))
    }

    /// Record one observation (values below range count as underflow;
    /// values above saturate into the last bucket).
    pub fn record(&mut self, x: f64) {
        self.total += 1;
        match self.bucket(x) {
            Some(i) => self.counts[i] += 1,
            None => self.underflow += 1,
        }
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Quantile estimate (q in [0,1]); 0.0 when empty. Returns the upper
    /// edge of the bucket containing the q-th observation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target.max(1) {
            return self.min_value;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return self.min_value * self.growth.powi(i as i32 + 1);
            }
        }
        self.min_value * self.growth.powi(self.counts.len() as i32)
    }

    /// Shorthand: p50.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// Shorthand: p99.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram with identical geometry.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len(),
                   "histogram geometry mismatch");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::latency_seconds();
        // Uniform 1..=1000 ms.
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.p50();
        assert!((p50 - 0.5).abs() / 0.5 < 0.05, "p50={p50}");
        let p99 = h.p99();
        assert!((p99 - 0.99).abs() / 0.99 < 0.05, "p99={p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn saturates_instead_of_panicking() {
        let mut h = Histogram::new(1e-3, 10.0, 1.1);
        h.record(1e9); // overflow → last bucket
        h.record(1e-9); // underflow
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= 10.0);
    }

    #[test]
    fn empty_quantile_zero() {
        let h = Histogram::latency_seconds();
        assert_eq!(h.p50(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::new(1e-3, 10.0, 1.05);
        let mut b = Histogram::new(1e-3, 10.0, 1.05);
        for _ in 0..100 {
            a.record(0.1);
            b.record(1.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        let p50 = a.p50();
        assert!(p50 > 0.09 && p50 < 1.2, "p50={p50}");
    }
}
