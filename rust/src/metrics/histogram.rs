//! Log-bucketed latency histogram with quantile estimation.
//!
//! HDR-style: geometric buckets over a configurable range give ~2 % relative
//! quantile error with a few hundred buckets — enough for the p50/p99
//! serving-latency numbers without storing samples.

/// Geometric-bucket histogram over (0, max] with saturating edges.
/// `PartialEq` is exact (bucket counts and geometry), which is what the
/// serving-replay determinism properties compare.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    min_value: f64,
    growth: f64,
    counts: Vec<u64>,
    underflow: u64,
    total: u64,
}

impl Histogram {
    /// Buckets spanning [min_value, max_value] with the given per-bucket
    /// growth factor (e.g. 1.02 → 2 % relative resolution).
    pub fn new(min_value: f64, max_value: f64, growth: f64) -> Self {
        assert!(min_value > 0.0 && max_value > min_value && growth > 1.0);
        let n = ((max_value / min_value).ln() / growth.ln()).ceil() as usize;
        Histogram {
            min_value,
            growth,
            counts: vec![0; n + 1],
            underflow: 0,
            total: 0,
        }
    }

    /// Default latency histogram: 1 µs .. 1 hour, 2 % resolution.
    pub fn latency_seconds() -> Self {
        Histogram::new(1e-6, 3600.0, 1.02)
    }

    fn bucket(&self, x: f64) -> Option<usize> {
        if x < self.min_value {
            return None;
        }
        let idx = ((x / self.min_value).ln() / self.growth.ln()) as usize;
        Some(idx.min(self.counts.len() - 1))
    }

    /// Record one observation (values below range count as underflow;
    /// values above saturate into the last bucket).
    pub fn record(&mut self, x: f64) {
        self.total += 1;
        match self.bucket(x) {
            Some(i) => self.counts[i] += 1,
            None => self.underflow += 1,
        }
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Quantile estimate (q in [0,1]); 0.0 when empty. Returns the upper
    /// edge of the bucket containing the q-th observation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target.max(1) {
            return self.min_value;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return self.min_value * self.growth.powi(i as i32 + 1);
            }
        }
        self.min_value * self.growth.powi(self.counts.len() as i32)
    }

    /// Shorthand: p50.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// Shorthand: p99.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram with identical geometry.
    ///
    /// Geometry means the full bucket layout — `min_value`, `growth`,
    /// *and* bucket count. Two histograms can share a length while
    /// bucketing entirely different ranges (e.g. microseconds vs
    /// seconds); summing their counts bucket-by-bucket would silently
    /// produce nonsense quantiles, so any mismatch panics.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            self.min_value == other.min_value
                && self.growth == other.growth
                && self.counts.len() == other.counts.len(),
            "histogram geometry mismatch: \
             min_value {} vs {}, growth {} vs {}, buckets {} vs {}",
            self.min_value, other.min_value, self.growth, other.growth,
            self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::latency_seconds();
        // Uniform 1..=1000 ms.
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.p50();
        assert!((p50 - 0.5).abs() / 0.5 < 0.05, "p50={p50}");
        let p99 = h.p99();
        assert!((p99 - 0.99).abs() / 0.99 < 0.05, "p99={p99}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn saturates_instead_of_panicking() {
        let mut h = Histogram::new(1e-3, 10.0, 1.1);
        h.record(1e9); // overflow → last bucket
        h.record(1e-9); // underflow
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= 10.0);
    }

    #[test]
    fn empty_quantile_zero() {
        let h = Histogram::latency_seconds();
        assert_eq!(h.p50(), 0.0);
    }

    #[test]
    #[should_panic(expected = "histogram geometry mismatch")]
    fn merge_rejects_same_length_different_geometry() {
        // Same bucket count, different range: before the geometry check
        // this merged silently into nonsense quantiles.
        let mut a = Histogram::new(1e-3, 10.0, 1.1);
        let b = Histogram::new(1e-6, 10.0e-3, 1.1);
        assert_eq!(a.counts.len(), b.counts.len(),
                   "test premise: lengths must collide");
        a.merge(&b);
    }

    #[test]
    #[should_panic(expected = "histogram geometry mismatch")]
    fn merge_rejects_different_growth() {
        let mut a = Histogram::new(1e-3, 10.0, 1.1);
        let mut b = Histogram::new(1e-3, 10.0, 1.2);
        // Pad the coarser histogram to the same length so only `growth`
        // differs.
        b.counts.resize(a.counts.len(), 0);
        a.merge(&b);
    }

    #[test]
    fn merge_preserves_quantiles() {
        // Property: merging two same-geometry histograms yields exactly
        // the quantiles of recording both sample sets into one — merge
        // is bucket-count addition, so this must be exact, not
        // approximate.
        let xs: Vec<f64> =
            (1..=500).map(|i| i as f64 * 2e-3).collect();
        let ys: Vec<f64> =
            (1..=300).map(|i| 0.4 + i as f64 * 1e-3).collect();
        let mut merged = Histogram::latency_seconds();
        let mut b = Histogram::latency_seconds();
        let mut whole = Histogram::latency_seconds();
        for &x in &xs {
            merged.record(x);
            whole.record(x);
        }
        for &y in &ys {
            b.record(y);
            whole.record(y);
        }
        merged.merge(&b);
        assert_eq!(merged, whole);
        for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn quantile_edges_zero_and_one() {
        let mut h = Histogram::latency_seconds();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        // q = 0.0 → the first observation's bucket (ceil clamps the
        // target to at least one observation, never below).
        let q0 = h.quantile(0.0);
        assert!(q0 >= 1e-3 * 0.98 && q0 <= 1e-3 * 1.1, "q0={q0}");
        // q = 1.0 → the last observation's bucket upper edge, not the
        // histogram's global max.
        let q1 = h.quantile(1.0);
        assert!(q1 >= 0.1 && q1 <= 0.1 * 1.05, "q1={q1}");
        assert!(q1 < 3600.0);
        // Out-of-range q clamps.
        assert_eq!(h.quantile(-3.0), h.quantile(0.0));
        assert_eq!(h.quantile(7.0), h.quantile(1.0));
    }

    #[test]
    fn quantile_underflow_only_population() {
        let mut h = Histogram::new(1.0, 100.0, 1.5);
        for _ in 0..10 {
            h.record(0.01); // below min_value → underflow bucket
        }
        assert_eq!(h.count(), 10);
        // Every quantile of an all-underflow population reports the
        // range floor — the one honest answer the sketch can give.
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 1.0, "q={q}");
        }
    }

    #[test]
    fn saturated_overflow_survives_merge() {
        // Overflow saturates into the last bucket; merging two saturated
        // histograms keeps the mass there and q=1.0 stays at the top
        // edge rather than overflowing the bucket index.
        let mut a = Histogram::new(1e-3, 10.0, 1.1);
        let mut b = Histogram::new(1e-3, 10.0, 1.1);
        for _ in 0..5 {
            a.record(1e9);
            b.record(1e12);
        }
        b.record(0.5); // one in-range sample on one side
        a.merge(&b);
        assert_eq!(a.count(), 11);
        assert!(a.quantile(1.0) >= 10.0);
        assert!(a.quantile(0.5) >= 10.0, "overflow dominates the median");
        // The in-range sample is still visible at the bottom.
        let q0 = a.quantile(0.0);
        assert!(q0 < 1.0, "q0={q0}");
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::new(1e-3, 10.0, 1.05);
        let mut b = Histogram::new(1e-3, 10.0, 1.05);
        for _ in 0..100 {
            a.record(0.1);
            b.record(1.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        let p50 = a.p50();
        assert!(p50 > 0.09 && p50 < 1.2, "p50={p50}");
    }
}
