//! Recorded-replay experiments: close the record → dump → replay loop
//! end to end.
//!
//! Two drivers:
//!
//! * [`replay_experiment`] — per policy × seed, run the serving
//!   simulator live while recording its queue timeline through the
//!   core's [`TraceRecorder`](crate::workload::TraceRecorder), dump the
//!   recording as a burst-encoded binary trace, replay the dump through
//!   [`ServingSimulator::run_source`], and report whether the replay is
//!   bit-identical to the live run (it must be: timestamps are stored
//!   verbatim);
//! * [`replay_grid`] — recorded-replay cells for the stress sweep: one
//!   adaptive-policy recording per seed, replayed under every built-in
//!   policy as [`SweepCell::Serving`] binary-trace cells, so every
//!   policy replays the *identical* request stream.

use std::sync::Arc;

use crate::agents::AgentRegistry;
use crate::allocator::PolicyKind;
use crate::server::{ServingConfig, ServingSimulator};
use crate::sim::batch::{ScenarioBuilder, SweepCell};
use crate::sim::SimConfig;

/// One row of the recorded-replay experiment.
#[derive(Debug, Clone)]
pub struct ReplayRow {
    /// Policy that drove both the recording and the replay.
    pub policy: String,
    /// Arrival-stream seed.
    pub seed: u64,
    /// Requests the live run recorded (accepted enqueues).
    pub recorded_requests: u64,
    /// Size of the binary dump (bytes).
    pub trace_bytes: u64,
    /// Requests the replay completed.
    pub replay_completed: u64,
    /// Replay mean per-request latency (seconds).
    pub replay_mean_latency_s: f64,
    /// Replay mean per-agent p99 latency (seconds).
    pub replay_p99_s: f64,
    /// Whether the replay reproduced the live run bit-identically
    /// (every latency, allocation, and counter exactly equal).
    pub bit_identical: bool,
}

fn replay_config(duration_s: f64, seed: u64) -> ServingConfig {
    let mut cfg = ServingConfig::paper();
    cfg.duration_s = duration_s;
    cfg.seed = seed;
    cfg
}

/// For every built-in policy × seed: record a live serving run's queue
/// timeline, dump it as a binary trace, replay the dump, and compare.
/// The `bit_identical` column is the closure property the binary format
/// exists for — recorded timestamps inject verbatim, so the replay *is*
/// the run.
pub fn replay_experiment(duration_s: f64, seeds: &[u64])
                         -> Vec<ReplayRow> {
    let mut rows =
        Vec::with_capacity(PolicyKind::all().len() * seeds.len());
    for &seed in seeds {
        let sim = ServingSimulator::with_registry(
            replay_config(duration_s, seed), AgentRegistry::paper());
        for policy in PolicyKind::all() {
            let mut live_policy = policy.clone();
            let (original, recorded) =
                sim.run_recording(&mut live_policy);
            let mut replay_policy = policy.clone();
            let replayed =
                sim.run_source(&mut replay_policy, &recorded);
            rows.push(ReplayRow {
                policy: policy.name().to_string(),
                seed,
                recorded_requests: recorded.total_arrivals() as u64,
                trace_bytes: recorded.byte_len() as u64,
                replay_completed: replayed.total_completed,
                replay_mean_latency_s: replayed.mean_latency(),
                replay_p99_s: replayed.mean_p99(),
                bit_identical: replayed == original,
            });
        }
    }
    rows
}

/// Recorded-replay stress cells: one adaptive-policy recording per
/// seed (a live serving run's dumped queue timeline), replayed under
/// every built-in policy, labelled `"serving/<policy>/replay/seed<seed>"`.
/// The recording is shared (not copied) across the policies of its
/// seed, so every policy replays the identical burst-timestamped
/// request stream through the queue path.
pub fn replay_grid(duration_s: f64, seeds: &[u64]) -> Vec<SweepCell> {
    let mut cells =
        Vec::with_capacity(PolicyKind::all().len() * seeds.len());
    for &seed in seeds {
        let cfg = replay_config(duration_s, seed);
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  AgentRegistry::paper());
        let (_, recorded) =
            sim.run_recording(&mut PolicyKind::adaptive());
        let recorded = Arc::new(recorded);
        for policy in PolicyKind::all() {
            cells.push(ScenarioBuilder::new(
                format!("serving/{}/replay/seed{seed}", policy.name()),
                SimConfig::paper(), AgentRegistry::paper())
                .policy(policy)
                .serving(cfg.clone())
                .bintrace(Arc::clone(&recorded))
                .build()
                .expect("replay cells carry no conflicting axes"));
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::batch::run_sweep;

    #[test]
    fn replay_experiment_is_bit_identical_for_every_policy() {
        let rows = replay_experiment(2.0, &[1, 2]);
        assert_eq!(rows.len(), PolicyKind::all().len() * 2);
        for row in &rows {
            assert!(row.bit_identical, "{}/seed{}", row.policy, row.seed);
            assert!(row.recorded_requests > 0, "{}", row.policy);
            assert_eq!(row.recorded_requests, row.replay_completed,
                       "{}: lossless replay completes everything",
                       row.policy);
            assert!(row.trace_bytes > 0 && row.replay_mean_latency_s > 0.0);
        }
    }

    #[test]
    fn replay_grid_cells_are_bit_identical_across_worker_counts() {
        let cells = replay_grid(2.0, &[42]);
        assert_eq!(cells.len(), PolicyKind::all().len());
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), cells.len(), "labels must be unique");
        assert!(cells.iter().any(|c| c.label()
                == "serving/adaptive/replay/seed42"));
        let sequential = run_sweep(&cells, 1);
        for workers in [2usize, 8] {
            let parallel = run_sweep(&cells, workers);
            for (a, b) in sequential.iter().zip(&parallel) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.result.as_serving().unwrap(),
                           b.result.as_serving().unwrap(),
                           "{} at {workers} workers", a.label);
            }
        }
        // Every policy served the identical recorded stream in full.
        let completed: Vec<u64> = sequential.iter()
            .map(|r| r.result.as_serving().unwrap().total_completed)
            .collect();
        assert!(completed.iter().all(|&c| c == completed[0] && c > 0),
                "shared recording must replay losslessly: {completed:?}");
    }
}
