//! Table I/II and Fig 2 drivers.

use crate::agents::AgentProfile;
use crate::allocator::{AdaptivePolicy, PolicyKind};
use crate::metrics::TimeSeries;
use crate::sim::batch::{default_workers, run_batch, Scenario};
use crate::sim::{SimConfig, SimResult, Simulator, SummaryRow};

/// One per-agent series for a policy (Fig 2(a)/(b) bars).
#[derive(Debug, Clone)]
pub struct PerAgentSeries {
    /// Policy name.
    pub policy: String,
    /// One value per agent, in Table I order.
    pub values: Vec<f64>,
}

/// One point in the cost-performance space (Fig 2(d)).
#[derive(Debug, Clone)]
pub struct CostPerfPoint {
    /// Policy name.
    pub policy: String,
    /// Mean latency (x-axis).
    pub avg_latency_s: f64,
    /// Total throughput (y-axis).
    pub total_throughput_rps: f64,
    /// Cost annotation.
    pub cost_dollars: f64,
}

/// Run the paper's three §IV policies over the §IV workload (batched
/// across workers; per-policy results are bit-identical to sequential
/// runs — the `sim_properties` suite asserts this).
pub fn run_paper_policies() -> Vec<SimResult> {
    let scenarios: Vec<Scenario> = [
        PolicyKind::static_equal(),
        PolicyKind::round_robin(),
        PolicyKind::adaptive(),
    ]
    .into_iter()
    .map(|p| Scenario::paper(p.name(), p))
    .collect();
    run_batch(&scenarios, default_workers())
        .into_iter()
        .map(|b| b.result)
        .collect()
}

/// Table I: agent characteristics (from the profiles, for the CSV).
pub fn table1() -> Vec<(String, Vec<f64>)> {
    AgentProfile::paper_agents().iter().map(|p| {
        (p.name.clone(), vec![
            p.model_mb as f64,
            p.base_tput,
            p.min_gpu,
            u8::from(p.priority) as f64,
        ])
    }).collect()
}

/// Table II: the headline comparison rows.
pub fn table2() -> Vec<SummaryRow> {
    run_paper_policies().iter().map(SimResult::summary).collect()
}

/// Fig 2(a): average latency per agent per policy.
pub fn fig2a() -> Vec<PerAgentSeries> {
    run_paper_policies().into_iter().map(|r| PerAgentSeries {
        policy: r.policy.clone(),
        values: r.agent_latencies(),
    }).collect()
}

/// Fig 2(b): throughput per agent per policy.
pub fn fig2b() -> Vec<PerAgentSeries> {
    run_paper_policies().into_iter().map(|r| PerAgentSeries {
        policy: r.policy.clone(),
        values: r.agent_throughputs(),
    }).collect()
}

/// Fig 2(c): adaptive GPU allocation over time (Poisson arrivals, fixed
/// seed — the gently-varying curves in the paper's figure).
pub fn fig2c() -> TimeSeries {
    let mut cfg = SimConfig::paper_poisson();
    cfg.record_timelines = true;
    let sim = Simulator::new(cfg, AgentProfile::paper_agents());
    let r = sim.run(&mut AdaptivePolicy::default());
    r.timelines.expect("timelines requested").allocation
}

/// Fig 2(d): cost-performance trade-off points.
pub fn fig2d() -> Vec<CostPerfPoint> {
    run_paper_policies().into_iter().map(|r| CostPerfPoint {
        policy: r.policy.clone(),
        avg_latency_s: r.mean_latency(),
        total_throughput_rps: r.total_throughput(),
        cost_dollars: r.cost_dollars,
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_shape() {
        let rows = table2();
        assert_eq!(rows.len(), 3);
        let static_row = &rows[0];
        let rr = &rows[1];
        let adaptive = &rows[2];
        assert_eq!(static_row.policy, "static_equal");
        assert_eq!(rr.policy, "round_robin");
        assert_eq!(adaptive.policy, "adaptive");
        // Who wins and by what factor (the shape the paper reports).
        assert!(rr.avg_latency_s > 6.0 * adaptive.avg_latency_s);
        assert!((adaptive.avg_latency_s - static_row.avg_latency_s).abs()
                < 5.0);
        assert!(adaptive.total_throughput_rps
                < static_row.total_throughput_rps);
        assert!((adaptive.total_throughput_rps
                 - static_row.total_throughput_rps).abs() < 2.5);
        // All policies cost the same $0.020.
        for r in &rows {
            assert!((r.cost_dollars - 0.020).abs() < 1e-6, "{}", r.policy);
        }
    }

    #[test]
    fn fig2a_adaptive_orders_by_priority() {
        let series = fig2a();
        let adaptive = series.iter().find(|s| s.policy == "adaptive")
            .unwrap();
        // reasoning (high priority) lowest, vision (medium) highest.
        let v = &adaptive.values;
        assert!(v[3] < v[0] && v[3] < v[1] && v[3] < v[2],
                "reasoning should be lowest: {v:?}");
        assert!(v[2] >= v[0] && v[2] >= v[1], "vision highest: {v:?}");
    }

    #[test]
    fn fig2c_allocation_is_stable_without_oscillation() {
        let ts = fig2c();
        assert_eq!(ts.len(), 100);
        // "Smooth allocation curves ... without disruptive oscillations":
        // per-agent std over time is small relative to the mean.
        for i in 0..4 {
            let series = ts.series(i);
            let mean = crate::util::mean(series);
            let std = crate::util::std_dev(series);
            assert!(std / mean < 0.15, "agent {i}: cv={}", std / mean);
        }
    }

    #[test]
    fn fig2d_adaptive_clusters_with_static() {
        let pts = fig2d();
        let find = |n: &str| pts.iter().find(|p| p.policy == n).unwrap();
        let adaptive = find("adaptive");
        let stat = find("static_equal");
        let rr = find("round_robin");
        // Low-latency/high-throughput cluster vs round-robin outlier.
        assert!((adaptive.avg_latency_s - stat.avg_latency_s).abs() < 10.0);
        assert!(rr.avg_latency_s > 5.0 * stat.avg_latency_s);
    }
}
