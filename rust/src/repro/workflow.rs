//! Workflow-DAG experiments: multi-stage collaborative workloads swept
//! end to end through every engine.
//!
//! Two drivers:
//!
//! * [`workflow_grid`] — the workflow evaluation grid as
//!   [`SweepCell::Workflow`] cells, built through [`ScenarioBuilder`]:
//!   spec shape × policy over the fluid single-GPU engine (the
//!   CriticalPath entry weighted for each shape), spec shape ×
//!   placement (workflow colocation vs the headroom default) over the
//!   cluster engine, and spec shape over the serving engine's native
//!   DAG execution — all × seed;
//! * [`workflow_experiment`] — the end-to-end latency head-to-head on
//!   the paper deployment: every built-in policy (CriticalPath weighted
//!   for the paper fan-out) drives the same workflow stream, and the
//!   row surfaces end-to-end mean and p99 workflow latency. A DAG-aware
//!   policy keeps every stage progressing each step, so it beats
//!   round-robin's rotation stalls on p99 (asserted in this module's
//!   tests).

use crate::agents::AgentRegistry;
use crate::allocator::PolicyKind;
use crate::cluster::PlacementStrategy;
use crate::server::ServingConfig;
use crate::sim::batch::{default_workers, run_sweep, ScenarioBuilder,
                        SweepCell};
use crate::sim::SimConfig;
use crate::workload::{ArrivalProcess, WorkflowSpec, WorkflowWorkload};

/// Every built-in policy, with the CriticalPath entry weighted for
/// `spec` (the unweighted registry entry is bit-identical to adaptive,
/// which would make the workflow lane race a duplicate).
fn workflow_policies(spec: &WorkflowSpec, n_agents: usize)
                     -> Vec<PolicyKind> {
    PolicyKind::all().into_iter()
        .map(|p| if p.name() == "critical_path" {
            PolicyKind::critical_path_for(spec, n_agents)
        } else {
            p
        })
        .collect()
}

/// The workflow sweep grid: for every spec shape in
/// [`WorkflowSpec::paper_shapes`], fluid single-GPU cells under every
/// built-in policy (`"workflow/<shape>/<policy>/seed<seed>"`), cluster
/// cells racing workflow colocation against the headroom default over
/// two 1.2-capacity devices
/// (`"workflow/<shape>/cluster/<placement>/seed<seed>"`), and serving
/// cells executing the DAG natively in virtual time
/// (`"workflow/<shape>/serving/seed<seed>"`). Instances release at the
/// paper rate (0.5 workflows/s).
pub fn workflow_grid(steps: u64, seeds: &[u64]) -> Vec<SweepCell> {
    let registry = AgentRegistry::paper;
    let mut cells = Vec::new();
    for spec in WorkflowSpec::paper_shapes() {
        let shape = spec.name().to_string();
        let workload = WorkflowWorkload::new(spec.clone(), 0.5);
        for policy in workflow_policies(&spec, registry().len()) {
            for &seed in seeds {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                cfg.seed = seed;
                cells.push(ScenarioBuilder::new(
                    format!("workflow/{shape}/{}/seed{seed}",
                            policy.name()),
                    cfg, registry())
                    .policy(policy.clone())
                    .workflow(workload.clone())
                    .build()
                    .expect("paper workflow cells are valid"));
            }
        }
        for (pname, placement) in [
            ("colocate", PlacementStrategy::WorkflowColocate),
            ("headroom", PlacementStrategy::HeadroomDecreasing),
        ] {
            for &seed in seeds {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                cfg.seed = seed;
                cells.push(ScenarioBuilder::new(
                    format!("workflow/{shape}/cluster/{pname}/seed{seed}"),
                    cfg, registry())
                    .capacities(vec![1.2, 1.2])
                    .placement(placement)
                    .workflow(workload.clone())
                    .build()
                    .expect("paper workflow cells are valid"));
            }
        }
        for &seed in seeds {
            let mut scfg = ServingConfig::paper();
            scfg.duration_s = steps as f64;
            scfg.seed = seed;
            // Deterministic releases so every cell of the lane carries
            // instances even at the short durations short sweeps use.
            scfg.arrival_process = ArrivalProcess::Deterministic;
            cells.push(ScenarioBuilder::new(
                format!("workflow/{shape}/serving/seed{seed}"),
                SimConfig::paper(), registry())
                .serving(scfg)
                .workflow(workload.clone())
                .build()
                .expect("paper workflow cells are valid"));
        }
    }
    cells
}

/// One row of the workflow policy head-to-head (per policy).
#[derive(Debug, Clone)]
pub struct WorkflowRow {
    /// Policy name.
    pub policy: String,
    /// Workflow instances released into the run.
    pub started: u64,
    /// Instances that completed end to end before the run ended.
    pub completed: u64,
    /// Mean end-to-end workflow latency (s).
    pub mean_s: f64,
    /// p99 end-to-end workflow latency (s).
    pub p99_s: f64,
}

/// The end-to-end workflow latency experiment on the paper deployment:
/// every built-in policy (the CriticalPath entry weighted for the paper
/// fan-out) drives the identical 0.5 workflows/s stream through the
/// fluid engine for `steps` one-second steps, all through one
/// `run_sweep` pool. Rows come back in [`PolicyKind::all`] order.
pub fn workflow_experiment(steps: u64) -> Vec<WorkflowRow> {
    let spec = WorkflowSpec::paper();
    let registry = AgentRegistry::paper();
    let cells: Vec<SweepCell> =
        workflow_policies(&spec, registry.len()).into_iter()
        .map(|policy| {
            let mut cfg = SimConfig::paper();
            cfg.steps = steps;
            ScenarioBuilder::new(
                format!("workflow/{}", policy.name()), cfg,
                registry.clone())
                .policy(policy)
                .workflow(WorkflowWorkload::paper())
                .build()
                .expect("paper workflow cells are valid")
        })
        .collect();
    let runs = run_sweep(&cells, default_workers());
    runs.iter().map(|r| {
        let wf = r.result.workflow().expect("workflow cells carry stats");
        WorkflowRow {
            policy: r.label.trim_start_matches("workflow/").to_string(),
            started: wf.started,
            completed: wf.completed,
            mean_s: wf.mean_s(),
            p99_s: wf.p99_s(),
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::batch::run_sweep;

    #[test]
    fn workflow_grid_covers_every_axis_with_unique_labels() {
        let seeds = [1u64, 2];
        let cells = workflow_grid(10, &seeds);
        let shapes = WorkflowSpec::paper_shapes().len();
        // Per shape: every policy (fluid) + 2 placements (cluster) + 1
        // serving lane, each × seed.
        let expected = shapes * (PolicyKind::all().len() + 2 + 1)
            * seeds.len();
        assert_eq!(cells.len(), expected);
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), expected, "labels must be unique");
        assert!(cells.iter().any(|c| c.label()
                == "workflow/fanout3/critical_path/seed1"));
        assert!(cells.iter().any(|c| c.label()
                == "workflow/chain3/cluster/colocate/seed2"));
        assert!(cells.iter().any(|c| c.label()
                == "workflow/fanout2/serving/seed1"));
        assert!(cells.iter()
                .all(|c| matches!(c, SweepCell::Workflow(_))));
    }

    #[test]
    fn workflow_grid_runs_deterministically_and_carries_stats() {
        let cells = workflow_grid(10, &[42]);
        let one = run_sweep(&cells, 1);
        let many = run_sweep(&cells, 8);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.label, b.label);
            let wa = a.result.workflow().expect("workflow stats");
            let wb = b.result.workflow().expect("workflow stats");
            assert_eq!(wa, wb, "{}", a.label);
            assert!(wa.started > 0, "{}: no instances released", a.label);
        }
    }

    #[test]
    fn workflow_experiment_rows_track_the_policy_registry() {
        let rows = workflow_experiment(60);
        assert_eq!(rows.len(), PolicyKind::all().len());
        let names: Vec<&str> =
            rows.iter().map(|r| r.policy.as_str()).collect();
        let expected: Vec<&str> = PolicyKind::all().iter()
            .map(PolicyKind::name).collect();
        assert_eq!(names, expected);
        for row in &rows {
            assert!(row.started > 0, "{}", row.policy);
        }
    }

    #[test]
    fn critical_path_beats_round_robin_on_workflow_p99() {
        // The acceptance race: on the paper deployment the DAG-aware
        // policy keeps every stage progressing each step, while
        // round-robin stalls each DAG level until its agent's turn.
        let rows = workflow_experiment(100);
        let by_name = |n: &str| rows.iter()
            .find(|r| r.policy == n).expect("policy row");
        let cp = by_name("critical_path");
        let rr = by_name("round_robin");
        assert!(cp.completed > 0, "critical_path completed nothing");
        assert!(cp.p99_s < rr.p99_s,
                "critical_path p99 {} !< round_robin p99 {}",
                cp.p99_s, rr.p99_s);
        assert!(cp.mean_s < rr.mean_s,
                "critical_path mean {} !< round_robin mean {}",
                cp.mean_s, rr.mean_s);
    }
}
