//! Experiment drivers: one function per paper table/figure (§IV–§V).
//!
//! Each driver returns structured data; [`write_all`] exports everything as
//! CSV under a results directory. The CLI (`agentsrv repro`), the examples,
//! and the criterion benches all call through here so every consumer sees
//! identical numbers.

mod economics;
mod experiments;
mod faults;
mod placement;
mod replay;
mod robustness;
mod serving;
mod workflow;

pub use economics::{coldstart_axis, cost_grid, economics_experiment,
                    idle_burst_config, idle_timeout_axis, pricing_axis,
                    EconomicsRow};
pub use faults::{eviction_rate_axis, fault_experiment, fault_grid,
                 FaultRow};
pub use experiments::{fig2a, fig2b, fig2c, fig2d, table1, table2,
                      CostPerfPoint, PerAgentSeries};
pub use placement::{adversarial_rates, adversarial_registry,
                    large_n_config, large_n_grid, placement_experiment,
                    placement_grid, sparse_burst_config,
                    sparse_hot_agents, synthetic_arrival_rates,
                    synthetic_sparse_rates, synthetic_sparse_registry,
                    PlacementRow};
pub use replay::{replay_experiment, replay_grid, ReplayRow};
pub use robustness::{cluster_grid, dominance_experiment,
                     overload_experiment, scaling_experiment,
                     spike_experiment, stress_grid, stress_shapes,
                     stress_sweep, synthetic_registry, trace_grid,
                     DominanceReport, OverloadReport, ScalingPoint,
                     SpikeReport};
pub use serving::{serving_experiment, serving_grid,
                  ServingComparisonRow};
pub use workflow::{workflow_experiment, workflow_grid, WorkflowRow};

use std::path::Path;

use crate::error::Result;
use crate::metrics::export;

/// Run every experiment and write its CSV into `dir`.
///
/// Produces: `table1.csv`, `table2.csv`, `fig2a_latency.csv`,
/// `fig2b_throughput.csv`, `fig2c_allocation.csv`, `fig2d_cost_perf.csv`,
/// `robustness_overload.csv`, `robustness_spike.csv`,
/// `robustness_dominance.csv`, `allocator_scaling.csv`, `economics.csv`,
/// `serving.csv`, `faults.csv`, `placement.csv`, `workflow.csv`,
/// `replay.csv`.
pub fn write_all(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;

    // Table I — agent characteristics.
    let t1 = table1();
    export::table_csv(
        &dir.join("table1.csv"),
        &["agent", "model_mb", "base_tput_rps", "min_gpu", "priority"],
        &t1,
    )?;

    // Table II — policy comparison.
    let rows = table2();
    export::table_csv(
        &dir.join("table2.csv"),
        &["policy", "avg_latency_s", "total_throughput_rps", "cost_dollars",
          "latency_std_s", "mean_utilization"],
        &rows.iter().map(|r| (r.policy.clone(), vec![
            r.avg_latency_s, r.total_throughput_rps, r.cost_dollars,
            r.latency_std_s, r.mean_utilization,
        ])).collect::<Vec<_>>(),
    )?;

    // Fig 2(a) — per-agent latency.
    let a = fig2a();
    export::table_csv(
        &dir.join("fig2a_latency.csv"),
        &["policy", "coordinator", "nlp", "vision", "reasoning"],
        &a.iter().map(|s| (s.policy.clone(), s.values.clone())).collect::<Vec<_>>(),
    )?;

    // Fig 2(b) — per-agent + total throughput.
    let b = fig2b();
    export::table_csv(
        &dir.join("fig2b_throughput.csv"),
        &["policy", "coordinator", "nlp", "vision", "reasoning", "total"],
        &b.iter().map(|s| {
            let mut v = s.values.clone();
            v.push(s.values.iter().sum());
            (s.policy.clone(), v)
        }).collect::<Vec<_>>(),
    )?;

    // Fig 2(c) — adaptive allocation timeline (Poisson, seed 42).
    let c = fig2c();
    export::timeseries_csv(&c, &dir.join("fig2c_allocation.csv"))?;

    // Fig 2(d) — cost/latency/throughput points.
    let d = fig2d();
    export::table_csv(
        &dir.join("fig2d_cost_perf.csv"),
        &["policy", "avg_latency_s", "total_throughput_rps", "cost_dollars"],
        &d.iter().map(|p| (p.policy.clone(), vec![
            p.avg_latency_s, p.total_throughput_rps, p.cost_dollars,
        ])).collect::<Vec<_>>(),
    )?;

    // §V.B robustness.
    let ov = overload_experiment(3.0);
    export::table_csv(
        &dir.join("robustness_overload.csv"),
        &["factor", "avg_latency_s", "min_agent_throughput_rps",
          "latency_degradation_pct"],
        &[
            ("1x".into(), vec![ov.baseline_latency_s,
                               ov.baseline_min_throughput, 0.0]),
            (format!("{}x", ov.factor), vec![
                ov.overload_latency_s, ov.overload_min_throughput,
                ov.degradation_pct]),
        ],
    )?;

    let sp = spike_experiment();
    export::table_csv(
        &dir.join("robustness_spike.csv"),
        &["metric", "value"],
        &[
            ("adaptation_ms".into(), vec![sp.adaptation_ms]),
            ("spike_factor".into(), vec![sp.factor]),
            ("pre_spike_alloc".into(), vec![sp.pre_spike_alloc]),
            ("post_spike_alloc".into(), vec![sp.post_spike_alloc]),
        ],
    )?;

    let dm = dominance_experiment(0.9);
    export::table_csv(
        &dir.join("robustness_dominance.csv"),
        &["agent", "request_share", "gpu_share"],
        &dm.agents.iter().map(|(name, req, gpu)| {
            (name.clone(), vec![*req, *gpu])
        }).collect::<Vec<_>>(),
    )?;

    // §V.B O(N) scaling.
    let sc = scaling_experiment(&[4, 16, 64, 256, 1024, 4096]);
    export::table_csv(
        &dir.join("allocator_scaling.csv"),
        &["n_agents", "ns_per_allocation"],
        &sc.iter().map(|p| (p.n_agents.to_string(),
                            vec![p.ns_per_call])).collect::<Vec<_>>(),
    )?;

    // Serverless economics: the Table II cost tie and where
    // scale-to-zero breaks it.
    let econ = economics_experiment(100);
    export::table_csv(
        &dir.join("economics.csv"),
        &["policy", "paper_warm_cost", "burst_warm_cost",
          "burst_s2z_cost", "savings_pct", "cold_starts",
          "mean_warm_fraction", "burst_warm_latency_s",
          "burst_s2z_latency_s"],
        &econ.iter().map(|r| (r.policy.clone(), vec![
            r.paper_warm_cost, r.burst_warm_cost, r.burst_s2z_cost,
            r.savings_pct, r.cold_starts as f64, r.mean_warm_fraction,
            r.burst_warm_latency_s, r.burst_s2z_latency_s,
        ])).collect::<Vec<_>>(),
    )?;

    // Queue-granularity serving vs fluid-model latency, per policy.
    let sv = serving_experiment(100.0);
    export::table_csv(
        &dir.join("serving.csv"),
        &["policy", "fluid_mean_latency_s", "serving_mean_latency_s",
          "serving_p99_s", "serving_mean_batch", "serving_windows"],
        &sv.iter().map(|r| (r.policy.clone(), vec![
            r.fluid_mean_latency_s, r.serving_mean_latency_s,
            r.serving_p99_s, r.serving_mean_batch,
            r.serving_windows as f64,
        ])).collect::<Vec<_>>(),
    )?;

    // Fault injection: graceful degradation under capacity loss, spot
    // eviction, and bounded-queue overload.
    let ft = fault_experiment(100);
    export::table_csv(
        &dir.join("faults.csv"),
        &["cell", "goodput_rps", "high_priority_goodput_rps",
          "recovery_time_s", "shed_fraction", "retried", "disruption"],
        &ft.iter().map(|r| (r.label.clone(), vec![
            r.goodput_rps, r.high_priority_goodput_rps,
            r.recovery_time_s, r.shed_fraction, r.retried as f64,
            r.disruption,
        ])).collect::<Vec<_>>(),
    )?;

    // §VI placement: strategy × rebalancer head-to-head over the
    // adversarial priority registry.
    let pl = placement_experiment(100);
    export::table_csv(
        &dir.join("placement.csv"),
        &["cell", "mean_latency_s", "high_priority_latency_s",
          "total_throughput_rps", "migrations", "migration_stall_s",
          "gpu_util_spread"],
        &pl.iter().map(|r| (format!("{}/{}", r.strategy, r.rebalancer),
                            vec![
            r.mean_latency_s, r.high_priority_latency_s,
            r.total_throughput_rps, r.migrations as f64,
            r.migration_stall_s, r.gpu_util_spread,
        ])).collect::<Vec<_>>(),
    )?;

    // Recorded replay: live serving runs dumped as binary traces and
    // replayed bit-identically (the closure property of the format).
    let rp = replay_experiment(10.0, &[42, 43]);
    export::table_csv(
        &dir.join("replay.csv"),
        &["cell", "recorded_requests", "trace_bytes",
          "replay_completed", "replay_mean_latency_s", "replay_p99_s",
          "bit_identical"],
        &rp.iter().map(|r| (format!("{}/seed{}", r.policy, r.seed),
                            vec![
            r.recorded_requests as f64, r.trace_bytes as f64,
            r.replay_completed as f64, r.replay_mean_latency_s,
            r.replay_p99_s, r.bit_identical as u64 as f64,
        ])).collect::<Vec<_>>(),
    )?;

    // Workflow-DAG head-to-head: end-to-end workflow latency per policy
    // (CriticalPath weighted for the paper fan-out).
    let wf = workflow_experiment(100);
    export::table_csv(
        &dir.join("workflow.csv"),
        &["policy", "started", "completed", "mean_latency_s",
          "p99_latency_s"],
        &wf.iter().map(|r| (r.policy.clone(), vec![
            r.started as f64, r.completed as f64, r.mean_s, r.p99_s,
        ])).collect::<Vec<_>>(),
    )?;

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_all_produces_every_artifact() {
        let dir = crate::util::TempDir::new("t").unwrap();
        write_all(dir.path()).unwrap();
        for f in ["table1.csv", "table2.csv", "fig2a_latency.csv",
                  "fig2b_throughput.csv", "fig2c_allocation.csv",
                  "fig2d_cost_perf.csv", "robustness_overload.csv",
                  "robustness_spike.csv", "robustness_dominance.csv",
                  "allocator_scaling.csv", "economics.csv",
                  "serving.csv", "faults.csv", "placement.csv",
                  "workflow.csv", "replay.csv"] {
            let p = dir.path().join(f);
            assert!(p.exists(), "{f} missing");
            assert!(std::fs::metadata(&p).unwrap().len() > 0, "{f} empty");
        }
    }
}
