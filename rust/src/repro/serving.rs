//! Serving-granularity experiments: the `server::` queue path replayed
//! through the sweep engine.
//!
//! Two drivers:
//!
//! * [`serving_grid`] — the serving-layer evaluation grid as
//!   [`SweepCell::Serving`] cells: policy × allocation window ×
//!   max-batch × workload shape × seed, plus recorded-trace replay
//!   cells (one paper-Poisson recording per seed, shared across
//!   policies);
//! * [`serving_experiment`] — the queue-granularity latency contrast:
//!   per policy, the fluid-model estimator (§IV.B) versus the serving
//!   simulator's measured per-request sojourn times (mean and p99) over
//!   the same §IV.A workload, both replayed through one `run_sweep`
//!   pool.

use std::sync::Arc;

use crate::agents::AgentRegistry;
use crate::allocator::PolicyKind;
use crate::server::ServingConfig;
use crate::sim::batch::{default_workers, run_sweep, Scenario,
                        ScenarioBuilder, ServingScenario, SweepCell};
use crate::sim::SimConfig;
use crate::workload::trace::Trace;
use crate::workload::{ArrivalProcess, WorkloadKind};

/// The workload-shape axis of the serving grid: steady Poisson plus a
/// mid-run 10× coordinator spike (the §V.B reallocation probe), both at
/// serving granularity.
fn serving_shapes(duration_s: f64, arrival_dt_s: f64)
                  -> Vec<(&'static str, WorkloadKind)> {
    let ticks = (duration_s / arrival_dt_s).round().max(1.0) as u64;
    vec![
        ("steady", WorkloadKind::Steady),
        ("spike10x", WorkloadKind::Spike {
            agent: 0,
            factor: 10.0,
            start: ticks * 2 / 5,
            end: ticks * 3 / 5,
        }),
    ]
}

/// The serving-layer sweep grid: every built-in policy × allocation
/// window {50 ms, 200 ms} × max batch {1, 8} × workload shape × seed,
/// each cell labelled
/// `"serving/<policy>/w<ms>ms/b<batch>/<shape>/seed<seed>"`, plus one
/// recorded paper-Poisson trace per seed replayed under every policy
/// (`"serving/<policy>/trace/seed<seed>"`; the recording is shared, not
/// copied, across its policies).
pub fn serving_grid(duration_s: f64, seeds: &[u64]) -> Vec<SweepCell> {
    let windows_ms = [50u64, 200];
    let max_batches = [1usize, 8];
    let base = ServingConfig::paper();
    let shapes = serving_shapes(duration_s, base.arrival_dt_s);
    let mut cells = Vec::new();
    for policy in PolicyKind::all() {
        for &window_ms in &windows_ms {
            for &max_batch in &max_batches {
                for (shape, kind) in &shapes {
                    for &seed in seeds {
                        let mut cfg = base.clone();
                        cfg.duration_s = duration_s;
                        cfg.alloc_window_s = window_ms as f64 / 1e3;
                        cfg.max_batch = max_batch;
                        cfg.workload_kind = kind.clone();
                        cfg.seed = seed;
                        cells.push(ScenarioBuilder::new(
                            format!("serving/{}/w{window_ms}ms/\
                                     b{max_batch}/{shape}/seed{seed}",
                                    policy.name()),
                            SimConfig::paper(), AgentRegistry::paper())
                            .policy(policy.clone())
                            .serving(cfg)
                            .build()
                            .expect("serving cells carry no \
                                     conflicting axes"));
                    }
                }
            }
        }
    }
    // Recorded-trace replays: one recording per seed, spanning the same
    // duration at one-second ticks, shared across the policies.
    let trace_steps = duration_s.round().max(1.0) as u64;
    for &seed in seeds {
        let trace = Arc::new(Trace::paper_poisson(trace_steps, seed));
        for policy in PolicyKind::all() {
            let mut cfg = base.clone();
            cfg.duration_s = duration_s;
            cells.push(ScenarioBuilder::new(
                format!("serving/{}/trace/seed{seed}", policy.name()),
                SimConfig::paper(), AgentRegistry::paper())
                .policy(policy)
                .serving(cfg)
                .trace(Arc::clone(&trace))
                .build()
                .expect("serving trace cells carry no conflicting \
                         axes"));
        }
    }
    cells
}

/// One row of the fluid-vs-serving latency contrast (per policy).
#[derive(Debug, Clone)]
pub struct ServingComparisonRow {
    /// Policy name.
    pub policy: String,
    /// Fluid-model mean latency (the §IV.B backlog estimator, s).
    pub fluid_mean_latency_s: f64,
    /// Serving-layer mean per-request sojourn time (s).
    pub serving_mean_latency_s: f64,
    /// Serving-layer mean per-agent p99 sojourn time (s).
    pub serving_p99_s: f64,
    /// Mean executed batch size at the serving layer.
    pub serving_mean_batch: f64,
    /// Allocation windows the serving run closed.
    pub serving_windows: u64,
}

/// The queue-granularity latency experiment: for every built-in policy,
/// one fluid [`Scenario`] (§IV.B estimator over `duration_s` one-second
/// steps, Poisson arrivals) and one [`ServingScenario`] of the same
/// workload, all replayed through one `run_sweep` pool. The fluid
/// estimator reads backlog-per-service-rate; the serving layer measures
/// each request's enqueue→completion sojourn through the real queue
/// path — the contrast the paper's 85 % headline actually lives in.
pub fn serving_experiment(duration_s: f64) -> Vec<ServingComparisonRow> {
    let steps = duration_s.round().max(1.0) as u64;
    let mut cells = Vec::new();
    for policy in PolicyKind::all() {
        let mut fluid_cfg = SimConfig::paper();
        fluid_cfg.steps = steps;
        fluid_cfg.arrival_process = ArrivalProcess::Poisson;
        cells.push(SweepCell::Single(Scenario::new(
            format!("fluid/{}", policy.name()), fluid_cfg,
            AgentRegistry::paper(), policy.clone())));

        let mut serving_cfg = ServingConfig::paper();
        serving_cfg.duration_s = duration_s;
        cells.push(SweepCell::Serving(ServingScenario::new(
            format!("serving/{}", policy.name()), serving_cfg,
            AgentRegistry::paper(), policy)));
    }
    let runs = run_sweep(&cells, default_workers());
    runs.chunks(2).map(|pair| {
        let fluid = pair[0].result.as_sim().expect("fluid cell first");
        let serving = pair[1].result.as_serving()
            .expect("serving cell second");
        ServingComparisonRow {
            policy: serving.policy.clone(),
            fluid_mean_latency_s: fluid.mean_latency(),
            serving_mean_latency_s: serving.mean_latency(),
            serving_p99_s: serving.mean_p99(),
            serving_mean_batch: serving.mean_batch(),
            serving_windows: serving.windows,
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_grid_covers_every_axis_with_unique_labels() {
        let seeds = [1u64, 2];
        let cells = serving_grid(3.0, &seeds);
        let policies = PolicyKind::all().len();
        // policy × window{2} × batch{2} × shape{2} × seed, plus one
        // trace cell per policy × seed.
        let expected = policies * 2 * 2 * 2 * seeds.len()
            + policies * seeds.len();
        assert_eq!(cells.len(), expected);
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), expected, "labels must be unique");
        assert!(cells.iter().any(|c| c.label()
                == "serving/adaptive/w50ms/b8/steady/seed1"));
        assert!(cells.iter().any(|c| c.label()
                == "serving/round_robin/trace/seed2"));
        assert!(cells.iter()
                .all(|c| matches!(c, SweepCell::Serving(_))));
    }

    #[test]
    fn serving_grid_runs_deterministically_through_the_pool() {
        let cells = serving_grid(2.0, &[42]);
        let one = run_sweep(&cells, 1);
        let many = run_sweep(&cells, 8);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.result.as_serving().unwrap(),
                       b.result.as_serving().unwrap(), "{}", a.label);
        }
        // Every cell actually served traffic.
        assert!(one.iter().all(|r| {
            r.result.as_serving().unwrap().total_completed > 0
        }));
    }

    #[test]
    fn serving_experiment_pairs_every_policy() {
        let rows = serving_experiment(5.0);
        assert_eq!(rows.len(), PolicyKind::all().len());
        for row in &rows {
            assert!(row.fluid_mean_latency_s >= 0.0);
            assert!(row.serving_mean_latency_s > 0.0, "{}", row.policy);
            assert!(row.serving_p99_s >= row.serving_mean_latency_s * 0.5,
                    "{}: p99 {} vs mean {}", row.policy,
                    row.serving_p99_s, row.serving_mean_latency_s);
            assert!(row.serving_windows > 0, "{}", row.policy);
            assert!(row.serving_mean_batch >= 1.0, "{}", row.policy);
        }
        let names: Vec<&str> =
            rows.iter().map(|r| r.policy.as_str()).collect();
        let expected: Vec<&str> = PolicyKind::all().iter()
            .map(PolicyKind::name).collect();
        assert_eq!(names, expected);
    }
}
