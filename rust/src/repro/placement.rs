//! Placement-policy experiments: strategy × rebalancer comparison over
//! the paper deployment, an adversarial priority registry, and
//! synthetic large-N registries — the ROADMAP's "placement policies as
//! a cell dimension" and "synthetic large-N registries as cluster
//! cells" axes, closed.
//!
//! Two drivers:
//!
//! * [`placement_grid`] — the placement axes as [`SweepCell::Cluster`]
//!   cells, folded into [`cluster_grid`](crate::repro::cluster_grid)
//!   (and therefore into `stress_sweep` and the CI sweeps): every
//!   [`PlacementStrategy`] × every [`Rebalancer`] kind over the paper
//!   deployment under dominance skew, plus `synthetic_registry`
//!   clusters of 16 / 64 / 256 agents on mixed-capacity devices;
//! * [`placement_experiment`] — the head-to-head table behind
//!   `agentsrv repro --exp placement` and `placement.csv`: every
//!   strategy × rebalancer over [`adversarial_registry`], reporting
//!   mean and High-priority latency, throughput, migrations, stalls,
//!   and GPU-utilization spread;
//! * [`large_n_grid`] — the skip-idle large-N axis: 1024- and
//!   4096-agent burst cells the event-stepped core fast-forwards, plus
//!   sparse-burst cells ([`synthetic_sparse_rates`]: only k of N agents
//!   ever receive arrivals) where the active-set tier steps just the
//!   hot minority inside busy ticks — also folded into the cluster grid
//!   and `stress_sweep`.

use crate::agents::{AgentProfile, AgentRegistry, Priority};
use crate::cluster::{MigrationModel, PlacementStrategy, Rebalancer};
use crate::repro::synthetic_registry;
use crate::sim::batch::{default_workers, run_sweep, ClusterScenario,
                        SweepCell};
use crate::sim::SimConfig;
use crate::workload::WorkloadKind;

/// The mixed-capacity device set the placement cells run on: one big
/// device plus progressively smaller ones (Σ = 2.5 GPUs).
fn mixed_capacities() -> Vec<f64> {
    vec![1.0, 0.75, 0.5, 0.25]
}

/// Arrival rates for a [`synthetic_registry`] of `n` agents: the
/// paper's §IV.A rates cycled, then normalized so the total stays at
/// the paper's 190 rps for *any* N (partial cycles included) — the
/// large-N cells stress *placement*, not overload.
pub fn synthetic_arrival_rates(n: usize) -> Vec<f64> {
    let base = AgentProfile::paper_arrival_rates();
    let raw: Vec<f64> = (0..n).map(|i| base[i % base.len()]).collect();
    let total: f64 = base.iter().sum();
    let raw_total: f64 = raw.iter().sum();
    let scale = total / raw_total;
    raw.into_iter().map(|r| r * scale).collect()
}

/// The `k` hot agents of a sparse-burst cell, spread evenly over `n`.
pub fn sparse_hot_agents(n: usize, k: usize) -> Vec<usize> {
    (0..k).map(|j| j * n / k).collect()
}

/// Arrival rates for a sparse-burst cell: only the `k` hot agents
/// ([`sparse_hot_agents`]) ever receive traffic, cycling the paper's
/// §IV.A rates over them and normalizing so total demand stays at the
/// paper's 190 rps — the cells stress *sparsity*, not overload.
pub fn synthetic_sparse_rates(n: usize, k: usize) -> Vec<f64> {
    let base = AgentProfile::paper_arrival_rates();
    let mut rates = vec![0.0; n];
    for (j, &i) in sparse_hot_agents(n, k).iter().enumerate() {
        rates[i] = base[j % base.len()];
    }
    let total: f64 = base.iter().sum();
    let raw_total: f64 = rates.iter().sum();
    let scale = total / raw_total;
    for r in rates.iter_mut() {
        *r *= scale;
    }
    rates
}

/// Registry for a sparse-burst cell: the
/// [`synthetic_registry`] profile shapes, except agents outside the hot
/// set carry a **zero** GPU floor — the serverless scale-to-zero
/// stance (a never-active agent holds no reservation), and what lets
/// the active-set tier settle the cold majority. Hot floors are scaled
/// so they stay jointly feasible at any `k`.
pub fn synthetic_sparse_registry(n: usize, k: usize) -> AgentRegistry {
    let base = AgentProfile::paper_agents();
    let mut profiles: Vec<AgentProfile> = (0..n).map(|i| {
        let b = &base[i % base.len()];
        AgentProfile {
            name: format!("agent{i}"),
            model_mb: b.model_mb,
            base_tput: b.base_tput,
            min_gpu: 0.0,
            priority: match i % 3 {
                0 => Priority::High,
                1 => Priority::Medium,
                _ => Priority::Low,
            },
        }
    }).collect();
    for (j, &i) in sparse_hot_agents(n, k).iter().enumerate() {
        profiles[i].min_gpu =
            base[j % base.len()].min_gpu * 4.0 / k.max(4) as f64;
    }
    AgentRegistry::new(profiles).expect("sparse profiles valid")
}

/// The config behind one sparse-burst cell: `n` agents, only the `k`
/// hot ones ever receiving traffic, all of it inside the same middle-
/// fifth burst window [`large_n_config`] uses. Outside the window the
/// whole-run idle jump applies; inside it the active-set tier steps
/// only the hot minority while the cold majority stays settled.
pub fn sparse_burst_config(n: usize, k: usize, steps: u64) -> SimConfig {
    let mut cfg = SimConfig::paper();
    cfg.steps = steps;
    cfg.arrival_rates = synthetic_sparse_rates(n, k);
    cfg.workload_kind = WorkloadKind::Burst {
        agents: sparse_hot_agents(n, k),
        start: steps * 2 / 5,
        end: steps * 3 / 5,
    };
    cfg
}

/// The adversarial registry for the strategy-dominance probes: one
/// small High-priority agent plus three bulk agents whose minimums and
/// traffic dominate. Size-only (headroom-decreasing) packing co-locates
/// the High agent with the hottest bulk agent; priority-spread parks it
/// on the least-contended device.
pub fn adversarial_registry() -> AgentRegistry {
    AgentRegistry::new(vec![
        AgentProfile {
            name: "bulk0".into(),
            model_mb: 2000,
            base_tput: 40.0,
            min_gpu: 0.50,
            priority: Priority::Medium,
        },
        AgentProfile {
            name: "bulk1".into(),
            model_mb: 2000,
            base_tput: 40.0,
            min_gpu: 0.45,
            priority: Priority::Medium,
        },
        AgentProfile {
            name: "bulk2".into(),
            model_mb: 1000,
            base_tput: 40.0,
            min_gpu: 0.25,
            priority: Priority::Low,
        },
        AgentProfile {
            name: "hi".into(),
            model_mb: 500,
            base_tput: 50.0,
            min_gpu: 0.20,
            priority: Priority::High,
        },
    ]).expect("adversarial registry is valid")
}

/// Arrival rates for [`adversarial_registry`]: bulk traffic dominates,
/// the High-priority agent runs a modest steady stream.
pub fn adversarial_rates() -> Vec<f64> {
    vec![80.0, 80.0, 20.0, 10.0]
}

/// The placement-policy axes as sweep cells, folded into
/// [`cluster_grid`](crate::repro::cluster_grid):
///
/// * every [`PlacementStrategy`] × every [`Rebalancer`] kind over the
///   paper deployment on a mixed-capacity 4-device cluster, under 90 %
///   single-agent dominance so the active rebalancers actually fire —
///   labelled `"placement/<strategy>/<rebalancer>/paper"`;
/// * synthetic large-N registries ([`synthetic_registry`] of 16 / 64 /
///   256 agents, [`synthetic_arrival_rates`]) on the same mixed
///   capacities under every strategy with hottest-agent rebalancing,
///   labelled `"placement/synth<n>/<strategy>"`.
///
/// Infeasible combos are skipped like the rest of the cluster grid.
pub fn placement_grid(steps: u64) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    for strategy in PlacementStrategy::all() {
        for rebalancer in Rebalancer::all() {
            let mut cfg = SimConfig::paper();
            cfg.steps = steps;
            cfg.workload_kind = WorkloadKind::Dominance {
                agent: 0, share: 0.9,
            };
            if let Ok(cell) = ClusterScenario::with_policies(
                format!("placement/{}/{}/paper", strategy.name(),
                        rebalancer.name()),
                cfg, AgentRegistry::paper(), mixed_capacities(),
                strategy, rebalancer)
            {
                cells.push(SweepCell::Cluster(cell));
            }
        }
    }
    for n in [16usize, 64, 256] {
        for strategy in PlacementStrategy::all() {
            let mut cfg = SimConfig::paper();
            cfg.steps = steps;
            cfg.arrival_rates = synthetic_arrival_rates(n);
            if let Ok(cell) = ClusterScenario::with_policies(
                format!("placement/synth{n}/{}", strategy.name()),
                cfg, synthetic_registry(n), mixed_capacities(), strategy,
                Rebalancer::HottestAgent(MigrationModel::default()))
            {
                cells.push(SweepCell::Cluster(cell));
            }
        }
    }
    cells
}

/// The skip-idle large-N axis, folded into
/// [`cluster_grid`](crate::repro::cluster_grid) (and therefore into
/// `stress_sweep`): synthetic registries of 1024 and 4096 agents on the
/// mixed-capacity devices, with *all* traffic packed into a mid-run
/// burst (`[2/5·steps, 3/5·steps)`) so the skip-idle event core
/// fast-forwards the idle majority of every run — what makes
/// 4096-agent cells routine sweep members instead of a bench-only
/// stunt. Labelled `"large_n/synth<n>/<strategy>"`; results are
/// bit-exact with the dense path (asserted by this module's tests).
pub fn large_n_grid(steps: u64) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    for n in [1024usize, 4096] {
        for strategy in [PlacementStrategy::HeadroomDecreasing,
                         PlacementStrategy::DemandAware] {
            if let Ok(cell) = ClusterScenario::with_policies(
                format!("large_n/synth{n}/{}", strategy.name()),
                large_n_config(n, steps), synthetic_registry(n),
                mixed_capacities(), strategy, Rebalancer::Static)
            {
                cells.push(SweepCell::Cluster(cell));
            }
        }
    }
    // Sparse-burst cells: only k of n agents ever receive arrivals, so
    // inside the burst window the active-set tier steps just the hot
    // minority while the settled cold majority is batch-accounted.
    for n in [1024usize, 4096] {
        for k in [8usize, 64] {
            if let Ok(cell) = ClusterScenario::with_policies(
                format!("large_n/sparse{n}x{k}/headroom"),
                sparse_burst_config(n, k, steps),
                synthetic_sparse_registry(n, k), mixed_capacities(),
                PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Static)
            {
                cells.push(SweepCell::Cluster(cell));
            }
        }
    }
    cells
}

/// The config behind one [`large_n_grid`] cell: `n` synthetic agents
/// whose entire (rate-normalized) traffic arrives in the middle fifth
/// of the run.
pub fn large_n_config(n: usize, steps: u64) -> SimConfig {
    let mut cfg = SimConfig::paper();
    cfg.steps = steps;
    cfg.arrival_rates = synthetic_arrival_rates(n);
    cfg.workload_kind = WorkloadKind::Burst {
        agents: (0..n).collect(),
        start: steps * 2 / 5,
        end: steps * 3 / 5,
    };
    cfg
}

/// One row of the strategy-comparison table (`placement.csv`).
#[derive(Debug, Clone)]
pub struct PlacementRow {
    /// Placement strategy name.
    pub strategy: String,
    /// Rebalancer name.
    pub rebalancer: String,
    /// Mean of per-agent mean latencies (s).
    pub mean_latency_s: f64,
    /// Mean latency over the High-priority agents only (s) — the number
    /// priority-spread placement exists to protect.
    pub high_priority_latency_s: f64,
    /// Aggregate throughput (rps).
    pub total_throughput_rps: f64,
    /// Migrations performed by the rebalancer.
    pub migrations: u64,
    /// Serving time lost to checkpoint transfers (s).
    pub migration_stall_s: f64,
    /// Max − min per-GPU mean utilization — the load-balance probe.
    pub gpu_util_spread: f64,
}

/// The §VI placement comparison behind `agentsrv repro --exp
/// placement`: every [`PlacementStrategy`] × [`Rebalancer`] over
/// [`adversarial_registry`] on two unit devices with bulk-heavy steady
/// traffic, all replayed through one `run_sweep` pool. On this registry
/// size-only packing pairs the High-priority agent with the hottest
/// bulk agent and its latency climbs; priority-spread keeps it on the
/// least-contended device and its latency stays flat — the contrast
/// `placement.csv` tabulates.
pub fn placement_experiment(steps: u64) -> Vec<PlacementRow> {
    let registry = adversarial_registry();
    let mut combos = Vec::new();
    let mut cells = Vec::new();
    for strategy in PlacementStrategy::all() {
        for rebalancer in Rebalancer::all() {
            let mut cfg = SimConfig::paper();
            cfg.steps = steps;
            cfg.arrival_rates = adversarial_rates();
            let cell = ClusterScenario::with_policies(
                format!("placement/{}/{}", strategy.name(),
                        rebalancer.name()),
                cfg, registry.clone(), vec![1.0, 1.0], strategy,
                rebalancer.clone())
                .expect("adversarial registry fits two unit GPUs");
            combos.push((strategy, rebalancer));
            cells.push(SweepCell::Cluster(cell));
        }
    }
    let runs = run_sweep(&cells, default_workers());
    runs.iter().zip(&combos).map(|(run, (strategy, rebalancer))| {
        let r = run.result.as_cluster().expect("cluster cell");
        let hi_lats: Vec<f64> = registry.profiles().iter().enumerate()
            .filter(|(_, p)| p.priority == Priority::High)
            .map(|(i, _)| r.agent_latencies[i])
            .collect();
        let util_max = r.gpu_utilization.iter().cloned()
            .fold(f64::MIN, f64::max);
        let util_min = r.gpu_utilization.iter().cloned()
            .fold(f64::MAX, f64::min);
        PlacementRow {
            strategy: strategy.name().to_string(),
            rebalancer: rebalancer.name().to_string(),
            mean_latency_s: r.mean_latency(),
            high_priority_latency_s: crate::util::mean(&hi_lats),
            total_throughput_rps: r.total_throughput(),
            migrations: r.migrations,
            migration_stall_s: r.migration_stall_s,
            gpu_util_spread: util_max - util_min,
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_rates_match_registry_and_scale_down() {
        // Partial cycles (n not a multiple of 4) normalize too.
        for n in [1usize, 4, 10, 16, 64, 256] {
            let rates = synthetic_arrival_rates(n);
            assert_eq!(rates.len(), synthetic_registry(n).len());
            let total: f64 = rates.iter().sum();
            // Total demand stays at the paper's 190 rps regardless of N.
            assert!((total - 190.0).abs() < 1e-9, "n={n}: {total}");
        }
    }

    #[test]
    fn placement_grid_covers_every_strategy_rebalancer_combo() {
        let cells = placement_grid(20);
        let strategies = PlacementStrategy::all();
        let rebalancers = Rebalancer::all();
        // paper combos + synth{16,64,256} × strategies, all feasible.
        let expected = strategies.len() * rebalancers.len()
            + 3 * strategies.len();
        assert_eq!(cells.len(), expected);
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), expected, "labels must be unique");
        for strategy in &strategies {
            for rebalancer in &rebalancers {
                let want = format!("placement/{}/{}/paper",
                                   strategy.name(), rebalancer.name());
                assert!(labels.contains(&want.as_str()),
                        "missing {want}");
            }
            let synth = format!("placement/synth64/{}", strategy.name());
            assert!(labels.contains(&synth.as_str()), "missing {synth}");
        }
        assert!(cells.iter()
                .all(|c| matches!(c, SweepCell::Cluster(_))));
    }

    #[test]
    fn synthetic_large_n_cells_run_through_the_pool() {
        // The ≥ 64-agent acceptance bar: synthetic cells run through
        // run_sweep and serve every agent.
        let cells: Vec<SweepCell> = placement_grid(10).into_iter()
            .filter(|c| c.label().starts_with("placement/synth"))
            .collect();
        assert!(!cells.is_empty());
        let runs = run_sweep(&cells, 4);
        for run in &runs {
            let r = run.result.as_cluster().expect("cluster cell");
            assert_eq!(r.n_gpus, 4, "{}", run.label);
            assert!(r.agent_throughputs.iter().all(|t| *t > 0.0),
                    "{}: an agent starved", run.label);
        }
        // At least one cell actually runs 256 agents.
        assert!(runs.iter().any(|run| {
            run.label.starts_with("placement/synth256")
                && run.result.as_cluster().unwrap()
                    .agent_throughputs.len() == 256
        }));
    }

    #[test]
    fn large_n_grid_runs_4096_agent_cells_through_the_pool() {
        // The tentpole acceptance bar: synthetic_registry(4096) cells as
        // routine sweep members, fast enough because the burst shape
        // leaves 4/5 of every run to the skip-idle core (and, on the
        // sparse cells, the cold majority to the active-set tier).
        let cells = large_n_grid(20);
        assert_eq!(cells.len(), 8,
                   "1024/4096 × headroom/demand + 1024/4096 × k=8/64");
        let labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        for want in ["large_n/synth1024/headroom",
                     "large_n/synth4096/demand",
                     "large_n/sparse1024x8/headroom",
                     "large_n/sparse4096x64/headroom"] {
            assert!(labels.contains(&want), "missing {want} in {labels:?}");
        }
        let runs = run_sweep(&cells, 4);
        for run in &runs {
            let r = run.result.as_cluster().expect("cluster cell");
            assert_eq!(r.n_gpus, mixed_capacities().len(), "{}", run.label);
            if run.label.starts_with("large_n/synth") {
                assert!(r.agent_throughputs.iter().all(|t| *t > 0.0),
                        "{}: an agent starved", run.label);
            } else {
                // Sparse cells: the hot minority serves, the cold
                // majority provably never does.
                assert!(r.agent_throughputs.iter().any(|t| *t > 0.0),
                        "{}: every agent starved", run.label);
                assert!(r.agent_throughputs.iter().any(|t| *t == 0.0),
                        "{}: no cold agent", run.label);
            }
        }
        assert!(runs.iter().any(|run| {
            run.label.starts_with("large_n/synth4096")
                && run.result.as_cluster().unwrap()
                    .agent_throughputs.len() == 4096
        }));
    }

    #[test]
    fn sparse_burst_cells_are_bit_exact_across_all_tiers() {
        use crate::cluster::ClusterSimulator;
        // Active-set vs skip-idle vs dense on the sparse-burst shape:
        // full ClusterResult equality, and the hot/cold split is real.
        for (n, k) in [(1024usize, 8usize), (4096, 64)] {
            let sim = ClusterSimulator::with_policies(
                sparse_burst_config(n, k, 100),
                synthetic_sparse_registry(n, k), mixed_capacities(),
                PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Static).unwrap();
            let active = sim.run().unwrap();
            assert_eq!(active, sim.run_dense().unwrap(), "n={n} k={k}");
            assert_eq!(active, sim.run_skip_idle().unwrap(),
                       "n={n} k={k}");
            let hot = sparse_hot_agents(n, k);
            for (i, t) in active.agent_throughputs.iter().enumerate() {
                if hot.contains(&i) {
                    assert!(*t > 0.0, "hot agent {i} starved (n={n})");
                } else {
                    assert_eq!(*t, 0.0, "cold agent {i} served (n={n})");
                }
            }
        }
    }

    #[test]
    fn sparse_burst_cells_are_pool_invariant() {
        // The 1/2/8-worker bit-identity gate over the new cells.
        let cells: Vec<SweepCell> = large_n_grid(20).into_iter()
            .filter(|c| c.label().starts_with("large_n/sparse"))
            .collect();
        assert_eq!(cells.len(), 4);
        let one = run_sweep(&cells, 1);
        for workers in [2usize, 8] {
            let many = run_sweep(&cells, workers);
            assert_eq!(one.len(), many.len());
            for (a, b) in one.iter().zip(&many) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.result.as_cluster(), b.result.as_cluster(),
                           "{} differs at {workers} workers", a.label);
            }
        }
    }

    #[test]
    fn sparse_rates_and_registry_agree_on_the_hot_set() {
        for (n, k) in [(16usize, 8usize), (1024, 8), (4096, 64)] {
            let hot = sparse_hot_agents(n, k);
            assert_eq!(hot.len(), k);
            let rates = synthetic_sparse_rates(n, k);
            let total: f64 = rates.iter().sum();
            assert!((total - 190.0).abs() < 1e-9, "n={n} k={k}: {total}");
            let reg = synthetic_sparse_registry(n, k);
            assert_eq!(reg.len(), n);
            assert!(reg.minimums_feasible(2.5), "n={n} k={k}");
            for i in 0..n {
                if hot.contains(&i) {
                    assert!(rates[i] > 0.0, "hot {i} has zero rate");
                } else {
                    assert_eq!(rates[i], 0.0, "cold {i} has traffic");
                    assert_eq!(reg.min_gpu()[i], 0.0,
                               "cold {i} holds a floor");
                }
            }
        }
    }

    #[test]
    fn large_n_cells_are_bit_exact_with_dense() {
        use crate::cluster::ClusterSimulator;
        // The skip-idle fast-forward must change nothing but wall time,
        // even at 4096 agents: run() == run_dense() exactly.
        for (n, steps) in [(1024usize, 200u64), (4096, 100)] {
            let sim = ClusterSimulator::with_policies(
                large_n_config(n, steps), synthetic_registry(n),
                mixed_capacities(), PlacementStrategy::HeadroomDecreasing,
                Rebalancer::Static).unwrap();
            let skip = sim.run().unwrap();
            assert_eq!(skip, sim.run_dense().unwrap(), "n={n}");
            assert!(skip.agent_throughputs.iter().all(|t| *t > 0.0),
                    "n={n}: an agent starved");
        }
    }

    #[test]
    fn placement_experiment_tabulates_every_combo() {
        let rows = placement_experiment(50);
        assert_eq!(rows.len(),
                   PlacementStrategy::all().len()
                       * Rebalancer::all().len());
        for row in &rows {
            assert!(row.total_throughput_rps > 0.0,
                    "{}/{}", row.strategy, row.rebalancer);
            assert!(row.gpu_util_spread >= 0.0);
            assert!(row.mean_latency_s >= 0.0);
        }
        // Static rebalancing never migrates.
        assert!(rows.iter()
                .filter(|r| r.rebalancer == "static")
                .all(|r| r.migrations == 0 && r.migration_stall_s == 0.0));
    }

    #[test]
    fn priority_spread_beats_size_only_packing_for_high_priority() {
        // The adversarial satellite: on a registry where the bulk
        // agents dominate traffic, headroom-decreasing pairs the High
        // agent with a hot bulk agent (its service rate dips below its
        // arrivals and latency climbs), while priority-spread keeps it
        // on the least-contended device.
        let rows = placement_experiment(100);
        let hi_latency = |strategy: &str| rows.iter()
            .find(|r| r.strategy == strategy && r.rebalancer == "static")
            .expect("combo present")
            .high_priority_latency_s;
        let spread = hi_latency("spread");
        let headroom = hi_latency("headroom");
        assert!(spread < headroom,
                "priority-spread {spread} should beat size-only \
                 packing {headroom} for the High-priority agent");
    }
}
