//! Fault-injection & graceful-degradation experiments.
//!
//! Two entry points:
//!
//! * [`fault_grid`] — the robustness axes as [`SweepCell::Fault`]
//!   cells: eviction rate × recovery policy × shed policy × allocator ×
//!   seed, across all three engines (fluid, cluster, serving). Folded
//!   into [`stress_sweep`](crate::repro::stress_sweep) so the whole
//!   evaluation surface, faults included, runs through one worker pool.
//! * [`fault_experiment`] — the graceful-degradation head-to-head: the
//!   same mid-run capacity loss under every allocator (the adaptive
//!   policy keeps High-priority goodput up where round-robin spreads
//!   the shortage evenly), the same spot eviction under every cluster
//!   recovery policy (throttled repack recovers; static forfeits the
//!   outage), and the serving shed-policy axis under overload.
//!
//! Exported as `faults.csv` by [`write_all`](crate::repro::write_all)
//! and via `agentsrv repro --exp faults`.

use crate::agents::AgentRegistry;
use crate::allocator::PolicyKind;
use crate::cluster::{MigrationModel, PlacementStrategy, Rebalancer};
use crate::serverless::ColdStartModel;
use crate::sim::batch::{run_sweep, FaultScenario, ScenarioBuilder,
                        SweepCell};
use crate::sim::fault::{AdmissionControl, FaultConfig, FaultEvent,
                        FaultModel, FaultPlan, ServingFaults, ShedPolicy};
use crate::sim::SimConfig;
use crate::server::ServingConfig;

/// The eviction-rate axis of the fault grid: (label, evictions/s).
/// Rates are per-device spot-eviction hazards; `evhigh` at 0.02/s over
/// a 100 s run expects ~2 outages per device.
pub fn eviction_rate_axis() -> Vec<(&'static str, f64)> {
    vec![("evlow", 0.005), ("evhigh", 0.02)]
}

/// The cluster-recovery axis swept by the fault grid.
fn recovery_axis() -> Vec<Rebalancer> {
    vec![
        Rebalancer::Static,
        Rebalancer::HottestAgent(MigrationModel::default()),
        Rebalancer::Repack(MigrationModel::default()),
    ]
}

/// The fault grid as sweep cells, across all three engines:
///
/// * single-GPU cells — every built-in policy × eviction rate × seed,
///   under a seeded spot-fault plan
///   (`"fault/single/<policy>/<rate>/seed<seed>"`);
/// * cluster cells — every recovery policy × eviction rate × seed on a
///   2-GPU cluster with throttled repack and rewarm cold starts
///   (`"fault/cluster/<rebalancer>/<rate>/seed<seed>"`);
/// * serving cells — {adaptive, round-robin} × shed policy × seed with
///   a short eviction window absorbed by retry and admission control
///   bounding the queues (`"fault/serving/<policy>/<shed>/seed<seed>"`).
///
/// Plans are generated from the seed, so every cell is reproducible
/// pure data and its parallel replay is bit-identical to the
/// sequential run (the property suite sweeps these cells at 1/2/8
/// workers).
pub fn fault_grid(steps: u64, seeds: &[u64]) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    let horizon = steps as f64; // dt = 1.0 in the paper config

    for policy in PolicyKind::all() {
        for (rate_name, rate) in eviction_rate_axis() {
            for &seed in seeds {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                cfg.seed = seed;
                let plan =
                    FaultModel::spot(rate, seed).generate(1, horizon);
                cells.push(ScenarioBuilder::new(
                    format!("fault/single/{}/{rate_name}/seed{seed}",
                            policy.name()),
                    cfg, AgentRegistry::paper())
                    .policy(policy.clone())
                    .faults(FaultConfig::new(plan))
                    .build()
                    .expect("fault cells carry no conflicting axes"));
            }
        }
    }

    for rebalancer in recovery_axis() {
        for (rate_name, rate) in eviction_rate_axis() {
            for &seed in seeds {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                cfg.seed = seed;
                let plan =
                    FaultModel::spot(rate, seed).generate(2, horizon);
                if let Ok(cell) = ScenarioBuilder::new(
                    format!("fault/cluster/{}/{rate_name}/seed{seed}",
                            rebalancer.name()),
                    cfg, AgentRegistry::paper())
                    .capacities(vec![1.2, 1.2])
                    .placement(PlacementStrategy::HeadroomDecreasing)
                    .rebalancer(rebalancer.clone())
                    .faults(FaultConfig::new(plan)
                        .with_repack_throttle(0.5)
                        .with_rewarm(ColdStartModel::default_platform()))
                    .build()
                {
                    cells.push(cell);
                }
            }
        }
    }

    for policy in [PolicyKind::adaptive(), PolicyKind::round_robin()] {
        for shed in ShedPolicy::all() {
            for &seed in seeds {
                let mut cfg = ServingConfig::paper();
                cfg.duration_s = (steps as f64 * 0.005).max(1.0);
                cfg.seed = seed;
                let plan = FaultPlan::new(vec![FaultEvent::GpuEviction {
                    t: 0.1, gpu: 0, duration: 0.02,
                }]);
                cells.push(ScenarioBuilder::new(
                    format!("fault/serving/{}/{}/seed{seed}",
                            policy.name(), shed.name()),
                    SimConfig::paper(), AgentRegistry::paper())
                    .policy(policy.clone())
                    .serving(cfg)
                    .serving_faults(ServingFaults::new(plan)
                        .with_admission(AdmissionControl::new(64, shed)))
                    .build()
                    .expect("serving fault cells carry no conflicting \
                             axes"));
            }
        }
    }

    cells
}

/// One row of the graceful-degradation comparison.
#[derive(Debug, Clone)]
pub struct FaultRow {
    /// Cell coordinates (`"single/<policy>"`, `"cluster/<rebalancer>"`,
    /// or `"serving/<shed>"`).
    pub label: String,
    /// Overall goodput over the run (requests/s actually served).
    pub goodput_rps: f64,
    /// Goodput of the High-priority agents (coordinator + reasoning)
    /// alone — the graceful-degradation probe.
    pub high_priority_goodput_rps: f64,
    /// Time spent degraded / lost to retries, per the engine's
    /// [`ResilienceReport`](crate::sim::fault::ResilienceReport).
    pub recovery_time_s: f64,
    /// Fraction of offered load shed by admission control.
    pub shed_fraction: f64,
    /// Retried batches (serving) or recovery migrations (cluster).
    pub retried: u64,
    /// Engine-specific disruption measure (stalled fraction, max repack
    /// move fraction, or failed fraction).
    pub disruption: f64,
}

/// The graceful-degradation head-to-head (§V robustness, extended with
/// faults):
///
/// * `single/<policy>` — every allocator under the *same* 60 %
///   capacity drop through the middle half of the run. Adaptive
///   priority weighting concentrates the shortage on Low/Medium tiers,
///   so High-priority goodput stays above round-robin's even split.
/// * `cluster/<rebalancer>` — the same spot eviction of one device
///   under each recovery policy; throttled `Repack` re-places the
///   displaced agents (bounded per-repack move fraction) where
///   `Static` forfeits the whole outage.
/// * `serving/<shed>` — the shed-policy axis under 3× overload with
///   bounded queues.
pub fn fault_experiment(steps: u64) -> Vec<FaultRow> {
    let horizon = steps as f64;
    let mut cells = Vec::new();

    // Single-engine capacity-drop comparison: one deterministic drop,
    // identical for every policy.
    let drop_plan = || FaultPlan::new(vec![FaultEvent::CapacityDrop {
        t: horizon * 0.25, frac: 0.6, duration: horizon * 0.5,
    }]);
    for policy in PolicyKind::all() {
        let mut cfg = SimConfig::paper();
        cfg.steps = steps;
        cells.push(SweepCell::Fault(FaultScenario::single(
            format!("single/{}", policy.name()),
            cfg, AgentRegistry::paper(), policy,
            FaultConfig::new(drop_plan()))));
    }

    // Cluster recovery comparison: one eviction, every recovery policy.
    let evict_plan = || FaultPlan::new(vec![FaultEvent::GpuEviction {
        t: horizon * 0.25, gpu: 0, duration: horizon * 0.25,
    }]);
    for rebalancer in recovery_axis() {
        let mut cfg = SimConfig::paper();
        cfg.steps = steps;
        if let Ok(cell) = FaultScenario::cluster(
            format!("cluster/{}", rebalancer.name()),
            cfg, AgentRegistry::paper(), vec![1.2, 1.2],
            PlacementStrategy::HeadroomDecreasing, rebalancer,
            FaultConfig::new(evict_plan()).with_repack_throttle(0.5))
        {
            cells.push(SweepCell::Fault(cell));
        }
    }

    // Serving shed-policy axis under overload with bounded queues.
    for shed in ShedPolicy::all() {
        let mut cfg = ServingConfig::paper();
        cfg.duration_s = (steps as f64 * 0.02).clamp(1.0, 5.0);
        cells.push(SweepCell::Fault(FaultScenario::serving(
            format!("serving/{}", shed.name()),
            cfg, AgentRegistry::paper(), PolicyKind::adaptive(),
            ServingFaults::new(FaultPlan::empty())
                .with_admission(AdmissionControl::new(48, shed)))));
    }

    let runs = run_sweep(&cells, crate::sim::batch::default_workers());
    runs.iter().map(|run| {
        // High-priority agents in the paper registry: coordinator (0)
        // and reasoning (3).
        let (goodput, high, rep) = match &run.result {
            crate::sim::batch::CellResult::Sim(r) => {
                let served: f64 = r.per_agent.iter()
                    .map(|a| a.processed_total).sum();
                let high: f64 = r.per_agent[0].processed_total
                    + r.per_agent[3].processed_total;
                (served / horizon, high / horizon, r.resilience.clone())
            }
            crate::sim::batch::CellResult::Cluster(r) => {
                let high = r.agent_throughputs[0]
                    + r.agent_throughputs[3];
                (r.total_throughput(), high, r.resilience.clone())
            }
            crate::sim::batch::CellResult::Serving(r) => {
                let span = r.makespan_s.max(1e-9);
                let high = (r.per_agent[0].completed
                            + r.per_agent[3].completed) as f64;
                (r.total_completed as f64 / span, high / span,
                 r.resilience.clone())
            }
        };
        let rep = rep.unwrap_or_default();
        FaultRow {
            label: run.label.clone(),
            goodput_rps: goodput,
            high_priority_goodput_rps: high,
            recovery_time_s: rep.recovery_time_s,
            shed_fraction: rep.shed_fraction,
            retried: rep.retried,
            disruption: rep.disruption,
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::batch::SweepCell;

    #[test]
    fn fault_grid_covers_every_axis_with_unique_labels() {
        let seeds = [1u64, 2];
        let cells = fault_grid(20, &seeds);
        let n_single =
            PolicyKind::all().len() * eviction_rate_axis().len() * 2;
        let n_cluster =
            recovery_axis().len() * eviction_rate_axis().len() * 2;
        let n_serving = 2 * ShedPolicy::all().len() * 2;
        assert_eq!(cells.len(), n_single + n_cluster + n_serving);
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        assert!(labels.iter()
                .any(|l| *l == "fault/single/adaptive/evhigh/seed2"));
        assert!(labels.iter()
                .any(|l| *l == "fault/cluster/repack/evlow/seed1"));
        assert!(labels.iter()
                .any(|l| *l == "fault/serving/round_robin/priority/seed2"));
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), cells.len(), "labels must be unique");
        assert!(cells.iter().all(|c| matches!(c, SweepCell::Fault(_))));
    }

    #[test]
    fn fault_grid_cells_run_and_surface_resilience() {
        // A thin slice of the grid actually runs; every cell carries a
        // ResilienceReport (the fault layer is armed in every cell —
        // plans from a seeded generator may legitimately be empty at
        // low rates, in which case the run is the control cell and
        // reports None).
        let cells = fault_grid(20, &[3]);
        let runs = run_sweep(&cells[..4.min(cells.len())], 2);
        assert!(!runs.is_empty());
        for run in &runs {
            let sim = run.result.as_sim()
                .expect("grid slice starts with single cells");
            assert!(sim.conservation_error() < 1e-6, "{}", run.label);
        }
    }

    #[test]
    fn adaptive_degrades_gracefully_where_round_robin_collapses() {
        let rows = fault_experiment(100);
        let get = |label: &str| rows.iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("missing row {label}"));

        // The tentpole claim: under the same capacity loss, adaptive
        // priority weighting keeps High-priority goodput above
        // round-robin's even split.
        let adaptive = get("single/adaptive");
        let rr = get("single/round_robin");
        assert!(adaptive.high_priority_goodput_rps
                > rr.high_priority_goodput_rps,
                "adaptive {} vs round-robin {}",
                adaptive.high_priority_goodput_rps,
                rr.high_priority_goodput_rps);
        // Both degrade, neither collapses to zero.
        assert!(rr.goodput_rps > 0.0);
        assert!(adaptive.recovery_time_s > 0.0);

        // Cluster recovery: throttled repack serves at least as much
        // High-priority work as never recovering, and its repacks
        // honor the 0.5 move throttle.
        let repack = get("cluster/repack");
        let stat = get("cluster/static");
        assert!(repack.high_priority_goodput_rps
                >= stat.high_priority_goodput_rps,
                "repack {} vs static {}",
                repack.high_priority_goodput_rps,
                stat.high_priority_goodput_rps);
        assert!(repack.disruption <= 0.5 + 1e-9,
                "repack moved {} of agents in one recovery",
                repack.disruption);

        // Serving shed axis: every policy sheds under overload but
        // keeps serving.
        for shed in ShedPolicy::all() {
            let row = get(&format!("serving/{}", shed.name()));
            assert!(row.shed_fraction > 0.0, "{}", row.label);
            assert!(row.goodput_rps > 0.0, "{}", row.label);
        }
    }
}
