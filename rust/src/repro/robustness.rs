//! §V.B robustness and scalability experiments.

use std::sync::Arc;
use std::time::Instant;

use crate::agents::{AgentProfile, AgentRegistry, Priority};
use crate::allocator::{AdaptivePolicy, AllocContext, AllocationPolicy,
                       PolicyKind};
use crate::cluster::{MigrationModel, PlacementStrategy, Rebalancer};
use crate::sim::batch::{run_batch, ClusterScenario, Scenario,
                        ScenarioBuilder, SweepCell};
use crate::sim::{SimConfig, Simulator};
use crate::workload::trace::Trace;
use crate::workload::{ArrivalProcess, WorkloadKind};

/// Outcome of the demand-overload experiment (§V.B: "demand exceeds
/// capacity by 3×").
#[derive(Debug, Clone)]
pub struct OverloadReport {
    /// Overload factor applied to every arrival rate.
    pub factor: f64,
    /// Adaptive mean latency at 1× (s).
    pub baseline_latency_s: f64,
    /// Adaptive mean latency at `factor`× (s).
    pub overload_latency_s: f64,
    /// Relative latency degradation in percent.
    pub degradation_pct: f64,
    /// Smallest per-agent throughput at 1× (rps) — starvation probe.
    pub baseline_min_throughput: f64,
    /// Smallest per-agent throughput under overload (rps).
    pub overload_min_throughput: f64,
}

/// Run adaptive allocation at 1× and `factor`× the paper workload.
///
/// The key §V.B claims checked: normalization degrades latency *gracefully*
/// (bounded by the estimator cap, no collapse) and prevents starvation
/// (every agent keeps processing — min throughput stays at its 1× level,
/// because Algorithm 1's allocation is scale-invariant in λ).
pub fn overload_experiment(factor: f64) -> OverloadReport {
    let mut over_cfg = SimConfig::paper();
    over_cfg.workload_kind = WorkloadKind::Scaled { factor };
    let scenarios = [
        Scenario::paper("baseline_1x", PolicyKind::adaptive()),
        Scenario::new(format!("overload_{factor}x"), over_cfg,
                      AgentRegistry::paper(), PolicyKind::adaptive()),
    ];
    let mut runs = run_batch(&scenarios, 2);
    let overload = runs.pop().expect("two scenarios ran").result;
    let baseline = runs.pop().expect("two scenarios ran").result;

    let min_tput = |r: &crate::sim::SimResult| {
        r.agent_throughputs().into_iter().fold(f64::MAX, f64::min)
    };
    OverloadReport {
        factor,
        baseline_latency_s: baseline.mean_latency(),
        overload_latency_s: overload.mean_latency(),
        degradation_pct: 100.0
            * (overload.mean_latency() / baseline.mean_latency() - 1.0),
        baseline_min_throughput: min_tput(&baseline),
        overload_min_throughput: min_tput(&overload),
    }
}

/// Outcome of the 10× arrival-spike experiment (§V.B: "adaptation occurs
/// within 100 ms").
#[derive(Debug, Clone)]
pub struct SpikeReport {
    /// Spike multiplier.
    pub factor: f64,
    /// Allocation of the spiked agent just before the spike.
    pub pre_spike_alloc: f64,
    /// Allocation of the spiked agent once adapted.
    pub post_spike_alloc: f64,
    /// Wall-simulation time from spike onset until the allocation reaches
    /// 95 % of its post-spike steady state (ms).
    pub adaptation_ms: f64,
}

/// 10 ms timesteps; the coordinator's arrival rate jumps 10× at t = 0.5 s.
///
/// Because Algorithm 1 re-evaluates demand from the instantaneous
/// observation each step, adaptation completes on the first step after
/// onset — 10 ms at this resolution, comfortably under the paper's 100 ms.
pub fn spike_experiment() -> SpikeReport {
    let factor = 10.0;
    let spike_start = 50u64; // step index at dt = 10 ms => t = 0.5 s
    let mut cfg = SimConfig::paper();
    cfg.dt = 0.01;
    cfg.steps = 100;
    cfg.workload_kind = WorkloadKind::Spike {
        agent: 0, factor, start: spike_start, end: cfg.steps,
    };
    cfg.arrival_process = ArrivalProcess::Deterministic;
    cfg.record_timelines = true;
    let sim = Simulator::new(cfg.clone(), AgentProfile::paper_agents());
    let r = sim.run(&mut AdaptivePolicy::default());
    let alloc = &r.timelines.expect("timelines").allocation;
    let coord = alloc.series(0);

    let pre = coord[spike_start as usize - 1];
    let post = *coord.last().expect("nonempty run");
    // First step at/after onset whose allocation is within 5 % of final.
    let adapted_step = (spike_start as usize..coord.len())
        .find(|&t| (coord[t] - post).abs() <= 0.05 * post)
        .unwrap_or(coord.len() - 1);
    let adaptation_ms =
        (adapted_step as f64 - spike_start as f64 + 1.0) * cfg.dt * 1000.0;

    SpikeReport { factor, pre_spike_alloc: pre, post_spike_alloc: post,
                  adaptation_ms }
}

/// Outcome of the single-agent-dominance experiment (§V.B: one agent
/// receives 90 % of all requests).
#[derive(Debug, Clone)]
pub struct DominanceReport {
    /// Per agent: (name, request share, mean GPU share).
    pub agents: Vec<(String, f64, f64)>,
    /// GPU share of the dominant agent.
    pub dominant_gpu_share: f64,
}

/// Priority-based weighting must prevent the dominant agent from
/// monopolizing the GPU: its share stays far below its request share and
/// every other agent keeps at least its minimum-derived share.
pub fn dominance_experiment(share: f64) -> DominanceReport {
    let mut cfg = SimConfig::paper();
    cfg.workload_kind = WorkloadKind::Dominance { agent: 0, share };
    cfg.record_timelines = true;
    let sim = Simulator::new(cfg, AgentProfile::paper_agents());
    let r = sim.run(&mut AdaptivePolicy::default());

    // Derived from the paper registry (not hardcoded), so the repro
    // tracks any change to the arrival-rate table. The shares sum to 1
    // by construction (asserted in this module's tests).
    let rates = AgentProfile::paper_arrival_rates();
    let total_rate: f64 = rates.iter().sum();
    let profiles = AgentProfile::paper_agents();
    let request_share = |i: usize| {
        if i == 0 {
            share
        } else {
            let others: f64 = total_rate - rates[0];
            (1.0 - share) * rates[i] / others
        }
    };
    let agents: Vec<(String, f64, f64)> = profiles.iter().enumerate()
        .map(|(i, p)| {
            (p.name.clone(), request_share(i),
             r.per_agent[i].allocation.mean())
        })
        .collect();
    let dominant_gpu_share = agents[0].2;
    DominanceReport { agents, dominant_gpu_share }
}

/// The shape axis of the §V.B stress grid: name, schedule, process.
///
/// Beyond the paper's four §V.B shapes, the grid stresses a diurnal
/// cycle (two full sine periods over the run) and a correlated
/// multi-agent burst (coordinator + vision spiking together — the fan-out
/// pattern a collaborative workflow produces).
pub fn stress_shapes(steps: u64)
                     -> Vec<(&'static str, WorkloadKind, ArrivalProcess)> {
    vec![
        ("steady", WorkloadKind::Steady, ArrivalProcess::Deterministic),
        ("overload3x", WorkloadKind::Scaled { factor: 3.0 },
         ArrivalProcess::Deterministic),
        ("spike10x", WorkloadKind::Spike {
            agent: 0, factor: 10.0,
            start: steps * 2 / 5, end: steps * 3 / 5,
        }, ArrivalProcess::Deterministic),
        ("poisson", WorkloadKind::Steady, ArrivalProcess::Poisson),
        ("diurnal", WorkloadKind::Diurnal {
            amplitude: 0.6, period: steps as f64 / 2.0,
        }, ArrivalProcess::Deterministic),
        ("multispike5x", WorkloadKind::MultiSpike {
            agents: vec![0, 2], factor: 5.0,
            start: steps * 2 / 5, end: steps * 3 / 5,
        }, ArrivalProcess::Deterministic),
    ]
}

/// The full §V.B robustness grid as batch scenarios: every built-in
/// policy × every stress shape × every seed, over the paper deployment,
/// labelled `"<policy>/<shape>/seed<seed>"`. The grid size is
/// `PolicyKind::all().len() × stress_shapes().len() × seeds.len()` —
/// growing the policy registry or the shape axis grows the grid.
///
/// `stress_grid(100, &[42])` is the grid the `robustness` bench ablates;
/// the `sweep_scaling` bench scales `steps` and `seeds` up to measure
/// batch-engine throughput.
pub fn stress_grid(steps: u64, seeds: &[u64]) -> Vec<Scenario> {
    let shapes = stress_shapes(steps);
    let policies = PolicyKind::all();
    let mut grid =
        Vec::with_capacity(policies.len() * shapes.len() * seeds.len());
    for policy in policies {
        for (shape, kind, process) in &shapes {
            for &seed in seeds {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                cfg.workload_kind = kind.clone();
                cfg.arrival_process = *process;
                cfg.seed = seed;
                grid.push(Scenario::new(
                    format!("{}/{shape}/seed{seed}", policy.name()),
                    cfg, AgentRegistry::paper(), policy.clone()));
            }
        }
    }
    grid
}

/// The §VI multi-GPU grid as sweep cells: GPU count × per-GPU capacity ×
/// migration model over the paper deployment, labelled
/// `"cluster/<gpus>gpu/cap<capacity>/<mig|nomig>"`. Infeasible combos
/// (the agents cannot be placed, e.g. one GPU at capacity 0.6) are
/// skipped. Each migration-enabled combo also gets a `/skew` variant
/// under 90 % single-agent dominance, so the migration path actually
/// fires inside the grid. Mixed per-GPU capacities (heterogeneous
/// devices) are a further axis, labelled
/// `"cluster/hetero/<cap>+<cap>+..."`, and the placement-policy axes —
/// every `PlacementStrategy` × `Rebalancer` combination plus synthetic
/// large-N registries ([`crate::repro::placement_grid`], labels
/// `"placement/..."`) — ride along, so the whole placement ×
/// rebalancing surface is sweepable through this one grid.
pub fn cluster_grid(steps: u64) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    // Heterogeneous-capacity cells: one large device plus smaller ones
    // (feasibility-checked like the uniform axis).
    for caps in [vec![1.0, 0.5], vec![1.0, 0.5, 0.25], vec![0.6, 0.4]] {
        let mut cfg = SimConfig::paper();
        cfg.steps = steps;
        let label = format!(
            "cluster/hetero/{}",
            caps.iter().map(|c| format!("{c}"))
                .collect::<Vec<_>>().join("+"));
        if let Ok(cell) = ClusterScenario::with_policies(
            label, cfg, AgentRegistry::paper(), caps,
            PlacementStrategy::HeadroomDecreasing, Rebalancer::Static)
        {
            cells.push(SweepCell::Cluster(cell));
        }
    }
    for n_gpus in [1usize, 2, 4] {
        for capacity in [0.6, 1.0] {
            for (mig_name, rebalancer) in [
                ("nomig", Rebalancer::Static),
                ("mig",
                 Rebalancer::HottestAgent(MigrationModel::default())),
            ] {
                let mut cfg = SimConfig::paper();
                cfg.steps = steps;
                if let Ok(cell) = ClusterScenario::new(
                    format!("cluster/{n_gpus}gpu/cap{capacity}/{mig_name}"),
                    cfg.clone(), AgentRegistry::paper(), n_gpus, capacity,
                    rebalancer.clone())
                {
                    cells.push(SweepCell::Cluster(cell));
                }
                // The skew variant exists to make the migration path
                // fire, which needs somewhere to migrate *to* — a
                // single-GPU cell can never rebalance.
                if !matches!(rebalancer, Rebalancer::Static)
                    && n_gpus >= 2
                {
                    let mut skew = cfg;
                    skew.workload_kind = WorkloadKind::Dominance {
                        agent: 0, share: 0.9,
                    };
                    if let Ok(cell) = ClusterScenario::new(
                        format!("cluster/{n_gpus}gpu/cap{capacity}/\
                                 {mig_name}/skew"),
                        skew, AgentRegistry::paper(), n_gpus, capacity,
                        rebalancer)
                    {
                        cells.push(SweepCell::Cluster(cell));
                    }
                }
            }
        }
    }
    // Placement-policy axes: strategy × rebalancer combos plus
    // synthetic large-N registries, as further cluster cells.
    cells.extend(crate::repro::placement_grid(steps));
    // Skip-idle large-N axis: 1024- and 4096-agent burst cells the
    // event core fast-forwards (labels "large_n/synth<n>/<strategy>"),
    // plus sparse-burst cells where only k of N agents ever receive
    // arrivals and the active-set tier steps just that hot minority
    // (labels "large_n/sparse<n>x<k>/headroom").
    cells.extend(crate::repro::large_n_grid(steps));
    cells
}

/// Trace-replay stress cells: one paper-workload Poisson trace recorded
/// per seed, replayed under every built-in policy, labelled
/// `"<policy>/trace/seed<seed>"`. The recorded trace is shared across
/// the policies of its seed, so every policy replays the *identical*
/// arrival stream.
pub fn trace_grid(steps: u64, seeds: &[u64]) -> Vec<SweepCell> {
    let mut cells =
        Vec::with_capacity(PolicyKind::all().len() * seeds.len());
    for &seed in seeds {
        // One recording per seed, shared (not copied) across policies.
        let trace = Arc::new(Trace::paper_poisson(steps, seed));
        for policy in PolicyKind::all() {
            cells.push(ScenarioBuilder::new(
                format!("{}/trace/seed{seed}", policy.name()),
                SimConfig::paper(), AgentRegistry::paper())
                .policy(policy)
                .trace(Arc::clone(&trace))
                .build()
                .expect("trace cells carry no conflicting axes"));
        }
    }
    cells
}

/// The whole §V.B + §VI + economics + serving + fault evaluation
/// surface as one heterogeneous grid: the single-GPU stress grid, the
/// cluster grid, the trace-replay cells, the serverless-economics cost
/// grid ([`crate::repro::cost_grid`]), the serving-layer queue-path
/// grid ([`crate::repro::serving_grid`], 10 virtual seconds per cell),
/// the fault-injection grid ([`crate::repro::fault_grid`] —
/// eviction rate × recovery policy × shed policy × allocator × seed),
/// the workflow-DAG grid ([`crate::repro::workflow_grid`] — spec
/// shape × policy × placement × seed), and the recorded-replay cells
/// ([`crate::repro::replay_grid`] — live serving recordings dumped as
/// binary traces, replayed under every policy), mixed for one
/// `run_sweep` call through one worker pool.
pub fn stress_sweep(steps: u64, seeds: &[u64]) -> Vec<SweepCell> {
    let mut cells: Vec<SweepCell> = stress_grid(steps, seeds)
        .into_iter().map(SweepCell::Single).collect();
    cells.extend(cluster_grid(steps));
    cells.extend(trace_grid(steps, seeds));
    cells.extend(crate::repro::cost_grid(steps, seeds));
    cells.extend(crate::repro::serving_grid(10.0, seeds));
    cells.extend(crate::repro::fault_grid(steps, seeds));
    cells.extend(crate::repro::workflow_grid(steps, seeds));
    cells.extend(crate::repro::replay_grid(10.0, seeds));
    cells
}

/// One point of the allocator O(N) scaling sweep.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    /// Number of agents.
    pub n_agents: usize,
    /// Nanoseconds per `allocate()` call (averaged).
    pub ns_per_call: f64,
}

/// Synthetic registry of `n` agents cycling the paper's profile shapes.
pub fn synthetic_registry(n: usize) -> AgentRegistry {
    let base = AgentProfile::paper_agents();
    let profiles: Vec<AgentProfile> = (0..n).map(|i| {
        let b = &base[i % base.len()];
        AgentProfile {
            name: format!("agent{i}"),
            model_mb: b.model_mb,
            base_tput: b.base_tput,
            // Scale minimums down so they remain jointly feasible.
            min_gpu: b.min_gpu * 4.0 / n.max(4) as f64,
            priority: match i % 3 {
                0 => Priority::High,
                1 => Priority::Medium,
                _ => Priority::Low,
            },
        }
    }).collect();
    AgentRegistry::new(profiles).expect("synthetic profiles valid")
}

/// Measure `allocate()` wall time against agent count (§V.B "allocation
/// computation consuming under 1 ms", O(N)).
pub fn scaling_experiment(sizes: &[usize]) -> Vec<ScalingPoint> {
    sizes.iter().map(|&n| {
        let reg = synthetic_registry(n);
        let rates: Vec<f64> = (0..n).map(|i| 10.0 + (i % 7) as f64).collect();
        let queues = vec![0.0; n];
        let mut out = vec![0.0; n];
        let mut policy = AdaptivePolicy::default();

        // Warm-up, then timed loop sized to ~1 ms of work minimum.
        let iters = (1_000_000 / n.max(1)).clamp(100, 100_000);
        for _ in 0..10 {
            let ctx = AllocContext {
                registry: &reg, arrival_rates: &rates,
                queue_depths: &queues, step: 0, capacity: 1.0,
            };
            policy.allocate(&ctx, &mut out);
        }
        let start = Instant::now();
        for step in 0..iters {
            let ctx = AllocContext {
                registry: &reg, arrival_rates: &rates,
                queue_depths: &queues, step: step as u64, capacity: 1.0,
            };
            policy.allocate(&ctx, &mut out);
        }
        let ns = start.elapsed().as_nanos() as f64 / iters as f64;
        ScalingPoint { n_agents: n, ns_per_call: ns }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::batch::run_sweep;

    #[test]
    fn overload_degrades_gracefully_without_starvation() {
        let r = overload_experiment(3.0);
        // Latency grows but stays bounded (estimator cap 1000 s).
        assert!(r.overload_latency_s > r.baseline_latency_s);
        assert!(r.overload_latency_s < 1000.0);
        // No starvation: Algorithm 1 is λ-scale-invariant, so every agent
        // keeps exactly its 1× throughput.
        assert!((r.overload_min_throughput
                 - r.baseline_min_throughput).abs() < 0.2,
                "min tput changed: {} -> {}",
                r.baseline_min_throughput, r.overload_min_throughput);
        assert!(r.overload_min_throughput > 0.0);
    }

    #[test]
    fn spike_adapts_within_100ms() {
        let r = spike_experiment();
        assert!(r.adaptation_ms <= 100.0, "took {} ms", r.adaptation_ms);
        assert!(r.post_spike_alloc > r.pre_spike_alloc,
                "spiked agent should gain share: {} -> {}",
                r.pre_spike_alloc, r.post_spike_alloc);
    }

    #[test]
    fn dominance_does_not_monopolize() {
        let r = dominance_experiment(0.9);
        assert!(r.dominant_gpu_share < 0.55,
                "dominant got {}", r.dominant_gpu_share);
        // Everyone else keeps a working share.
        for (name, _, gpu) in &r.agents[1..] {
            assert!(*gpu > 0.1, "{name} starved at {gpu}");
        }
    }

    #[test]
    fn dominance_request_shares_sum_to_one() {
        // The shares are derived from paper_arrival_rates(), not
        // hardcoded totals, so they must partition the request volume at
        // any dominance level.
        for share in [0.5, 0.9, 0.99] {
            let r = dominance_experiment(share);
            let total: f64 = r.agents.iter().map(|(_, req, _)| *req).sum();
            assert!((total - 1.0).abs() < 1e-9,
                    "share {share}: request shares sum to {total}");
            assert!((r.agents[0].1 - share).abs() < 1e-12);
        }
    }

    #[test]
    fn allocator_is_linear_and_sub_millisecond() {
        let pts = scaling_experiment(&[4, 64, 1024]);
        for p in &pts {
            assert!(p.ns_per_call < 1_000_000.0,
                    "N={} took {} ns", p.n_agents, p.ns_per_call);
        }
        // O(N): 256x more agents must cost well under 256^2 x more time —
        // allow generous constant-factor noise, reject quadratic blowup.
        let small = pts[0].ns_per_call.max(1.0);
        let big = pts[2].ns_per_call;
        assert!(big / small < 2000.0, "ratio {}", big / small);
    }

    #[test]
    fn stress_grid_covers_every_policy_shape_seed_cell() {
        let grid = stress_grid(50, &[1, 2]);
        // Size tracks the policy registry and the shape axis — adding a
        // policy or a shape must grow the grid without touching this
        // test.
        let expected = PolicyKind::all().len() * stress_shapes(50).len() * 2;
        assert_eq!(grid.len(), expected);
        let mut labels: Vec<&str> =
            grid.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), expected, "labels must be unique");
        assert!(grid.iter()
                .any(|s| s.label == "adaptive/overload3x/seed2"));
        assert!(grid.iter().any(|s| s.label == "feedback/diurnal/seed1"));
        assert!(grid.iter()
                .any(|s| s.label == "adaptive/multispike5x/seed2"));
        // Every cell runs the configured number of steps.
        let runs = run_batch(&grid[..4], 2);
        assert!(runs.iter().all(|r| r.result.steps == 50));
    }

    #[test]
    fn cluster_grid_skips_infeasible_combos_and_labels_axes() {
        let cells = cluster_grid(20);
        let labels: Vec<&str> = cells.iter().map(SweepCell::label).collect();
        // One GPU at 0.6 capacity cannot hold the paper agents (Σ min =
        // 1.0): skipped, not panicked.
        assert!(!labels.iter().any(|l| l.starts_with("cluster/1gpu/cap0.6")),
                "{labels:?}");
        // Feasible axes are present, including the skewed migration
        // cell, the heterogeneous-capacity cells, and the
        // placement-policy axes (strategy × rebalancer combos plus
        // synthetic large-N registries).
        for want in ["cluster/1gpu/cap1/nomig", "cluster/2gpu/cap0.6/mig",
                     "cluster/4gpu/cap1/mig/skew", "cluster/hetero/1+0.5",
                     "cluster/hetero/0.6+0.4",
                     "placement/spread/repack/paper",
                     "placement/demand/hottest/paper",
                     "placement/synth64/demand",
                     "placement/synth256/inorder",
                     "large_n/synth1024/headroom",
                     "large_n/synth4096/demand"] {
            assert!(labels.contains(&want), "missing {want} in {labels:?}");
        }
        // Every cell is a cluster cell and actually runs.
        let runs = run_sweep(&cells, 4);
        assert!(runs.iter().all(|r| r.result.as_cluster().is_some()));
        // The skew cells exist to exercise migration: at least one
        // migration-enabled cell must migrate.
        let migrated = runs.iter()
            .filter(|r| r.label.ends_with("/skew"))
            .any(|r| r.result.as_cluster().unwrap().migrations >= 1);
        assert!(migrated, "no skew cell migrated");
        // The dominance-skewed placement combos fire their rebalancers
        // too.
        let placement_migrated = runs.iter()
            .filter(|r| r.label.starts_with("placement/")
                    && r.label.contains("/hottest/"))
            .any(|r| r.result.as_cluster().unwrap().migrations >= 1);
        assert!(placement_migrated, "no placement cell migrated");
    }

    #[test]
    fn stress_sweep_mixes_every_cell_kind() {
        let seeds = [1u64, 2];
        let cells = stress_sweep(10, &seeds);
        let singles = cells.iter()
            .filter(|c| matches!(c, SweepCell::Single(_))).count();
        let clusters = cells.iter()
            .filter(|c| matches!(c, SweepCell::Cluster(_))).count();
        let traces = cells.iter()
            .filter(|c| matches!(c, SweepCell::Trace(_))).count();
        let costs = cells.iter()
            .filter(|c| matches!(c, SweepCell::Cost(_))).count();
        let servings = cells.iter()
            .filter(|c| matches!(c, SweepCell::Serving(_))).count();
        let faults = cells.iter()
            .filter(|c| matches!(c, SweepCell::Fault(_))).count();
        let workflows = cells.iter()
            .filter(|c| matches!(c, SweepCell::Workflow(_))).count();
        assert_eq!(singles, stress_grid(10, &seeds).len());
        assert_eq!(clusters, cluster_grid(10).len());
        assert_eq!(traces,
                   PolicyKind::all().len() * seeds.len());
        assert_eq!(costs, crate::repro::cost_grid(10, &seeds).len());
        // Serving cells come from two grids: the serving grid and the
        // recorded-replay grid (both emit SweepCell::Serving).
        assert_eq!(servings,
                   crate::repro::serving_grid(10.0, &seeds).len()
                       + crate::repro::replay_grid(10.0, &seeds).len());
        assert_eq!(faults, crate::repro::fault_grid(10, &seeds).len());
        assert_eq!(workflows,
                   crate::repro::workflow_grid(10, &seeds).len());
        assert_eq!(cells.len(),
                   singles + clusters + traces + costs + servings
                       + faults + workflows);
        assert!(singles > 0 && clusters > 0 && traces > 0 && costs > 0
                && servings > 0 && faults > 0 && workflows > 0);
    }

    #[test]
    fn synthetic_registry_minimums_feasible() {
        for n in [4usize, 16, 256] {
            let reg = synthetic_registry(n);
            assert!(reg.minimums_feasible(1.0), "n={n}");
        }
    }
}
