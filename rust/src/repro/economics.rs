//! Serverless-economics experiments: Table II's cost row and the
//! §II.B/§III.D elasticity axes (pricing × scale-to-zero × cold start)
//! as first-class sweep cells.
//!
//! Two drivers:
//!
//!   * [`cost_grid`] — the full economics grid as [`SweepCell::Cost`]
//!     cells for `run_sweep`: every built-in policy × the pricing axis ×
//!     the idle-timeout axis × the cold-start axis × a seed set, over an
//!     idle-burst workload (two agents hard-idle outside a mid-run
//!     burst window — the shape under which scale-to-zero actually
//!     reclaims money and cold starts actually charge latency);
//!   * [`economics_experiment`] — the headline comparison: under the
//!     paper's all-warm model every full-GPU policy bills exactly
//!     Table II's $0.020 / 100 s (cost cannot distinguish them), and a
//!     finite scale-to-zero timeout *breaks that tie*, because each
//!     policy leaves a different share of the device parked on agents
//!     that the autoscaler can reclaim.

use crate::agents::AgentRegistry;
use crate::allocator::PolicyKind;
use crate::serverless::{ColdStartModel, EconomicsModel, GpuPricing};
use crate::sim::batch::{default_workers, run_sweep, CostScenario,
                        ScenarioBuilder, SweepCell};
use crate::sim::SimConfig;
use crate::workload::WorkloadKind;

/// The pricing axis of the cost grid: the paper's T4 (continuous
/// billing), the same device under a 300 ms billing quantum, and a 2×
/// premium device class.
///
/// The quantum applies per charge interval — one simulation step — so a
/// quantum that does not divide the 1 s step surfaces the rounding
/// overhead (each step bills `ceil(1.0 / 0.3) × 0.3 = 1.2` s, a 20 %
/// markup). A quantum that divides `dt` exactly (e.g. 100 ms) would be
/// indistinguishable from continuous billing at this granularity, which
/// is why the axis uses 300 ms.
pub fn pricing_axis() -> Vec<(&'static str, GpuPricing)> {
    vec![
        ("t4", GpuPricing::t4()),
        ("t4q300ms", GpuPricing {
            dollars_per_hour: 0.72,
            billing_quantum_s: 0.3,
        }),
        ("premium2x", GpuPricing {
            dollars_per_hour: 1.44,
            billing_quantum_s: 0.0,
        }),
    ]
}

/// The cold-start axis: an NVMe-cached fast path, the representative
/// platform (200 ms + 1 GB/s), and a 10× slow object-store load.
pub fn coldstart_axis() -> Vec<(&'static str, ColdStartModel)> {
    vec![
        ("fast", ColdStartModel {
            base_s: 0.05,
            s_per_mb: 0.0001,
            jitter: 0.05,
        }),
        ("platform", ColdStartModel::default_platform()),
        ("slow10x", ColdStartModel {
            base_s: 2.0,
            s_per_mb: 0.01,
            jitter: 0.1,
        }),
    ]
}

/// The scale-to-zero axis: always warm (the paper's evaluation) plus
/// two finite idle timeouts.
pub fn idle_timeout_axis() -> Vec<(&'static str, f64)> {
    vec![
        ("warm", f64::INFINITY),
        ("idle30", 30.0),
        ("idle5", 5.0),
    ]
}

/// The workload the cost cells run: NLP and reasoning hard-idle (zero
/// arrivals) outside a mid-run burst window, the other agents steady at
/// the paper rates. `seed` drives cold-start jitter.
pub fn idle_burst_config(steps: u64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::paper();
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.workload_kind = WorkloadKind::Burst {
        agents: vec![1, 3],
        start: steps * 2 / 5,
        end: steps * 3 / 5,
    };
    cfg
}

/// The serverless-economics grid as sweep cells: every built-in policy
/// × [`pricing_axis`] × [`idle_timeout_axis`] × [`coldstart_axis`] ×
/// `seeds`, over the [`idle_burst_config`] workload, labelled
/// `"cost/<policy>/<pricing>/<timeout>/<coldstart>/seed<seed>"`. The
/// always-warm timeout never samples a cold start, so its cells carry
/// the `platform` cold-start model only (the other entries would be
/// duplicate work under a different label).
pub fn cost_grid(steps: u64, seeds: &[u64]) -> Vec<SweepCell> {
    let mut cells = Vec::new();
    for policy in PolicyKind::all() {
        for (p_name, pricing) in pricing_axis() {
            for (t_name, idle_timeout_s) in idle_timeout_axis() {
                let colds = if idle_timeout_s.is_finite() {
                    coldstart_axis()
                } else {
                    vec![("platform", ColdStartModel::default_platform())]
                };
                for (c_name, cold_start) in colds {
                    for &seed in seeds {
                        let economics = EconomicsModel {
                            pricing,
                            cold_start: cold_start.clone(),
                            idle_timeout_s,
                        };
                        cells.push(ScenarioBuilder::new(
                            format!("cost/{}/{p_name}/{t_name}/{c_name}\
                                     /seed{seed}", policy.name()),
                            idle_burst_config(steps, seed),
                            AgentRegistry::paper())
                            .policy(policy.clone())
                            .economics(economics)
                            .build()
                            .expect("cost cells carry no conflicting \
                                     axes"));
                    }
                }
            }
        }
    }
    cells
}

/// One policy row of [`economics_experiment`].
#[derive(Debug, Clone)]
pub struct EconomicsRow {
    /// Policy name.
    pub policy: String,
    /// Paper workload, all-warm model — Table II's cost row: $0.020 per
    /// 100 s for every full-GPU policy.
    pub paper_warm_cost: f64,
    /// Idle-burst workload, all-warm model (idle agents still bill).
    pub burst_warm_cost: f64,
    /// Idle-burst workload under a 5 s scale-to-zero timeout.
    pub burst_s2z_cost: f64,
    /// Percent of the all-warm burst bill reclaimed by scale-to-zero.
    pub savings_pct: f64,
    /// Cold-start wake-ups across agents in the scale-to-zero run.
    pub cold_starts: u64,
    /// Mean warm fraction across agents in the scale-to-zero run.
    pub mean_warm_fraction: f64,
    /// Mean latency on the burst workload, all warm (s).
    pub burst_warm_latency_s: f64,
    /// Mean latency on the burst workload with scale-to-zero (s) — what
    /// the reclaimed dollars cost in cold-start delay.
    pub burst_s2z_latency_s: f64,
}

/// Run every built-in policy over three economics settings — paper
/// workload all-warm (the Table II tie), idle-burst all-warm, and
/// idle-burst with a 5 s scale-to-zero timeout — through the sweep
/// engine, and fold the results into one row per policy.
pub fn economics_experiment(steps: u64) -> Vec<EconomicsRow> {
    let policies = PolicyKind::all();
    let mut cells = Vec::with_capacity(policies.len() * 3);
    for policy in &policies {
        cells.push(SweepCell::Cost(CostScenario::new(
            format!("paper-warm/{}", policy.name()),
            SimConfig::paper(), AgentRegistry::paper(),
            EconomicsModel::paper_all_warm(), policy.clone())));
        cells.push(SweepCell::Cost(CostScenario::new(
            format!("burst-warm/{}", policy.name()),
            idle_burst_config(steps, 42), AgentRegistry::paper(),
            EconomicsModel::paper_all_warm(), policy.clone())));
        cells.push(SweepCell::Cost(CostScenario::new(
            format!("burst-s2z/{}", policy.name()),
            idle_burst_config(steps, 42), AgentRegistry::paper(),
            EconomicsModel::with_idle_timeout(5.0), policy.clone())));
    }
    let runs = run_sweep(&cells, default_workers());

    runs.chunks_exact(3).zip(&policies).map(|(chunk, policy)| {
        let paper_warm = chunk[0].result.as_sim().expect("cost cell");
        let burst_warm = chunk[1].result.as_sim().expect("cost cell");
        let burst_s2z = chunk[2].result.as_sim().expect("cost cell");
        let econ = burst_s2z.economics.as_ref()
            .expect("economics always on in a cost cell");
        let warm_cost = burst_warm.cost_dollars;
        EconomicsRow {
            policy: policy.name().to_string(),
            paper_warm_cost: paper_warm.cost_dollars,
            burst_warm_cost: warm_cost,
            burst_s2z_cost: burst_s2z.cost_dollars,
            savings_pct: if warm_cost > 0.0 {
                100.0 * (1.0 - burst_s2z.cost_dollars / warm_cost)
            } else {
                0.0
            },
            cold_starts: econ.total_cold_starts(),
            mean_warm_fraction: econ.mean_warm_fraction(),
            burst_warm_latency_s: burst_warm.mean_latency(),
            burst_s2z_latency_s: burst_s2z.mean_latency(),
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_grid_covers_every_axis_with_unique_labels() {
        let seeds = [1u64, 2];
        let cells = cost_grid(50, &seeds);
        // warm carries one cold-start entry, the finite timeouts all of
        // them.
        let per_policy = pricing_axis().len()
            * (1 + (idle_timeout_axis().len() - 1) * coldstart_axis().len())
            * seeds.len();
        assert_eq!(cells.len(), PolicyKind::all().len() * per_policy);
        let mut labels: Vec<&str> =
            cells.iter().map(SweepCell::label).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), cells.len(), "labels must be unique");
        assert!(cells.iter().all(
            |c| matches!(c, SweepCell::Cost(_))));
        assert!(labels.iter().any(
            |l| *l == "cost/adaptive/t4/warm/platform/seed1"));
        assert!(labels.iter().any(
            |l| *l == "cost/static_equal/premium2x/idle5/slow10x/seed2"));
    }

    #[test]
    fn cost_cells_surface_their_economics_reports() {
        let cells = cost_grid(50, &[42]);
        let runs = run_sweep(&cells[..6], 3);
        for run in &runs {
            let econ = run.result.economics()
                .unwrap_or_else(|| panic!("{}: report missing", run.label));
            assert_eq!(econ.per_agent_cost.len(), 4);
            assert!((run.result.cost_dollars() - econ.total_cost()).abs()
                    < 1e-9, "{}", run.label);
        }
    }

    #[test]
    fn all_warm_ties_at_table2_cost_and_scale_to_zero_breaks_it() {
        // One economics_experiment run backs both halves of the claim
        // (the full property-level version lives in sim_properties.rs).
        let rows = economics_experiment(100);
        // Every full-GPU policy bills exactly $0.020 per 100 s under the
        // all-warm paper settings — the cost tie the paper reports.
        assert_eq!(rows.len(), PolicyKind::all().len());
        for row in &rows {
            assert!((row.paper_warm_cost - 0.020).abs() < 1e-6,
                    "{}: {}", row.policy, row.paper_warm_cost);
        }
        // ...and a finite idle timeout breaks that tie.
        let costs: Vec<f64> =
            rows.iter().map(|r| r.burst_s2z_cost).collect();
        let max = costs.iter().cloned().fold(f64::MIN, f64::max);
        let min = costs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min > 1e-4,
                "scale-to-zero should separate the policies: {costs:?}");
        for row in &rows {
            // Reclaiming idle instances can only reduce the bill...
            assert!(row.burst_s2z_cost <= row.burst_warm_cost + 1e-12,
                    "{}: {} > {}", row.policy, row.burst_s2z_cost,
                    row.burst_warm_cost);
            // ...the burst pays for it in cold starts and cold steps.
            assert!(row.cold_starts >= 1, "{}", row.policy);
            assert!(row.mean_warm_fraction < 1.0, "{}", row.policy);
            assert!(row.burst_s2z_latency_s
                    >= row.burst_warm_latency_s - 1e-9,
                    "{}: cold starts cannot reduce latency", row.policy);
        }
        // At least one policy actually saves real money.
        assert!(rows.iter().any(|r| r.savings_pct > 10.0),
                "{rows:?}");
    }
}
