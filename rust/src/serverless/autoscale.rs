//! Scale-to-zero autoscaler for agent instances.
//!
//! Keeps an agent's container warm while it has traffic or backlog, scales
//! to zero after an idle timeout, and triggers warm-up when demand returns.
//! This is the serverless elasticity substrate (§II.B / §III.D) the
//! allocation policies run on top of; the paper's evaluation holds all
//! agents warm, which corresponds to `idle_timeout_s = ∞`.

use crate::serverless::{ColdStartModel, InstanceState};
use crate::util::Rng;

/// What the autoscaler decided for one agent this step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AutoscaleDecision {
    /// Keep the current state.
    Hold,
    /// Begin warming a cold instance (cold start sampled).
    ScaleUp { ready_at: f64 },
    /// Tear the instance down (idle timeout hit).
    ScaleToZero,
}

/// Per-agent scale-to-zero controller.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cold_start: ColdStartModel,
    idle_timeout_s: f64,
    /// Per-agent: state and seconds of continuous idleness.
    states: Vec<InstanceState>,
    idle_for: Vec<f64>,
}

impl Autoscaler {
    /// Create for `n` agents, all initially warm (the paper's setup).
    pub fn all_warm(n: usize, cold_start: ColdStartModel,
                    idle_timeout_s: f64) -> Self {
        Autoscaler {
            cold_start,
            idle_timeout_s,
            states: vec![InstanceState::Warm; n],
            idle_for: vec![0.0; n],
        }
    }

    /// Current state of an agent's instance.
    pub fn state(&self, agent: usize) -> InstanceState {
        self.states[agent]
    }

    /// Whether the agent can serve requests right now.
    pub fn is_warm(&self, agent: usize) -> bool {
        matches!(self.states[agent], InstanceState::Warm)
    }

    /// Advance one step: observe demand (arrivals + backlog) for each
    /// agent at time `now` and return the decision taken per agent.
    pub fn step(&mut self, now: f64, dt: f64, demand: &[f64],
                model_mb: &[u32], rng: &mut Rng) -> Vec<AutoscaleDecision> {
        let mut out = Vec::with_capacity(self.states.len());
        for i in 0..self.states.len() {
            let busy = demand[i] > 0.0;
            let decision = match self.states[i] {
                InstanceState::Warm => {
                    if busy {
                        self.idle_for[i] = 0.0;
                        AutoscaleDecision::Hold
                    } else {
                        self.idle_for[i] += dt;
                        if self.idle_for[i] >= self.idle_timeout_s {
                            self.states[i] = InstanceState::Cold;
                            AutoscaleDecision::ScaleToZero
                        } else {
                            AutoscaleDecision::Hold
                        }
                    }
                }
                InstanceState::Cold => {
                    if busy {
                        let ready_at =
                            now + self.cold_start.sample(model_mb[i], rng);
                        self.states[i] = InstanceState::Warming { ready_at };
                        self.idle_for[i] = 0.0;
                        AutoscaleDecision::ScaleUp { ready_at }
                    } else {
                        AutoscaleDecision::Hold
                    }
                }
                InstanceState::Warming { ready_at } => {
                    if now >= ready_at {
                        self.states[i] = InstanceState::Warm;
                        self.idle_for[i] = 0.0;
                    }
                    AutoscaleDecision::Hold
                }
            };
            out.push(decision);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(timeout: f64) -> (Autoscaler, Rng) {
        (Autoscaler::all_warm(2, ColdStartModel::default_platform(),
                              timeout),
         Rng::new(9))
    }

    #[test]
    fn scales_to_zero_after_idle_timeout() {
        let (mut a, mut rng) = scaler(3.0);
        let mb = [500u32, 3000];
        for t in 0..3 {
            a.step(t as f64, 1.0, &[0.0, 5.0], &mb, &mut rng);
        }
        assert!(!a.is_warm(0), "idle agent should be cold");
        assert!(a.is_warm(1), "busy agent must stay warm");
    }

    #[test]
    fn warms_up_on_demand_and_becomes_ready() {
        let (mut a, mut rng) = scaler(1.0);
        let mb = [500u32, 3000];
        // Go cold.
        a.step(0.0, 1.0, &[0.0, 0.0], &mb, &mut rng);
        assert!(!a.is_warm(0));
        // Demand returns -> warming with a future ready time.
        let d = a.step(1.0, 1.0, &[10.0, 0.0], &mb, &mut rng);
        let ready_at = match d[0] {
            AutoscaleDecision::ScaleUp { ready_at } => ready_at,
            other => panic!("expected ScaleUp, got {other:?}"),
        };
        assert!(ready_at > 1.0);
        assert!(!a.is_warm(0));
        // After the cold start elapses it serves again.
        a.step(ready_at + 0.1, 1.0, &[10.0, 0.0], &mb, &mut rng);
        assert!(a.is_warm(0));
    }

    #[test]
    fn busy_agent_never_scales_down() {
        let (mut a, mut rng) = scaler(2.0);
        let mb = [500u32, 3000];
        for t in 0..50 {
            a.step(t as f64, 1.0, &[1.0, 1.0], &mb, &mut rng);
        }
        assert!(a.is_warm(0) && a.is_warm(1));
    }
}
