//! Scale-to-zero autoscaler for agent instances.
//!
//! Keeps an agent's container warm while it has traffic or backlog, scales
//! to zero after an idle timeout, and triggers warm-up when demand returns.
//! This is the serverless elasticity substrate (§II.B / §III.D) the
//! allocation policies run on top of; the paper's evaluation holds all
//! agents warm, which corresponds to `idle_timeout_s = ∞`.
//!
//! The simulation hot loops drive [`Autoscaler::step`] once per timestep;
//! it is allocation-free — outcomes are queried through
//! [`Autoscaler::state`] / [`Autoscaler::is_warm`] and the per-agent
//! [`Autoscaler::cold_starts`] counters.

use crate::serverless::{ColdStartModel, InstanceState};
use crate::util::Rng;

/// Per-agent scale-to-zero controller.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cold_start: ColdStartModel,
    idle_timeout_s: f64,
    /// Per-agent: state and seconds of continuous idleness.
    states: Vec<InstanceState>,
    idle_for: Vec<f64>,
    /// Per-agent: cold-start wake-ups triggered so far.
    cold_starts: Vec<u64>,
}

impl Autoscaler {
    /// Create for `n` agents, all initially warm (the paper's setup).
    pub fn all_warm(n: usize, cold_start: ColdStartModel,
                    idle_timeout_s: f64) -> Self {
        Autoscaler {
            cold_start,
            idle_timeout_s,
            states: vec![InstanceState::Warm; n],
            idle_for: vec![0.0; n],
            cold_starts: vec![0; n],
        }
    }

    /// Current state of an agent's instance.
    pub fn state(&self, agent: usize) -> InstanceState {
        self.states[agent]
    }

    /// Whether the agent can serve requests right now.
    pub fn is_warm(&self, agent: usize) -> bool {
        matches!(self.states[agent], InstanceState::Warm)
    }

    /// Cold-start wake-ups per agent since construction.
    pub fn cold_starts(&self) -> &[u64] {
        &self.cold_starts
    }

    /// Whether every instance is scaled to zero. A fully-cold scaler
    /// observing zero demand is an absorbing no-op: `step` neither
    /// mutates state nor consumes RNG, which is what lets the skip-idle
    /// engines fast-forward such windows. Note that *warm* idle agents
    /// do mutate (`idle_for` accrues), so warmth anywhere disqualifies
    /// the skip.
    pub fn all_cold(&self) -> bool {
        self.states.iter().all(|s| matches!(s, InstanceState::Cold))
    }

    /// Advance one step: observe demand (arrivals + backlog) for each
    /// agent at time `now`. A warm agent whose continuous idleness
    /// reaches `idle_timeout_s` is torn down; a cold agent with demand
    /// begins warming behind a sampled cold start (counted in
    /// [`Autoscaler::cold_starts`]); a warming agent becomes warm once
    /// `now` passes its ready time. Returns the number of cold-start
    /// wake-ups triggered this step.
    pub fn step(&mut self, now: f64, dt: f64, demand: &[f64],
                model_mb: &[u32], rng: &mut Rng) -> usize {
        let mut woke = 0;
        for i in 0..self.states.len() {
            let busy = demand[i] > 0.0;
            match self.states[i] {
                InstanceState::Warm => {
                    if busy {
                        self.idle_for[i] = 0.0;
                    } else {
                        self.idle_for[i] += dt;
                        if self.idle_for[i] >= self.idle_timeout_s {
                            self.states[i] = InstanceState::Cold;
                        }
                    }
                }
                InstanceState::Cold => {
                    if busy {
                        let ready_at =
                            now + self.cold_start.sample(model_mb[i], rng);
                        self.states[i] = InstanceState::Warming { ready_at };
                        self.idle_for[i] = 0.0;
                        self.cold_starts[i] += 1;
                        woke += 1;
                    }
                }
                InstanceState::Warming { ready_at } => {
                    if now >= ready_at {
                        self.states[i] = InstanceState::Warm;
                        self.idle_for[i] = 0.0;
                    }
                }
            }
        }
        woke
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(timeout: f64) -> (Autoscaler, Rng) {
        (Autoscaler::all_warm(2, ColdStartModel::default_platform(),
                              timeout),
         Rng::new(9))
    }

    #[test]
    fn scales_to_zero_after_idle_timeout() {
        let (mut a, mut rng) = scaler(3.0);
        let mb = [500u32, 3000];
        for t in 0..3 {
            a.step(t as f64, 1.0, &[0.0, 5.0], &mb, &mut rng);
        }
        assert!(!a.is_warm(0), "idle agent should be cold");
        assert!(a.is_warm(1), "busy agent must stay warm");
        assert_eq!(a.cold_starts(), &[0, 0], "teardown is not a wake-up");
    }

    #[test]
    fn timeout_boundary_is_inclusive() {
        // idle_for accrues dt per idle step and tears down at *exactly*
        // the timeout — not one step later.
        let (mut a, mut rng) = scaler(2.0);
        let mb = [500u32, 3000];
        a.step(0.0, 1.0, &[0.0, 1.0], &mb, &mut rng); // idle_for = 1.0
        assert!(a.is_warm(0));
        a.step(1.0, 1.0, &[0.0, 1.0], &mb, &mut rng); // idle_for = 2.0
        assert!(!a.is_warm(0), "must scale down at idle_for == timeout");
    }

    #[test]
    fn zero_timeout_tears_down_on_first_idle_step() {
        let (mut a, mut rng) = scaler(0.0);
        let mb = [500u32, 3000];
        a.step(0.0, 1.0, &[0.0, 1.0], &mb, &mut rng);
        assert!(!a.is_warm(0));
        assert!(a.is_warm(1), "busy agent unaffected by zero timeout");
    }

    #[test]
    fn infinite_timeout_never_scales_down() {
        let (mut a, mut rng) = scaler(f64::INFINITY);
        let mb = [500u32, 3000];
        for t in 0..10_000 {
            a.step(t as f64, 1.0, &[0.0, 0.0], &mb, &mut rng);
        }
        assert!(a.is_warm(0) && a.is_warm(1));
        assert_eq!(a.cold_starts(), &[0, 0]);
    }

    #[test]
    fn demand_on_the_teardown_step_resets_the_idle_clock() {
        let (mut a, mut rng) = scaler(2.0);
        let mb = [500u32, 3000];
        a.step(0.0, 1.0, &[0.0, 1.0], &mb, &mut rng); // idle_for = 1.0
        a.step(1.0, 1.0, &[4.0, 1.0], &mb, &mut rng); // busy again
        a.step(2.0, 1.0, &[0.0, 1.0], &mb, &mut rng); // idle_for = 1.0
        assert!(a.is_warm(0), "idle clock must restart after traffic");
    }

    #[test]
    fn warms_up_on_demand_and_becomes_ready() {
        let (mut a, mut rng) = scaler(1.0);
        let mb = [500u32, 3000];
        // Go cold.
        a.step(0.0, 1.0, &[0.0, 0.0], &mb, &mut rng);
        assert!(!a.is_warm(0));
        // Demand returns -> warming with a future ready time.
        let woke = a.step(1.0, 1.0, &[10.0, 0.0], &mb, &mut rng);
        assert_eq!(woke, 1);
        assert_eq!(a.cold_starts(), &[1, 0]);
        let ready_at = match a.state(0) {
            InstanceState::Warming { ready_at } => ready_at,
            other => panic!("expected Warming, got {other:?}"),
        };
        assert!(ready_at > 1.0);
        assert!(!a.is_warm(0));
        // After the cold start elapses it serves again.
        a.step(ready_at + 0.1, 1.0, &[10.0, 0.0], &mb, &mut rng);
        assert!(a.is_warm(0));
    }

    #[test]
    fn busy_agent_never_scales_down() {
        let (mut a, mut rng) = scaler(2.0);
        let mb = [500u32, 3000];
        for t in 0..50 {
            a.step(t as f64, 1.0, &[1.0, 1.0], &mb, &mut rng);
        }
        assert!(a.is_warm(0) && a.is_warm(1));
    }

    #[test]
    fn repeated_idle_busy_cycles_count_every_wake() {
        let (mut a, mut rng) = scaler(1.0);
        let mb = [500u32, 3000];
        let mut t = 0.0;
        for _ in 0..3 {
            // Idle long enough to go cold.
            for _ in 0..2 {
                a.step(t, 1.0, &[0.0, 1.0], &mb, &mut rng);
                t += 1.0;
            }
            assert!(!a.is_warm(0));
            // Wake and wait out the cold start (coordinator ≈ 0.7 s).
            a.step(t, 1.0, &[5.0, 1.0], &mb, &mut rng);
            t += 1.0;
            a.step(t, 1.0, &[5.0, 1.0], &mb, &mut rng);
            t += 1.0;
            assert!(a.is_warm(0));
        }
        assert_eq!(a.cold_starts(), &[3, 0]);
    }
}
