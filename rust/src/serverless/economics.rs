//! The serverless-economics bundle threaded through the simulators.
//!
//! An [`EconomicsModel`] groups the three platform knobs the paper's
//! cost claims rest on — GPU pricing (Table II's cost row), the
//! scale-to-zero idle timeout (§II.B/§III.D elasticity), and the
//! cold-start latency distribution (§III.D) — into one value that
//! [`SimConfig::economics`] threads through `Simulator::run` and
//! `ClusterSimulator::run_with_arena`. When enabled, every step charges
//! each agent for its allocated fraction, idle agents scale to zero
//! after the timeout, and waking agents pay a sampled cold start; the
//! per-agent outcome comes back as an [`EconomicsReport`].
//!
//! [`SimConfig::economics`]: crate::sim::SimConfig

use crate::serverless::{Autoscaler, BillingMeter, ColdStartModel,
                        GpuPricing};
use crate::util;
use crate::util::Rng;

/// Serverless platform economics for one simulation run: pricing,
/// scale-to-zero, and cold starts, evaluated per step in the hot loop.
#[derive(Debug, Clone, PartialEq)]
pub struct EconomicsModel {
    /// Per-device pricing; each agent is billed `fraction × time` under
    /// it (this replaces the config's whole-device meter for the run).
    pub pricing: GpuPricing,
    /// Cold-start latency model sampled when a scaled-to-zero agent's
    /// instance wakes on returning demand.
    pub cold_start: ColdStartModel,
    /// Scale-to-zero idle timeout in seconds. `f64::INFINITY` holds
    /// every agent warm forever — the paper's evaluation setting.
    pub idle_timeout_s: f64,
}

impl EconomicsModel {
    /// The paper's §IV platform: T4 pricing, the representative
    /// cold-start model, and every agent held warm (infinite idle
    /// timeout) — the setting behind Table II's $0.020 / 100 s cost row.
    pub fn paper_all_warm() -> Self {
        EconomicsModel {
            pricing: GpuPricing::t4(),
            cold_start: ColdStartModel::default_platform(),
            idle_timeout_s: f64::INFINITY,
        }
    }

    /// The paper platform with a finite scale-to-zero idle timeout.
    pub fn with_idle_timeout(idle_timeout_s: f64) -> Self {
        EconomicsModel {
            idle_timeout_s,
            ..EconomicsModel::paper_all_warm()
        }
    }

    /// Whether instances can ever be torn down under this model.
    pub fn scales_to_zero(&self) -> bool {
        self.idle_timeout_s.is_finite()
    }
}

/// Per-agent economics of one run, surfaced in `SimResult` /
/// `ClusterResult` when the config enables an [`EconomicsModel`].
///
/// All three vectors are in agent-id order and the same length.
#[derive(Debug, Clone, PartialEq)]
pub struct EconomicsReport {
    /// Dollars billed per agent (allocated fraction × time; forfeited
    /// allocations of cold or migrating agents are not billed).
    pub per_agent_cost: Vec<f64>,
    /// Cold-start wake-ups per agent over the run.
    pub cold_starts: Vec<u64>,
    /// Fraction of steps each agent's *instance* was warm under the
    /// scale-to-zero lifecycle, in [0, 1] (1.0 everywhere under an
    /// all-warm model). This tracks instance warmth only: a cluster
    /// agent mid-migration is warm but still serves nothing — that
    /// stall is accounted separately (`ClusterResult::migration_stall_s`
    /// and the forfeited, unbilled allocation).
    pub warm_fraction: Vec<f64>,
}

impl EconomicsReport {
    /// Total billed dollars (sum of the per-agent bills).
    pub fn total_cost(&self) -> f64 {
        self.per_agent_cost.iter().sum()
    }

    /// Total cold-start wake-ups across agents.
    pub fn total_cold_starts(&self) -> u64 {
        self.cold_starts.iter().sum()
    }

    /// Mean warm fraction across agents.
    pub fn mean_warm_fraction(&self) -> f64 {
        util::mean(&self.warm_fraction)
    }
}

/// Per-run accumulator behind [`EconomicsReport`]: the simulation loops
/// feed it one `charge_step` per step (plus `note_warm` per servable
/// agent when a scale-to-zero lifecycle is active) and `finish` it into
/// the report.
#[derive(Debug, Clone)]
pub(crate) struct EconomicsMeter {
    pricing: GpuPricing,
    per_agent_cost: Vec<f64>,
    warm_steps: Vec<u64>,
}

impl EconomicsMeter {
    pub(crate) fn new(model: &EconomicsModel, n: usize) -> Self {
        EconomicsMeter {
            pricing: model.pricing,
            per_agent_cost: vec![0.0; n],
            warm_steps: vec![0; n],
        }
    }

    /// Charge one step: agent fractions in `alloc` held for `dt`
    /// seconds. Callers pass the post-lifecycle allocation, so forfeited
    /// fractions are never billed.
    pub(crate) fn charge_step(&mut self, alloc: &[f64], dt: f64) {
        for (cost, g) in self.per_agent_cost.iter_mut().zip(alloc) {
            *cost += self.pricing.cost(*g, dt);
        }
    }

    /// Record that `agent`'s instance could serve this step.
    pub(crate) fn note_warm(&mut self, agent: usize) {
        self.warm_steps[agent] += 1;
    }

    /// Finalize into the report. `scaler` is the run's autoscaler when a
    /// scale-to-zero lifecycle was active; without one every agent was
    /// warm for the whole run by construction.
    pub(crate) fn finish(self, steps: u64, scaler: Option<&Autoscaler>)
                         -> EconomicsReport {
        let n = self.per_agent_cost.len();
        let warm_fraction = match scaler {
            None => vec![1.0; n],
            Some(_) if steps == 0 => vec![1.0; n],
            Some(_) => self.warm_steps.iter()
                .map(|w| *w as f64 / steps as f64)
                .collect(),
        };
        let cold_starts = match scaler {
            None => vec![0; n],
            Some(s) => s.cold_starts().to_vec(),
        };
        EconomicsReport {
            per_agent_cost: self.per_agent_cost,
            cold_starts,
            warm_fraction,
        }
    }
}

/// The complete per-run economics instrumentation, shared by
/// `Simulator::run_inner` and `ClusterSimulator::run_with_arena` so the
/// two engines cannot drift apart: the billing meter (model pricing
/// overriding the config fallback), the optional per-agent
/// [`EconomicsMeter`], and the optional scale-to-zero lifecycle
/// (autoscaler + its dedicated jitter RNG, seeded `seed ^ 0xC01D`).
#[derive(Debug)]
pub(crate) struct EconInstruments {
    billing: BillingMeter,
    meter: Option<EconomicsMeter>,
    lifecycle: Option<(Autoscaler, Rng)>,
}

impl EconInstruments {
    /// Build for one run of `n` agents. `fallback_pricing` (the
    /// config's whole-device pricing) bills the run when `economics` is
    /// `None`; the lifecycle exists only for a finite idle timeout.
    pub(crate) fn new(economics: Option<&EconomicsModel>,
                      fallback_pricing: GpuPricing, n: usize, seed: u64)
                      -> Self {
        EconInstruments {
            billing: BillingMeter::new(
                economics.map_or(fallback_pricing, |e| e.pricing)),
            meter: economics.map(|e| EconomicsMeter::new(e, n)),
            lifecycle: economics
                .filter(|e| e.scales_to_zero())
                .map(|e| {
                    (Autoscaler::all_warm(n, e.cold_start.clone(),
                                          e.idle_timeout_s),
                     Rng::new(seed ^ 0xC01D))
                }),
        }
    }

    /// Advance the scale-to-zero lifecycle one step (`now = step · dt`):
    /// agents whose instance cannot serve forfeit their allocation
    /// (zeroed in `alloc`, hence never billed), warm agents are counted
    /// toward their warm fraction. No-op without a lifecycle.
    pub(crate) fn apply_lifecycle(&mut self, step: u64, dt: f64,
                                  queues: &[f64], model_mb: &[u32],
                                  alloc: &mut [f64]) {
        let Some((scaler, rng)) = self.lifecycle.as_mut() else {
            return;
        };
        let now = step as f64 * dt;
        scaler.step(now, dt, queues, model_mb, rng);
        for (i, g) in alloc.iter_mut().enumerate() {
            if !scaler.is_warm(i) {
                *g = 0.0;
            } else if let Some(m) = self.meter.as_mut() {
                m.note_warm(i);
            }
        }
    }

    /// Skip-idle contract: `true` when a zero-demand, zero-allocation
    /// step is a bit-exact no-op on every instrument. Billing and the
    /// per-agent meter always are (`+= 0.0` charges); the lifecycle is
    /// only when there is none, or when every instance is already Cold —
    /// the absorbing state where `Autoscaler::step` touches neither
    /// state nor RNG. A *warm* idle instance accrues `idle_for` (it is
    /// counting down toward teardown), so it must be stepped densely.
    pub(crate) fn idle_fixed_point(&self) -> bool {
        self.lifecycle.as_ref()
            .map_or(true, |(scaler, _)| scaler.all_cold())
    }

    /// Bill this step's post-forfeiture allocation: the whole-device
    /// total plus, when economics is on, the per-agent breakdown.
    pub(crate) fn charge_step(&mut self, total_alloc: f64, alloc: &[f64],
                              dt: f64) {
        self.billing.charge(total_alloc, dt);
        if let Some(m) = self.meter.as_mut() {
            m.charge_step(alloc, dt);
        }
    }

    /// Finalize: `(total cost, GPU-seconds, economics report)`.
    pub(crate) fn finish(self, steps: u64)
                         -> (f64, f64, Option<EconomicsReport>) {
        let report = self.meter.map(|m| m.finish(
            steps, self.lifecycle.as_ref().map(|(scaler, _)| scaler)));
        (self.billing.total_cost(), self.billing.gpu_seconds(), report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn paper_model_is_all_warm_t4() {
        let m = EconomicsModel::paper_all_warm();
        assert!(!m.scales_to_zero());
        assert_eq!(m.pricing, GpuPricing::t4());
        // Full GPU for 100 s under the paper model = Table II's $0.020.
        assert!((m.pricing.cost(1.0, 100.0) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn finite_timeout_scales_to_zero() {
        let m = EconomicsModel::with_idle_timeout(30.0);
        assert!(m.scales_to_zero());
        assert_eq!(m.idle_timeout_s, 30.0);
        assert_eq!(m.pricing, GpuPricing::t4());
    }

    #[test]
    fn meter_bills_per_agent_and_sums_to_total() {
        let model = EconomicsModel::paper_all_warm();
        let mut meter = EconomicsMeter::new(&model, 2);
        for _ in 0..100 {
            meter.charge_step(&[0.75, 0.25], 1.0);
        }
        let report = meter.finish(100, None);
        assert!((report.total_cost() - 0.02).abs() < 1e-12);
        assert!((report.per_agent_cost[0] - 0.015).abs() < 1e-12);
        assert!((report.per_agent_cost[1] - 0.005).abs() < 1e-12);
        assert_eq!(report.cold_starts, vec![0, 0]);
        assert_eq!(report.warm_fraction, vec![1.0, 1.0]);
        assert_eq!(report.mean_warm_fraction(), 1.0);
        assert_eq!(report.total_cold_starts(), 0);
    }

    #[test]
    fn idle_fixed_point_tracks_lifecycle_state() {
        // No economics at all / all-warm economics: no lifecycle → the
        // instruments are pure accumulators, always skippable at zero
        // allocation.
        let none = EconInstruments::new(None, GpuPricing::t4(), 2, 7);
        assert!(none.idle_fixed_point());
        let all_warm = EconomicsModel::paper_all_warm();
        let warm = EconInstruments::new(Some(&all_warm), GpuPricing::t4(),
                                        2, 7);
        assert!(warm.idle_fixed_point());

        // Finite timeout: warm instances are counting toward teardown,
        // so the window must be stepped densely until everyone is cold.
        let model = EconomicsModel::with_idle_timeout(1.0);
        let mut econ = EconInstruments::new(Some(&model), GpuPricing::t4(),
                                            2, 7);
        assert!(!econ.idle_fixed_point());
        let mb = [500u32, 500];
        let mut alloc = [0.0, 0.0];
        for step in 0..2 {
            econ.apply_lifecycle(step, 1.0, &[0.0, 0.0], &mb, &mut alloc);
        }
        // Both instances torn down → Cold is absorbing at zero demand.
        assert!(econ.idle_fixed_point());
        // And the absorbing state really is a bit-no-op: further idle
        // steps change nothing observable.
        let (scaler_before, _) = econ.lifecycle.as_ref().unwrap();
        let states: Vec<_> =
            (0..2).map(|i| scaler_before.state(i)).collect();
        for step in 2..10 {
            econ.apply_lifecycle(step, 1.0, &[0.0, 0.0], &mb, &mut alloc);
        }
        let (scaler_after, _) = econ.lifecycle.as_ref().unwrap();
        for (i, s) in states.iter().enumerate() {
            assert_eq!(*s, scaler_after.state(i));
        }
        assert_eq!(scaler_after.cold_starts(), &[0, 0]);
    }

    #[test]
    fn finish_reads_warmth_and_cold_starts_from_the_scaler() {
        let model = EconomicsModel::with_idle_timeout(1.0);
        let mut meter = EconomicsMeter::new(&model, 2);
        let mut scaler = Autoscaler::all_warm(
            2, model.cold_start.clone(), model.idle_timeout_s);
        let mut rng = Rng::new(3);
        let mb = [500u32, 500];
        // Agent 0 idles cold, then wakes; agent 1 stays busy throughout.
        for t in 0..4u64 {
            let demand = if t < 2 { [0.0, 5.0] } else { [5.0, 5.0] };
            scaler.step(t as f64, 1.0, &demand, &mb, &mut rng);
            for i in 0..2 {
                if scaler.is_warm(i) {
                    meter.note_warm(i);
                }
            }
        }
        let report = meter.finish(4, Some(&scaler));
        assert_eq!(report.cold_starts, vec![1, 0]);
        assert!(report.warm_fraction[0] < 1.0);
        assert_eq!(report.warm_fraction[1], 1.0);
    }
}
