//! Pay-per-use GPU billing.
//!
//! The paper models an NVIDIA T4 at $0.72/hour with fractional allocation
//! billed by GPU-fraction × time (all three policies allocate the full GPU
//! and therefore cost exactly $0.020 per 100 s — Table II's cost row).

/// Hourly pricing for one GPU class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuPricing {
    /// Dollars per GPU-hour for the whole device.
    pub dollars_per_hour: f64,
    /// Smallest billable time quantum in seconds (serverless platforms
    /// bill per 100 ms or finer; the paper's numbers imply continuous).
    pub billing_quantum_s: f64,
}

impl GpuPricing {
    /// The paper's platform: NVIDIA T4, 16 GB, $0.72/hour (§IV.A).
    pub fn t4() -> Self {
        GpuPricing { dollars_per_hour: 0.72, billing_quantum_s: 0.0 }
    }

    /// Cost of running `fraction` of the GPU for `seconds`.
    pub fn cost(&self, fraction: f64, seconds: f64) -> f64 {
        let billed = if self.billing_quantum_s > 0.0 {
            (seconds / self.billing_quantum_s).ceil() * self.billing_quantum_s
        } else {
            seconds
        };
        self.dollars_per_hour / 3600.0 * fraction.max(0.0) * billed.max(0.0)
    }
}

/// Accumulates cost over a run.
#[derive(Debug, Clone)]
pub struct BillingMeter {
    pricing: GpuPricing,
    total: f64,
    gpu_seconds: f64,
}

impl BillingMeter {
    /// New meter over the given pricing.
    pub fn new(pricing: GpuPricing) -> Self {
        BillingMeter { pricing, total: 0.0, gpu_seconds: 0.0 }
    }

    /// Charge one interval: total allocated `fraction` for `seconds`.
    pub fn charge(&mut self, fraction: f64, seconds: f64) {
        self.total += self.pricing.cost(fraction, seconds);
        self.gpu_seconds += fraction.max(0.0) * seconds.max(0.0);
    }

    /// Accumulated dollars.
    pub fn total_cost(&self) -> f64 {
        self.total
    }

    /// Accumulated GPU-seconds (fraction-weighted).
    pub fn gpu_seconds(&self) -> f64 {
        self.gpu_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cost_is_two_cents_per_100s() {
        // Full GPU for 100 s at T4 pricing = $0.02 — Table II's cost row.
        let mut m = BillingMeter::new(GpuPricing::t4());
        for _ in 0..100 {
            m.charge(1.0, 1.0);
        }
        assert!((m.total_cost() - 0.02).abs() < 1e-12,
                "cost={}", m.total_cost());
        assert!((m.gpu_seconds() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn fractional_billing_scales_linearly() {
        let p = GpuPricing::t4();
        assert!((p.cost(0.5, 3600.0) - 0.36).abs() < 1e-12);
        assert_eq!(p.cost(-1.0, 10.0), 0.0);
        assert_eq!(p.cost(1.0, -10.0), 0.0);
    }

    #[test]
    fn quantum_rounds_up() {
        let p = GpuPricing { dollars_per_hour: 3600.0,
                             billing_quantum_s: 0.1 };
        // 0.25 s bills as 0.3 s at $1/s.
        assert!((p.cost(1.0, 0.25) - 0.3).abs() < 1e-9);
    }

    #[test]
    fn quantum_exact_multiples_bill_exactly() {
        // ceil() on an exact multiple must not add a phantom quantum.
        let p = GpuPricing { dollars_per_hour: 3600.0,
                             billing_quantum_s: 0.1 };
        assert!((p.cost(1.0, 0.3) - 0.3).abs() < 1e-9);
        assert!((p.cost(1.0, 10.0) - 10.0).abs() < 1e-9);
        // One quantum exactly.
        assert!((p.cost(1.0, 0.1) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn quantum_sub_quantum_runs_bill_one_full_quantum() {
        let p = GpuPricing { dollars_per_hour: 3600.0,
                             billing_quantum_s: 60.0 };
        // A 1 s invocation on per-minute billing pays the full minute,
        // scaled by the allocated fraction.
        assert!((p.cost(1.0, 1.0) - 60.0).abs() < 1e-9);
        assert!((p.cost(0.5, 1.0) - 30.0).abs() < 1e-9);
        // Even an infinitesimal run rounds up to a whole quantum.
        assert!((p.cost(1.0, 1e-9) - 60.0).abs() < 1e-9);
    }

    #[test]
    fn quantum_zero_and_negative_inputs_bill_nothing() {
        let p = GpuPricing { dollars_per_hour: 3600.0,
                             billing_quantum_s: 0.1 };
        // ceil(0 / q) = 0: a zero-length run is free, not one quantum.
        assert_eq!(p.cost(1.0, 0.0), 0.0);
        assert_eq!(p.cost(0.0, 10.0), 0.0);
        // Negative inputs clamp to zero rather than producing refunds:
        // ceil(-2.5) = -2 quanta would otherwise bill -0.2 s.
        assert_eq!(p.cost(1.0, -0.25), 0.0);
        assert_eq!(p.cost(-0.5, -0.25), 0.0);
        assert_eq!(p.cost(-1.0, 5.0), 0.0);
    }
}
