//! Container/model cold-start model.
//!
//! Serverless platforms spin agent containers up and down; a cold start
//! costs a model-size-dependent load time (checkpoint loading, §III.D).
//! The paper's evaluation pre-loads all models (sub-second platform cold
//! starts are cited in §I), so the paper-mode simulator keeps instances
//! warm; the serving stack and the ablation benches exercise the full
//! warm/cold lifecycle.

use crate::util::Rng;

/// Lifecycle state of one agent's container instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InstanceState {
    /// No instance provisioned (scale-to-zero).
    Cold,
    /// Instance starting; ready at the stored step-time (seconds).
    Warming { ready_at: f64 },
    /// Instance serving.
    Warm,
}

/// Cold-start latency model: base platform delay plus model-load time
/// proportional to checkpoint size, with multiplicative jitter.
#[derive(Debug, Clone, PartialEq)]
pub struct ColdStartModel {
    /// Fixed platform provisioning delay (seconds).
    pub base_s: f64,
    /// Seconds per megabyte of model checkpoint (PCIe/NVMe load rate).
    pub s_per_mb: f64,
    /// Jitter amplitude (0.1 = ±10 %).
    pub jitter: f64,
}

impl ColdStartModel {
    /// Representative serverless GPU platform (§I cites sub-second platform
    /// cold starts; checkpoint loading dominates for multi-GB models):
    /// 200 ms base + 1 GB/s effective load rate.
    pub fn default_platform() -> Self {
        ColdStartModel { base_s: 0.2, s_per_mb: 0.001, jitter: 0.1 }
    }

    /// Sample a cold-start duration for a model of `model_mb` megabytes.
    pub fn sample(&self, model_mb: u32, rng: &mut Rng) -> f64 {
        let nominal = self.base_s + self.s_per_mb * model_mb as f64;
        let j = 1.0 + self.jitter * (2.0 * rng.uniform() - 1.0);
        (nominal * j).max(0.0)
    }

    /// Deterministic nominal duration (no jitter) — used by tests and by
    /// capacity planning in the autoscaler.
    pub fn nominal(&self, model_mb: u32) -> f64 {
        self.base_s + self.s_per_mb * model_mb as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_models_start_slower() {
        let m = ColdStartModel::default_platform();
        assert!(m.nominal(3000) > m.nominal(500));
        // 3 GB model ≈ 0.2 + 3.0 = 3.2 s.
        assert!((m.nominal(3000) - 3.2).abs() < 1e-12);
    }

    #[test]
    fn jitter_bounded() {
        let m = ColdStartModel::default_platform();
        let mut rng = Rng::new(5);
        let nominal = m.nominal(2000);
        for _ in 0..1000 {
            let s = m.sample(2000, &mut rng);
            assert!(s >= nominal * 0.899 && s <= nominal * 1.101,
                    "s={s} nominal={nominal}");
        }
    }

    #[test]
    fn state_transitions_are_plain_data() {
        let s = InstanceState::Warming { ready_at: 3.5 };
        assert_ne!(s, InstanceState::Warm);
        assert_eq!(InstanceState::Cold, InstanceState::Cold);
    }
}
