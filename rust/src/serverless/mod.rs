//! Serverless GPU platform model (§III.D / §IV.A substrate).
//!
//! Models the platform characteristics the paper assumes: fine-grained
//! fractional GPU billing ([`billing`]), container cold starts
//! ([`coldstart`]), and scale-to-zero autoscaling ([`autoscale`]). The
//! simulator and the serving stack both consume these, so cost numbers and
//! cold-start penalties are computed identically everywhere.

mod autoscale;
mod billing;
mod coldstart;

pub use autoscale::{AutoscaleDecision, Autoscaler};
pub use billing::{BillingMeter, GpuPricing};
pub use coldstart::{ColdStartModel, InstanceState};
