//! Serverless GPU platform model (§III.D / §IV.A substrate).
//!
//! Models the platform characteristics the paper assumes: fine-grained
//! fractional GPU billing ([`BillingMeter`]), container cold starts
//! ([`ColdStartModel`]), scale-to-zero autoscaling ([`Autoscaler`]), and
//! the economics bundle that threads all three through the simulation hot
//! loops as one optional [`EconomicsModel`]. The simulator and the serving
//! stack both consume these, so cost numbers and cold-start penalties are
//! computed identically everywhere.

mod autoscale;
mod billing;
mod coldstart;
mod economics;

pub use autoscale::Autoscaler;
pub use billing::{BillingMeter, GpuPricing};
pub use coldstart::{ColdStartModel, InstanceState};
pub use economics::{EconomicsModel, EconomicsReport};

pub(crate) use economics::EconInstruments;
