//! agentsrv CLI — the launcher.
//!
//! ```text
//! agentsrv simulate [--config f.json] [--policy p] [--steps N]
//!                   [--poisson] [--seed N] [--timelines out.csv]
//! agentsrv repro    [--out DIR] [--exp ID]      regenerate tables/figures
//!                                               (incl. --exp serving: the
//!                                               queue-granularity contrast;
//!                                               --exp placement: strategy x
//!                                               rebalancer comparison;
//!                                               --exp workflow: DAG
//!                                               end-to-end latency)
//! agentsrv serve    [--artifacts DIR] [--policy p] [--requests N]
//!                   [--workflows N]             end-to-end PJRT serving
//! agentsrv trace convert --in PATH [--out PATH] CSV <-> binary (.atrb)
//!                                               trace conversion; a
//!                                               directory converts the
//!                                               whole corpus
//! agentsrv verify   [--artifacts DIR]           golden-vector check
//! agentsrv config   [--out FILE]                dump the paper config
//! agentsrv bench-gate --measured FILE [--baseline FILE]
//!                   [--tolerance F] [--bootstrap]
//!                                               bench-regression gate
//! ```
//!
//! Arg parsing is hand-rolled (the image is offline; no clap).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use agentsrv::agents::AgentProfile;
use agentsrv::allocator::policy_by_name;
use agentsrv::config::DeploymentConfig;
use agentsrv::coordinator::{ReasoningPipeline, TaskKind};
use agentsrv::error::{Error, Result};
use agentsrv::metrics::export;
use agentsrv::repro;
use agentsrv::runtime::{InferenceEngine, Manifest};
use agentsrv::server::{AgentServer, ServerConfig};
use agentsrv::sim::Simulator;
use agentsrv::util::bench::compare_bench_reports;
use agentsrv::util::json::Value;
use agentsrv::util::Rng;
use agentsrv::workload::bintrace::{save_trace, BinTrace};
use agentsrv::workload::trace::Trace;
use agentsrv::workload::ArrivalProcess;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    // `trace` carries a subcommand word before its options; every other
    // command parses the remaining args as options directly.
    let result = if cmd == "trace" {
        cmd_trace(rest)
    } else {
        let opts = match Opts::parse(rest) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("error: {e}\n{USAGE}");
                return ExitCode::FAILURE;
            }
        };
        match cmd.as_str() {
            "simulate" => cmd_simulate(&opts),
            "repro" => cmd_repro(&opts),
            "serve" => cmd_serve(&opts),
            "verify" => cmd_verify(&opts),
            "config" => cmd_config(&opts),
            "bench-gate" => cmd_bench_gate(&opts),
            "help" | "--help" | "-h" => {
                println!("{USAGE}");
                Ok(())
            }
            other => Err(Error::Config(format!(
                "unknown command '{other}'"))),
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
agentsrv — adaptive GPU allocation for multi-agent serving

USAGE:
  agentsrv simulate [--config FILE] [--policy NAME] [--steps N]
                    [--poisson] [--seed N] [--timelines FILE.csv]
  agentsrv repro    [--out DIR] [--exp table1|table2|fig2a|fig2b|fig2c|
                                       fig2d|overload|spike|dominance|
                                       scaling|economics|serving|
                                       placement|faults|workflow|replay|
                                       all]
  agentsrv serve    [--artifacts DIR] [--policy NAME] [--requests N]
                    [--workflows N] [--seed N]
  agentsrv trace convert --in PATH [--out PATH]
                    (CSV <-> binary .atrb by extension; a directory
                     converts every trace in the corpus)
  agentsrv verify   [--artifacts DIR]
  agentsrv config   [--out FILE]
  agentsrv bench-gate --measured FILE [--baseline FILE=BENCH_sweep.json]
                    [--tolerance FRACTION=0.25] [--bootstrap]

POLICIES: adaptive (paper Alg. 1) | static_equal | round_robin |
          predictive | feedback | critical_path";

/// Parsed `--key value` / `--flag` options.
struct Opts {
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Opts> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                return Err(Error::Config(format!(
                    "unexpected argument '{a}'")));
            };
            // Flags that take no value.
            if matches!(key, "poisson" | "quick" | "bootstrap") {
                flags.push(key.to_string());
                i += 1;
                continue;
            }
            let Some(value) = args.get(i + 1) else {
                return Err(Error::Config(format!(
                    "--{key} requires a value")));
            };
            values.insert(key.to_string(), value.clone());
            i += 2;
        }
        Ok(Opts { values, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| Error::Config(format!(
                "--{key} must be an integer, got '{v}'"))),
        }
    }
}

fn print_table2_style(rows: &[agentsrv::sim::SummaryRow]) {
    println!("{:<14} {:>14} {:>17} {:>10} {:>16}", "policy",
             "avg latency(s)", "total tput(rps)", "cost($)",
             "latency std(s)");
    for r in rows {
        println!("{:<14} {:>14.1} {:>17.1} {:>10.3} {:>16.1}",
                 r.policy, r.avg_latency_s, r.total_throughput_rps,
                 r.cost_dollars, r.latency_std_s);
    }
}

fn cmd_simulate(opts: &Opts) -> Result<()> {
    let deployment = match opts.get("config") {
        Some(path) => DeploymentConfig::load(&PathBuf::from(path))?,
        None => DeploymentConfig::paper(),
    };
    let mut cfg = deployment.sim_config()?;
    cfg.steps = opts.u64_or("steps", cfg.steps)?;
    cfg.seed = opts.u64_or("seed", cfg.seed)?;
    if opts.flag("poisson") {
        cfg.arrival_process = ArrivalProcess::Poisson;
    }
    let timelines_out = opts.get("timelines").map(PathBuf::from);
    cfg.record_timelines = timelines_out.is_some();

    let policy_name = opts.get("policy").unwrap_or(&deployment.policy);
    let mut policy = policy_by_name(policy_name).ok_or_else(
        || Error::Config(format!("unknown policy '{policy_name}'")))?;

    let sim = Simulator::new(cfg, deployment.profiles()?);
    let result = sim.run(policy.as_mut());

    println!("policy: {}   steps: {}   dt: {}s", result.policy,
             result.steps, result.dt);
    println!("{:<14} {:>12} {:>12} {:>12} {:>12} {:>12}", "agent",
             "latency(s)", "tput(rps)", "queue", "alloc", "util");
    for a in &result.per_agent {
        println!("{:<14} {:>12.1} {:>12.1} {:>12.0} {:>12.3} {:>12.2}",
                 a.name, a.latency.mean(), a.throughput.mean(),
                 a.queue.mean(), a.allocation.mean(),
                 a.utilization.mean());
    }
    println!("\nmean latency  : {:>10.1} s", result.mean_latency());
    println!("total tput    : {:>10.1} rps", result.total_throughput());
    println!("cost          : {:>10.4} $", result.cost_dollars);
    println!("latency std   : {:>10.1} s", result.latency_std());

    if let (Some(path), Some(tl)) = (timelines_out, &result.timelines) {
        export::timeseries_csv(&tl.allocation, &path)?;
        println!("allocation timeline -> {}", path.display());
    }
    Ok(())
}

fn cmd_repro(opts: &Opts) -> Result<()> {
    let out = PathBuf::from(opts.get("out").unwrap_or("results"));
    let exp = opts.get("exp").unwrap_or("all");
    std::fs::create_dir_all(&out)?;
    match exp {
        "all" => {
            repro::write_all(&out)?;
            println!("Table II (reproduced):");
            print_table2_style(&repro::table2());
            println!("\nall experiment CSVs -> {}/", out.display());
        }
        "table1" => {
            for (name, vals) in repro::table1() {
                println!("{name:<14} {vals:?}");
            }
        }
        "table2" => print_table2_style(&repro::table2()),
        "fig2a" => {
            for s in repro::fig2a() {
                println!("{:<14} {:?}", s.policy, s.values);
            }
        }
        "fig2b" => {
            for s in repro::fig2b() {
                println!("{:<14} {:?}", s.policy, s.values);
            }
        }
        "fig2c" => {
            let ts = repro::fig2c();
            let path = out.join("fig2c_allocation.csv");
            export::timeseries_csv(&ts, &path)?;
            println!("allocation timeline -> {}", path.display());
        }
        "fig2d" => {
            for p in repro::fig2d() {
                println!("{:<14} latency {:>7.1}s tput {:>5.1}rps \
                          cost ${:.3}",
                         p.policy, p.avg_latency_s,
                         p.total_throughput_rps, p.cost_dollars);
            }
        }
        "overload" => {
            let r = repro::overload_experiment(3.0);
            println!("{r:#?}");
        }
        "spike" => {
            let r = repro::spike_experiment();
            println!("{r:#?}");
        }
        "dominance" => {
            let r = repro::dominance_experiment(0.9);
            println!("{r:#?}");
        }
        "scaling" => {
            for p in repro::scaling_experiment(&[4, 16, 64, 256, 1024,
                                                 4096]) {
                println!("N={:<6} {:>12.0} ns/allocation",
                         p.n_agents, p.ns_per_call);
            }
        }
        "economics" => {
            println!("{:<14} {:>10} {:>10} {:>9} {:>8} {:>6} {:>6}",
                     "policy", "paper($)", "burst($)", "s2z($)",
                     "saved%", "wakes", "warm");
            for r in repro::economics_experiment(100) {
                println!("{:<14} {:>10.4} {:>10.4} {:>9.4} {:>8.1} \
                          {:>6} {:>6.2}",
                         r.policy, r.paper_warm_cost, r.burst_warm_cost,
                         r.burst_s2z_cost, r.savings_pct, r.cold_starts,
                         r.mean_warm_fraction);
            }
        }
        "serving" => {
            println!("{:<14} {:>11} {:>13} {:>11} {:>11} {:>9}",
                     "policy", "fluid(s)", "serving(s)", "p99(s)",
                     "mean batch", "windows");
            for r in repro::serving_experiment(100.0) {
                println!("{:<14} {:>11.1} {:>13.1} {:>11.1} {:>11.2} \
                          {:>9}",
                         r.policy, r.fluid_mean_latency_s,
                         r.serving_mean_latency_s, r.serving_p99_s,
                         r.serving_mean_batch, r.serving_windows);
            }
            println!("\n(fluid = §IV.B backlog estimator; serving = \
                      per-request sojourn through the queue path the \
                      threaded server shares via ServingCore)");
        }
        "placement" => {
            println!("{:<10} {:>8} {:>12} {:>11} {:>10} {:>5} {:>9} \
                      {:>7}",
                     "strategy", "rebal", "mean lat(s)", "hi-pri(s)",
                     "tput(rps)", "migs", "stall(s)", "spread");
            for r in repro::placement_experiment(100) {
                println!("{:<10} {:>8} {:>12.1} {:>11.1} {:>10.1} {:>5} \
                          {:>9.2} {:>7.2}",
                         r.strategy, r.rebalancer, r.mean_latency_s,
                         r.high_priority_latency_s,
                         r.total_throughput_rps, r.migrations,
                         r.migration_stall_s, r.gpu_util_spread);
            }
            println!("\n(the placement strategy fixes where agents live \
                      at construction; the rebalancer decides who moves \
                      under live imbalance — priority-spread keeps the \
                      High-priority agent on the least-contended device, \
                      which is the hi-pri latency column)");
        }
        "faults" => {
            println!("{:<22} {:>10} {:>9} {:>11} {:>7} {:>8} {:>9}",
                     "cell", "tput(rps)", "hi-pri", "degraded(s)",
                     "shed%", "retried", "disrupt");
            for r in repro::fault_experiment(100) {
                println!("{:<22} {:>10.1} {:>9.1} {:>11.1} {:>7.1} \
                          {:>8} {:>9.2}",
                         r.label, r.goodput_rps,
                         r.high_priority_goodput_rps, r.recovery_time_s,
                         r.shed_fraction * 100.0, r.retried,
                         r.disruption);
            }
            println!("\n(single/* rows share one 60% capacity drop; \
                      cluster/* rows share one spot eviction — repack \
                      recovers under the move throttle where static \
                      forfeits the outage; serving/* rows shed under \
                      bounded queues)");
        }
        "workflow" => {
            println!("{:<14} {:>8} {:>10} {:>10} {:>10}",
                     "policy", "started", "completed", "mean(s)",
                     "p99(s)");
            for r in repro::workflow_experiment(100) {
                println!("{:<14} {:>8} {:>10} {:>10.1} {:>10.1}",
                         r.policy, r.started, r.completed, r.mean_s,
                         r.p99_s);
            }
            println!("\n(end-to-end workflow latency: release of the \
                      plan stage to completion of the aggregate stage \
                      over the paper fan-out DAG — the critical-path \
                      policy front-loads the stages the DAG serializes \
                      on, where round_robin stalls every level until \
                      its agent's turn)");
        }
        "replay" => {
            println!("{:<22} {:>9} {:>11} {:>10} {:>9} {:>9} {:>5}",
                     "cell", "recorded", "bytes", "completed",
                     "mean(s)", "p99(s)", "bit=");
            for r in repro::replay_experiment(10.0, &[42, 43]) {
                println!("{:<22} {:>9} {:>11} {:>10} {:>9.2} {:>9.2} \
                          {:>5}",
                         format!("{}/seed{}", r.policy, r.seed),
                         r.recorded_requests, r.trace_bytes,
                         r.replay_completed, r.replay_mean_latency_s,
                         r.replay_p99_s,
                         if r.bit_identical { "yes" } else { "NO" });
            }
            println!("\n(each live serving run records its accepted \
                      queue timeline, dumps it as a burst-encoded \
                      binary trace, and replays the dump — `bit=` is \
                      whether the replay reproduced the live run \
                      exactly, the closure property the .atrb format \
                      stores absolute timestamps for)");
        }
        other => return Err(Error::Config(format!(
            "unknown experiment '{other}'"))),
    }
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<()> {
    let dir = PathBuf::from(opts.get("artifacts").unwrap_or("artifacts"));
    let policy = opts.get("policy").unwrap_or("adaptive").to_string();
    let n_requests = opts.u64_or("requests", 64)?;
    let n_workflows = opts.u64_or("workflows", 8)?;
    let seed = opts.u64_or("seed", 42)?;

    let manifest = Manifest::load(&dir)?;
    let vocabs: Vec<(String, usize)> = manifest.agents.iter()
        .map(|a| (a.name.clone(), a.vocab)).collect();
    let seq = manifest.seq_len;

    println!("starting server (policy: {policy}) ...");
    let mut cfg = ServerConfig::new(&dir);
    cfg.policy = policy;
    let server = AgentServer::start(cfg)?;

    // Direct per-agent load, weighted like the paper's arrival mix.
    let mut rng = Rng::new(seed);
    let rates = AgentProfile::paper_arrival_rates();
    let total_rate: f64 = rates.iter().sum();
    let names: Vec<String> =
        vocabs.iter().map(|(n, _)| n.clone()).collect();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        // Sample an agent proportional to the paper's rates.
        let mut pick = rng.uniform() * total_rate;
        let mut agent = 0usize;
        for (j, r) in rates.iter().enumerate() {
            if pick < *r {
                agent = j;
                break;
            }
            pick -= r;
        }
        let vocab = vocabs[agent].1;
        let tokens: Vec<i32> = (0..seq)
            .map(|k| ((i * 31 + k as u64 * 7 + 3) % vocab as u64) as i32)
            .collect();
        pending.push(server.submit(&names[agent], tokens)?);
    }
    let mut completed = 0u64;
    for rx in pending {
        rx.recv().map_err(|_| Error::Serving(
            "request dropped".into()))??;
        completed += 1;
    }
    println!("direct requests completed: {completed}");

    // Collaborative workflows.
    let pipeline = ReasoningPipeline::new(&server, vocabs);
    for i in 0..n_workflows {
        let kind = TaskKind::sample(&mut rng);
        let wf = pipeline.run(&server, kind, i)?;
        println!("workflow {i:>3} {:<12} stages {} answer {:>4} \
                  total {:>8.2?}",
                 format!("{:?}", wf.kind), wf.stages.len(), wf.answer(),
                 wf.total);
    }

    let stats = server.shutdown();
    println!("\n{:<14} {:>9} {:>12} {:>12} {:>10} {:>10}", "agent",
             "completed", "p50", "p99", "mean batch", "gpu share");
    for a in &stats.per_agent {
        println!("{:<14} {:>9} {:>12} {:>12} {:>10.2} {:>10.3}",
                 a.name, a.completed,
                 format!("{:.2}ms", a.p50_s * 1e3),
                 format!("{:.2}ms", a.p99_s * 1e3),
                 a.mean_batch, a.gpu_share);
    }
    println!("\ntotal completed: {}   errors: {}   gpu busy: {:.2}s",
             stats.total_completed, stats.total_errors,
             stats.gpu_busy_seconds);
    println!("last allocation: {:?}",
             stats.last_allocation.iter().map(|g| (g * 1e3).round() / 1e3)
                 .collect::<Vec<_>>());
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let Some((sub, rest)) = args.split_first() else {
        return Err(Error::Config(
            "trace requires a subcommand (convert)".into()));
    };
    let opts = Opts::parse(rest)?;
    match sub.as_str() {
        "convert" => cmd_trace_convert(&opts),
        other => Err(Error::Config(format!(
            "unknown trace subcommand '{other}'"))),
    }
}

/// The destination a trace converts to: `.csv` becomes `.atrb` and
/// vice versa. Direction is sniffed from the extension alone.
fn converted_path(src: &Path) -> Result<PathBuf> {
    match src.extension().and_then(|e| e.to_str()) {
        Some("csv") => Ok(src.with_extension("atrb")),
        Some("atrb") => Ok(src.with_extension("csv")),
        _ => Err(Error::Trace(format!(
            "{}: unknown trace extension (expected .csv or .atrb)",
            src.display()))),
    }
}

fn convert_one(src: &Path, dst: &Path) -> Result<()> {
    match src.extension().and_then(|e| e.to_str()) {
        Some("csv") => save_trace(&Trace::load(src)?, dst)?,
        Some("atrb") => BinTrace::open(src)?.to_trace()?.save(dst)?,
        _ => return Err(Error::Trace(format!(
            "{}: unknown trace extension (expected .csv or .atrb)",
            src.display()))),
    }
    println!("{} -> {} ({} bytes)", src.display(), dst.display(),
             std::fs::metadata(dst)?.len());
    Ok(())
}

fn cmd_trace_convert(opts: &Opts) -> Result<()> {
    let input = PathBuf::from(opts.get("in").ok_or_else(|| Error::Config(
        "--in PATH required (a .csv/.atrb trace, or a directory of \
         them)".into()))?);
    if input.is_dir() {
        // Corpus-wide: every trace in the directory converts to its
        // opposite format, into --out (or alongside the originals).
        let out_dir = match opts.get("out") {
            Some(o) => PathBuf::from(o),
            None => input.clone(),
        };
        std::fs::create_dir_all(&out_dir)?;
        let mut sources: Vec<PathBuf> = std::fs::read_dir(&input)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| matches!(p.extension().and_then(|e| e.to_str()),
                                 Some("csv" | "atrb")))
            .collect();
        sources.sort();
        if sources.is_empty() {
            return Err(Error::Trace(format!(
                "no .csv or .atrb traces in {}", input.display())));
        }
        for src in &sources {
            let name = converted_path(src)?;
            let name = name.file_name().ok_or_else(|| Error::Trace(
                format!("{}: no file name", src.display())))?;
            convert_one(src, &out_dir.join(name))?;
        }
        println!("{} trace(s) converted -> {}", sources.len(),
                 out_dir.display());
        Ok(())
    } else {
        let dst = match opts.get("out") {
            Some(o) => PathBuf::from(o),
            None => converted_path(&input)?,
        };
        convert_one(&input, &dst)
    }
}

fn cmd_verify(opts: &Opts) -> Result<()> {
    let dir = PathBuf::from(opts.get("artifacts").unwrap_or("artifacts"));
    let mut engine = InferenceEngine::load(&dir)?;
    println!("platform: {}", engine.platform());
    let verified = engine.verify_golden()?;
    for (agent, batch) in &verified {
        println!("golden OK: {agent} b{batch}");
    }
    println!("{} (agent, batch) variants verified bit-exact against JAX",
             verified.len());
    Ok(())
}

fn cmd_bench_gate(opts: &Opts) -> Result<()> {
    let baseline_path = opts.get("baseline").unwrap_or("BENCH_sweep.json");
    let measured_path = opts.get("measured").ok_or_else(|| Error::Config(
        "--measured FILE required (a `sweep_scaling -- --json` report)"
            .into()))?;
    let tolerance: f64 = match opts.get("tolerance") {
        None => 0.25,
        Some(v) => v.parse().map_err(|_| Error::Config(format!(
            "--tolerance must be a fraction in [0, 1), got '{v}'")))?,
    };
    // Validate before the bootstrap early-return below, so a bad value
    // in CI fails immediately instead of lying dormant until a baseline
    // is committed.
    if !(0.0..1.0).contains(&tolerance) {
        return Err(Error::Config(format!(
            "--tolerance must be a fraction in [0, 1), got {tolerance}")));
    }
    let baseline = Value::parse(&std::fs::read_to_string(baseline_path)?)?;
    let measured = Value::parse(&std::fs::read_to_string(measured_path)?)?;

    // Bootstrap mode: an unpopulated baseline (results: null) records
    // rather than gates — the measured report is the candidate baseline
    // to commit.
    let unpopulated = !matches!(baseline.get("results"),
                                Some(Value::Object(_)));
    if unpopulated && opts.flag("bootstrap") {
        println!("bench-gate: baseline {baseline_path} has no populated \
                  results; nothing to gate against (bootstrap mode).");
        println!("commit {measured_path}'s numbers into {baseline_path} \
                  to arm the gate.");
        return Ok(());
    }

    let cmp = compare_bench_reports(&baseline, &measured, tolerance)?;
    println!("bench-gate: {} entr{} compared against {baseline_path} \
              (allowed drop {:.0}%)",
             cmp.compared.len(),
             if cmp.compared.len() == 1 { "y" } else { "ies" },
             tolerance * 100.0);
    for name in &cmp.skipped {
        println!("  skipped: {name} (absent from one report)");
    }
    if cmp.passed() {
        println!("  all within tolerance — gate passes");
        Ok(())
    } else {
        for r in &cmp.regressions {
            eprintln!("  REGRESSION {r}");
        }
        Err(Error::Artifact(format!(
            "bench-regression gate failed: {} entr{} regressed",
            cmp.regressions.len(),
            if cmp.regressions.len() == 1 { "y" } else { "ies" })))
    }
}

fn cmd_config(opts: &Opts) -> Result<()> {
    let text = DeploymentConfig::paper().to_json_text();
    match opts.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("paper config -> {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}
