//! Per-agent FIFO queues and dynamic batch formation.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::error::Result;
use crate::server::CompletedRequest;

/// One queued inference request.
#[derive(Debug)]
pub struct QueuedRequest {
    /// Token ids (seq_len of them).
    pub tokens: Vec<i32>,
    /// Enqueue timestamp (latency measurement starts here).
    pub enqueued: Instant,
    /// Reply channel resolved by the serving thread.
    pub reply: Sender<Result<CompletedRequest>>,
}

/// FIFO queue for one agent, with arrival accounting for the allocator.
#[derive(Debug, Default)]
pub struct AgentQueue {
    queue: VecDeque<QueuedRequest>,
    /// Arrivals since the last allocator window rollover.
    pub window_arrivals: u64,
    /// Total arrivals ever.
    pub total_arrivals: u64,
}

impl AgentQueue {
    /// Empty queue.
    pub fn new() -> Self {
        AgentQueue::default()
    }

    /// Enqueue one request.
    pub fn push(&mut self, req: QueuedRequest) {
        self.queue.push_back(req);
        self.window_arrivals += 1;
        self.total_arrivals += 1;
    }

    /// Current depth.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no requests wait.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pop up to `max_batch` requests (dynamic batching: take whatever is
    /// waiting, bounded by the largest compiled variant).
    pub fn pop_batch(&mut self, max_batch: usize) -> Vec<QueuedRequest> {
        let n = self.queue.len().min(max_batch);
        self.queue.drain(..n).collect()
    }

    /// Read-and-reset the window arrival counter (allocator input).
    pub fn take_window_arrivals(&mut self) -> u64 {
        std::mem::take(&mut self.window_arrivals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req() -> QueuedRequest {
        let (tx, _rx) = channel();
        QueuedRequest {
            tokens: vec![0; 8],
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn fifo_batching() {
        let mut q = AgentQueue::new();
        for _ in 0..5 {
            q.push(req());
        }
        assert_eq!(q.len(), 5);
        let b = q.pop_batch(4);
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 1);
        let b = q.pop_batch(4);
        assert_eq!(b.len(), 1);
        assert!(q.is_empty());
        assert_eq!(q.pop_batch(4).len(), 0);
    }

    #[test]
    fn window_arrivals_reset() {
        let mut q = AgentQueue::new();
        q.push(req());
        q.push(req());
        assert_eq!(q.take_window_arrivals(), 2);
        assert_eq!(q.take_window_arrivals(), 0);
        assert_eq!(q.total_arrivals, 2);
    }
}
