//! The clock-abstracted serving core: one scheduling state machine for
//! both the threaded PJRT server and the virtual-time simulator.
//!
//! [`ServingCore`] owns everything the serving loop *decides and
//! accounts* — windowed arrival stats feeding the [`AllocationPolicy`],
//! the [`GpuGovernor`] stride pick, per-agent latency histograms, batch
//! and GPU-time counters — while staying agnostic about *when* things
//! happen ([`Clock`]) and *how* a batch runs ([`Executor`]). The
//! threaded [`AgentServer`](crate::server::AgentServer) drives it with
//! wall-clock `Instant`s and the PJRT engine; the deterministic
//! [`ServingSimulator`](crate::server::ServingSimulator) drives the
//! identical core in virtual time with a profile-derived cost model.

use crate::agents::AgentRegistry;
use crate::allocator::{AllocContext, AllocationPolicy};
use crate::metrics::Histogram;
use crate::server::GpuGovernor;
use crate::sim::fault::RetryPolicy;
use crate::workload::TraceRecorder;

/// A source of timestamps the core can subtract. The core never *reads*
/// a clock — drivers hand it instants — so the same scheduling code runs
/// against wall time and virtual time.
pub trait Clock {
    /// Timestamp type the driver supplies.
    type Instant: Copy + std::fmt::Debug;

    /// Seconds from `earlier` to `later` (saturating at zero).
    fn seconds_between(earlier: &Self::Instant, later: &Self::Instant)
                       -> f64;
}

/// Wall-clock time: instants are `std::time::Instant`.
#[derive(Debug, Clone, Copy, Default)]
pub struct WallClock;

impl Clock for WallClock {
    type Instant = std::time::Instant;

    fn seconds_between(earlier: &Self::Instant, later: &Self::Instant)
                       -> f64 {
        later.duration_since(*earlier).as_secs_f64()
    }
}

/// Virtual time: instants are seconds since simulation start.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock;

impl Clock for VirtualClock {
    type Instant = f64;

    fn seconds_between(earlier: &Self::Instant, later: &Self::Instant)
                       -> f64 {
        (later - earlier).max(0.0)
    }
}

/// Runs one dynamic batch for an agent. Returns the service seconds the
/// governor is charged (measured PJRT wall time on hardware, cost-model
/// time in the simulator) alongside the execution outcome.
pub trait Executor {
    /// One queued request as the driver represents it (token rows on the
    /// server, enqueue timestamps in the simulator).
    type Request;
    /// What a successful batch produces (next tokens on hardware,
    /// nothing in the simulator).
    type Output;

    /// Execute one batch for `agent`.
    fn execute(&mut self, agent: usize, batch: &[Self::Request])
               -> (f64, crate::error::Result<Self::Output>);
}

/// One agent's serving statistics row: the named replacement for the old
/// opaque `(name, completed, p50, p99, mean batch, gpu share)` 6-tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentStat {
    /// Agent name.
    pub name: String,
    /// Requests completed.
    pub completed: u64,
    /// Median request latency (seconds).
    pub p50_s: f64,
    /// 99th-percentile request latency (seconds).
    pub p99_s: f64,
    /// Mean executed batch size.
    pub mean_batch: f64,
    /// Fraction of total GPU busy time this agent consumed.
    pub gpu_share: f64,
}

/// Per-agent counters the core accumulates.
#[derive(Debug, Clone, PartialEq)]
struct AgentCounters {
    completed: u64,
    errors: u64,
    latency: Histogram,
    latency_sum_s: f64,
    batch_sum: u64,
    batches: u64,
    gpu_seconds: f64,
}

impl AgentCounters {
    fn new() -> Self {
        AgentCounters {
            completed: 0,
            errors: 0,
            latency: Histogram::latency_seconds(),
            latency_sum_s: 0.0,
            batch_sum: 0,
            batches: 0,
            gpu_seconds: 0.0,
        }
    }
}

/// The serving scheduling core (window stats → policy → governor pick →
/// batch accounting), generic over the [`Clock`] supplying instants and
/// the policy type (`Box<dyn AllocationPolicy>` on the server,
/// [`PolicyKind`](crate::allocator::PolicyKind) or `&mut P` in sweeps).
///
/// The driver owns the queues and the executor; the core owns every
/// decision in between:
///
/// 1. [`window_due`](ServingCore::window_due) /
///    [`reallocate`](ServingCore::reallocate) — close an allocation
///    window, feed observed rates + depths to the policy, re-weight the
///    governor;
/// 2. [`pick`](ServingCore::pick) — idle→busy wakeup snaps, then the
///    stride-scheduled agent choice;
/// 3. [`record_batch`](ServingCore::record_batch) /
///    [`record_completion`](ServingCore::record_completion) /
///    [`record_failed_batch`](ServingCore::record_failed_batch) —
///    governor charge and per-agent stats.
pub struct ServingCore<C: Clock, P: AllocationPolicy> {
    registry: AgentRegistry,
    policy: P,
    governor: GpuGovernor,
    alloc_window_s: f64,
    capacity: f64,
    max_batches: Vec<usize>,
    alloc: Vec<f64>,
    last_alloc: Vec<f64>,
    rates: Vec<f64>,
    depths: Vec<f64>,
    prev_backlogged: Vec<bool>,
    window_start: Option<C::Instant>,
    step: u64,
    stats: Vec<AgentCounters>,
    trajectory: Option<Vec<Vec<f64>>>,
    retry: RetryPolicy,
    retried: u64,
    recorder: Option<TraceRecorder>,
}

impl<C: Clock, P: AllocationPolicy> ServingCore<C, P> {
    /// Build a core over a registry. `max_batches[i]` caps agent `i`'s
    /// dynamic batches (the largest compiled variant on hardware). The
    /// policy is `reset()` so instances can be reused across runs. With
    /// `record_trajectory`, every window's allocation vector is kept.
    pub fn new(registry: AgentRegistry, mut policy: P, alloc_window_s: f64,
               capacity: f64, max_batches: Vec<usize>,
               record_trajectory: bool) -> Self {
        assert_eq!(max_batches.len(), registry.len(),
                   "max_batches must cover every agent");
        policy.reset();
        let n = registry.len();
        ServingCore {
            governor: GpuGovernor::new(n),
            alloc: vec![1.0 / n.max(1) as f64; n],
            last_alloc: vec![0.0; n],
            rates: vec![0.0; n],
            depths: vec![0.0; n],
            prev_backlogged: vec![false; n],
            window_start: None,
            step: 0,
            stats: (0..n).map(|_| AgentCounters::new()).collect(),
            trajectory: record_trajectory.then(Vec::new),
            retry: RetryPolicy::none(),
            retried: 0,
            recorder: None,
            registry,
            policy,
            alloc_window_s,
            capacity,
            max_batches,
        }
    }

    /// Number of agents served.
    pub fn agent_count(&self) -> usize {
        self.registry.len()
    }

    /// Dynamic-batch cap for one agent.
    pub fn max_batch(&self, agent: usize) -> usize {
        self.max_batches[agent]
    }

    /// Name of the driving policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// True when `now` closes the current allocation window. The first
    /// call anchors the window and returns false.
    pub fn window_due(&mut self, now: C::Instant) -> bool {
        match self.window_start {
            None => {
                self.window_start = Some(now);
                false
            }
            Some(start) => {
                C::seconds_between(&start, &now) >= self.alloc_window_s
            }
        }
    }

    /// Close the window at `now`: feed the policy the observed arrival
    /// rates (`window_arrivals[i]` requests over the window) and queue
    /// depths, re-weight the governor, and open the next window.
    pub fn reallocate(&mut self, now: C::Instant, window_arrivals: &[u64],
                      queue_depths: &[f64]) {
        let start = self.window_start.unwrap_or(now);
        let secs = C::seconds_between(&start, &now).max(1e-9);
        for i in 0..self.registry.len() {
            self.rates[i] = window_arrivals[i] as f64 / secs;
            self.depths[i] = queue_depths[i];
        }
        let ctx = AllocContext {
            registry: &self.registry,
            arrival_rates: &self.rates,
            queue_depths: &self.depths,
            step: self.step,
            capacity: self.capacity,
        };
        self.policy.allocate(&ctx, &mut self.alloc);
        self.governor.set_weights(&self.alloc);
        self.governor.rebase();
        self.last_alloc.copy_from_slice(&self.alloc);
        if let Some(traj) = self.trajectory.as_mut() {
            traj.push(self.alloc.clone());
        }
        self.window_start = Some(now);
        self.step += 1;
    }

    /// Snap newly-backlogged agents forward (no catch-up monopoly), then
    /// pick the backlogged agent with the smallest stride pass.
    pub fn pick(&mut self, backlogged: &[bool]) -> Option<usize> {
        debug_assert_eq!(backlogged.len(), self.prev_backlogged.len());
        for i in 0..backlogged.len() {
            if backlogged[i] && !self.prev_backlogged[i] {
                self.governor.on_wakeup(i, backlogged);
            }
        }
        self.prev_backlogged.copy_from_slice(backlogged);
        self.governor.pick(backlogged)
    }

    /// Account one successfully executed batch: charge the governor
    /// `service_s / g` and update the batch counters.
    pub fn record_batch(&mut self, agent: usize, batch_size: usize,
                        service_s: f64) {
        self.governor.charge(agent, service_s);
        let st = &mut self.stats[agent];
        st.batches += 1;
        st.batch_sum += batch_size as u64;
        st.gpu_seconds += service_s;
    }

    /// Account one failed batch: the GPU time is still charged to the
    /// governor (it was consumed), the requests count as errors.
    pub fn record_failed_batch(&mut self, agent: usize, batch_size: usize,
                               service_s: f64) {
        self.governor.charge(agent, service_s);
        self.stats[agent].errors += batch_size as u64;
    }

    /// Replace the retry policy (default: [`RetryPolicy::none`], the
    /// pre-fault-layer fail-permanently semantic).
    pub fn set_retry(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    /// The active retry policy.
    pub fn retry(&self) -> &RetryPolicy {
        &self.retry
    }

    /// Batches that failed transiently and were retried.
    pub fn retried_batches(&self) -> u64 {
        self.retried
    }

    /// Account one failed execution attempt (`attempt` is 0-based) and
    /// decide what the driver does next — the single failure semantic
    /// both the threaded server and the simulator share. The consumed
    /// GPU time is always charged to the governor. Returns
    /// `Some(backoff_s)` when the batch should be retried after that
    /// backoff, or `None` when attempts are exhausted and the batch's
    /// requests are counted as errors (exactly
    /// [`record_failed_batch`](ServingCore::record_failed_batch)).
    pub fn on_batch_failure(&mut self, agent: usize, batch_size: usize,
                            service_s: f64, attempt: u32) -> Option<f64> {
        self.governor.charge(agent, service_s);
        if attempt + 1 < self.retry.max_attempts {
            self.retried += 1;
            Some(self.retry.backoff_for(attempt))
        } else {
            self.stats[agent].errors += batch_size as u64;
            None
        }
    }

    /// Start recording the live queue timeline: every subsequent
    /// [`record_enqueue`](ServingCore::record_enqueue) lands in a
    /// [`TraceRecorder`] whose step duration is `dt` seconds. Both
    /// shells share this hook — the simulator passes virtual enqueue
    /// times, the threaded server passes wall seconds since serve
    /// start. Panics on a non-positive/non-finite `dt` (driver bug,
    /// not data).
    pub fn enable_recorder(&mut self, dt: f64) {
        let names = self.registry.profiles().iter()
            .map(|p| p.name.clone()).collect();
        self.recorder = Some(TraceRecorder::new(names, dt)
            .expect("valid recorder dt"));
    }

    /// Record one accepted request's enqueue (`t_s` seconds since run
    /// start). A single `None` check when recording is disabled — the
    /// hot path costs nothing unless
    /// [`enable_recorder`](ServingCore::enable_recorder) was called.
    #[inline]
    pub fn record_enqueue(&mut self, agent: usize, t_s: f64) {
        if let Some(r) = self.recorder.as_mut() {
            r.record(agent, t_s);
        }
    }

    /// Take the recorded queue timeline (None unless
    /// [`enable_recorder`](ServingCore::enable_recorder) was called);
    /// recording stops.
    pub fn take_recorder(&mut self) -> Option<TraceRecorder> {
        self.recorder.take()
    }

    /// Record one completed request's end-to-end latency.
    pub fn record_completion(&mut self, agent: usize, latency_s: f64) {
        let st = &mut self.stats[agent];
        st.completed += 1;
        st.latency_sum_s += latency_s;
        st.latency.record(latency_s);
    }

    /// The allocation produced by the last closed window (zeros before
    /// the first window closes, matching the legacy server).
    pub fn last_allocation(&self) -> &[f64] {
        &self.last_alloc
    }

    /// Allocation windows closed so far.
    pub fn windows_closed(&self) -> u64 {
        self.step
    }

    /// Take the recorded allocation trajectory (empty unless the core
    /// was built with `record_trajectory`).
    pub fn take_trajectory(&mut self) -> Vec<Vec<f64>> {
        self.trajectory.take().unwrap_or_default()
    }

    /// Per-agent statistics rows.
    pub fn agent_stats(&self) -> Vec<AgentStat> {
        let total_gpu: f64 = self.stats.iter()
            .map(|s| s.gpu_seconds).sum::<f64>().max(1e-12);
        self.stats.iter().enumerate().map(|(i, s)| AgentStat {
            name: self.registry.profile(i).name.clone(),
            completed: s.completed,
            p50_s: s.latency.p50(),
            p99_s: s.latency.p99(),
            mean_batch: if s.batches == 0 {
                0.0
            } else {
                s.batch_sum as f64 / s.batches as f64
            },
            gpu_share: s.gpu_seconds / total_gpu,
        }).collect()
    }

    /// Exact per-agent mean latency (seconds; 0 for idle agents).
    pub fn mean_latencies(&self) -> Vec<f64> {
        self.stats.iter().map(|s| {
            if s.completed == 0 {
                0.0
            } else {
                s.latency_sum_s / s.completed as f64
            }
        }).collect()
    }

    /// Per-agent latency histograms (cloned snapshots).
    pub fn latency_histograms(&self) -> Vec<Histogram> {
        self.stats.iter().map(|s| s.latency.clone()).collect()
    }

    /// Total completed requests.
    pub fn total_completed(&self) -> u64 {
        self.stats.iter().map(|s| s.completed).sum()
    }

    /// Total failed requests.
    pub fn total_errors(&self) -> u64 {
        self.stats.iter().map(|s| s.errors).sum()
    }

    /// Total GPU busy seconds across agents.
    pub fn gpu_busy_seconds(&self) -> f64 {
        self.stats.iter().map(|s| s.gpu_seconds).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::PolicyKind;

    fn core() -> ServingCore<VirtualClock, PolicyKind> {
        ServingCore::new(AgentRegistry::paper(), PolicyKind::adaptive(),
                         0.1, 1.0, vec![8; 4], true)
    }

    #[test]
    fn first_window_anchors_then_rolls_over() {
        let mut c = core();
        assert!(!c.window_due(0.0), "first call only anchors");
        assert!(!c.window_due(0.05));
        assert!(c.window_due(0.1));
        c.reallocate(0.1, &[8, 4, 4, 2], &[0.0; 4]);
        assert_eq!(c.windows_closed(), 1);
        assert!(!c.window_due(0.15), "window re-anchored at rollover");
        // The published allocation respects capacity.
        let total: f64 = c.last_allocation().iter().sum();
        assert!(total > 0.0 && total <= 1.0 + 1e-9, "{total}");
    }

    #[test]
    fn last_allocation_is_zero_before_the_first_window() {
        let c = core();
        assert_eq!(c.last_allocation(), &[0.0; 4]);
    }

    #[test]
    fn on_batch_failure_retries_then_fails_permanently() {
        let mut c = core();
        c.set_retry(RetryPolicy::bounded());
        // bounded() = 3 attempts, 0.01 s backoff, ×2 per attempt.
        let b0 = c.on_batch_failure(1, 3, 0.005, 0).expect("retry 1");
        assert!((b0 - 0.01).abs() < 1e-12, "{b0}");
        let b1 = c.on_batch_failure(1, 3, 0.005, 1).expect("retry 2");
        assert!((b1 - 0.02).abs() < 1e-12, "{b1}");
        assert_eq!(c.on_batch_failure(1, 3, 0.005, 2), None,
                   "attempts exhausted");
        assert_eq!(c.retried_batches(), 2);
        assert_eq!(c.total_errors(), 3, "errors counted only at exhaustion");
        // GPU time was charged for every attempt.
        assert!((c.gpu_busy_seconds() - 0.015).abs() < 1e-12);
    }

    #[test]
    fn default_retry_none_matches_record_failed_batch() {
        let mut a = core();
        let mut b = core();
        assert_eq!(a.on_batch_failure(2, 5, 0.01, 0), None);
        b.record_failed_batch(2, 5, 0.01);
        assert_eq!(a.total_errors(), b.total_errors());
        assert_eq!(a.retried_batches(), 0);
    }

    #[test]
    fn batch_and_completion_accounting_roll_up() {
        let mut c = core();
        c.record_batch(0, 4, 0.02);
        c.record_batch(0, 2, 0.01);
        c.record_batch(1, 1, 0.01);
        for lat in [0.05, 0.06, 0.07] {
            c.record_completion(0, lat);
        }
        c.record_failed_batch(1, 3, 0.005);
        assert_eq!(c.total_completed(), 3);
        assert_eq!(c.total_errors(), 3);
        assert!((c.gpu_busy_seconds() - 0.04).abs() < 1e-12);
        let stats = c.agent_stats();
        assert_eq!(stats[0].name, "coordinator");
        assert_eq!(stats[0].completed, 3);
        assert!((stats[0].mean_batch - 3.0).abs() < 1e-12);
        assert!((stats[0].gpu_share - 0.75).abs() < 1e-9);
        assert!((c.mean_latencies()[0] - 0.06).abs() < 1e-12);
    }

    #[test]
    fn trajectory_records_one_row_per_window() {
        let mut c = core();
        c.window_due(0.0);
        c.reallocate(0.1, &[8, 4, 4, 2], &[0.0; 4]);
        c.reallocate(0.2, &[8, 4, 4, 2], &[1.0; 4]);
        let traj = c.take_trajectory();
        assert_eq!(traj.len(), 2);
        assert_eq!(traj[1].len(), 4);
    }

    #[test]
    fn recorder_is_disabled_by_default_and_captures_when_enabled() {
        let mut c = core();
        c.record_enqueue(0, 0.5); // no recorder: a no-op
        assert!(c.take_recorder().is_none());
        c.enable_recorder(0.1);
        c.record_enqueue(1, 0.25);
        c.record_enqueue(1, 0.25);
        let r = c.take_recorder().expect("enabled");
        assert_eq!(r.len(), 2);
        assert!(c.take_recorder().is_none(), "take stops recording");
    }

    #[test]
    fn pick_skips_idle_and_snaps_wakers() {
        let mut c = core();
        c.window_due(0.0);
        c.reallocate(0.1, &[10, 0, 0, 0], &[5.0, 0.0, 0.0, 0.0]);
        // Only the coordinator is backlogged.
        assert_eq!(c.pick(&[true, false, false, false]), Some(0));
        for _ in 0..100 {
            c.record_batch(0, 8, 0.01);
        }
        // NLP wakes: the snap keeps it from monopolizing, but it is
        // immediately schedulable.
        let picked = c.pick(&[true, true, false, false]).unwrap();
        assert!(picked < 2);
    }
}
