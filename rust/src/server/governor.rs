//! Weighted-share GPU governor: stride scheduling over allocation
//! fractions.
//!
//! The allocator emits GPU fractions g_i; on real hardware those map to
//! MIG slices or time-slicing ratios. Here each agent carries a virtual
//! clock ("pass"). The governor always runs the backlogged agent with the
//! smallest pass, then advances that clock by `cost / g_i`. Standard
//! stride-scheduling argument: long-run compute share → g_i / Σg.

/// Stride scheduler over dynamic weights.
#[derive(Debug, Clone)]
pub struct GpuGovernor {
    weights: Vec<f64>,
    pass: Vec<f64>,
    /// Floor so zero-weight agents still make (very slow) progress instead
    /// of starving — the paper's minimum-requirement philosophy.
    min_weight: f64,
}

impl GpuGovernor {
    /// Create for `n` agents with equal initial weights.
    pub fn new(n: usize) -> Self {
        GpuGovernor {
            weights: vec![1.0 / n.max(1) as f64; n],
            pass: vec![0.0; n],
            min_weight: 1e-3,
        }
    }

    /// Replace the weights with a fresh allocation (fractions, needn't be
    /// normalized). Passes are preserved so re-weighting is incremental.
    pub fn set_weights(&mut self, alloc: &[f64]) {
        assert_eq!(alloc.len(), self.weights.len());
        self.weights.copy_from_slice(alloc);
    }

    /// Current weight of an agent.
    pub fn weight(&self, agent: usize) -> f64 {
        self.weights[agent]
    }

    /// Pick the next agent to run among those with backlog. Returns None
    /// when `backlogged` is all-false.
    pub fn pick(&self, backlogged: &[bool]) -> Option<usize> {
        debug_assert_eq!(backlogged.len(), self.pass.len());
        let mut best: Option<usize> = None;
        for i in 0..self.pass.len() {
            if !backlogged[i] {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) if self.pass[i] < self.pass[b] => best = Some(i),
                _ => {}
            }
        }
        best
    }

    /// Charge `agent` for `cost` seconds of GPU time.
    pub fn charge(&mut self, agent: usize, cost: f64) {
        let w = self.weights[agent].max(self.min_weight);
        self.pass[agent] += cost.max(0.0) / w;
    }

    /// Re-anchor all passes near zero (prevents unbounded growth on
    /// long-running servers; relative order is preserved).
    pub fn rebase(&mut self) {
        if let Some(min) = self.pass.iter().cloned().reduce(f64::min) {
            if min > 1e6 {
                for p in &mut self.pass {
                    *p -= min;
                }
            }
        }
    }

    /// When an idle agent becomes backlogged its stale (tiny) pass would
    /// let it monopolize the GPU while it catches up; snap it forward to
    /// the minimum pass among backlogged peers.
    pub fn on_wakeup(&mut self, agent: usize, backlogged: &[bool]) {
        let floor = (0..self.pass.len())
            .filter(|i| backlogged[*i] && *i != agent)
            .map(|i| self.pass[i])
            .fold(f64::INFINITY, f64::min);
        if floor.is_finite() && self.pass[agent] < floor {
            self.pass[agent] = floor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulate always-backlogged agents with unit-cost batches and check
    /// the long-run share converges to the weights.
    fn share_after(weights: &[f64], rounds: usize) -> Vec<f64> {
        let mut gov = GpuGovernor::new(weights.len());
        gov.set_weights(weights);
        let backlogged = vec![true; weights.len()];
        let mut runs = vec![0usize; weights.len()];
        for _ in 0..rounds {
            let a = gov.pick(&backlogged).unwrap();
            runs[a] += 1;
            gov.charge(a, 0.01);
        }
        runs.iter().map(|r| *r as f64 / rounds as f64).collect()
    }

    #[test]
    fn shares_converge_to_weights() {
        let shares = share_after(&[0.75, 0.25], 4000);
        assert!((shares[0] - 0.75).abs() < 0.02, "{shares:?}");

        let shares = share_after(&[0.2386, 0.2538, 0.2115, 0.2961], 8000);
        for (s, w) in shares.iter().zip([0.2386, 0.2538, 0.2115, 0.2961]) {
            assert!((s - w).abs() < 0.02, "{shares:?}");
        }
    }

    #[test]
    fn pick_skips_idle_agents() {
        let mut gov = GpuGovernor::new(3);
        gov.set_weights(&[0.1, 0.8, 0.1]);
        assert_eq!(gov.pick(&[false, false, true]), Some(2));
        assert_eq!(gov.pick(&[false, false, false]), None);
    }

    #[test]
    fn zero_weight_agent_does_not_starve() {
        let mut gov = GpuGovernor::new(2);
        gov.set_weights(&[1.0, 0.0]);
        let backlogged = [true, true];
        let mut ran1 = 0;
        for _ in 0..100_000 {
            let a = gov.pick(&backlogged).unwrap();
            if a == 1 {
                ran1 += 1;
            }
            gov.charge(a, 0.001);
        }
        assert!(ran1 > 0, "zero-weight agent starved");
        assert!(ran1 < 1000, "zero-weight agent ran too much: {ran1}");
    }

    #[test]
    fn wakeup_prevents_catchup_monopoly() {
        let mut gov = GpuGovernor::new(2);
        gov.set_weights(&[0.5, 0.5]);
        // Agent 0 runs alone for a while.
        for _ in 0..1000 {
            gov.charge(0, 0.01);
        }
        // Agent 1 wakes with pass 0 — snap it forward.
        gov.on_wakeup(1, &[true, true]);
        // Now shares should be balanced going forward, not 100% agent 1.
        let backlogged = [true, true];
        let mut runs = [0usize; 2];
        for _ in 0..1000 {
            let a = gov.pick(&backlogged).unwrap();
            runs[a] += 1;
            gov.charge(a, 0.01);
        }
        assert!(runs[0] > 300, "{runs:?}");
    }

    #[test]
    fn rebase_preserves_order() {
        let mut gov = GpuGovernor::new(2);
        gov.set_weights(&[0.5, 0.5]);
        gov.charge(0, 1e7);
        gov.charge(1, 2e7);
        gov.rebase();
        assert_eq!(gov.pick(&[true, true]), Some(0));
    }

    #[test]
    fn compute_time_shares_converge_with_heterogeneous_batch_costs() {
        // Agents whose batches consume different GPU time: over a long
        // window the governor equalizes *compute time* — not batch
        // counts — to the allocated g_i. This is the stated contract the
        // serving core relies on.
        let weights = [0.6, 0.4];
        let costs = [0.004, 0.001]; // agent 0's batches are 4x heavier
        let mut gov = GpuGovernor::new(2);
        gov.set_weights(&weights);
        let backlogged = [true, true];
        let mut time = [0.0f64; 2];
        for _ in 0..200_000 {
            let a = gov.pick(&backlogged).unwrap();
            gov.charge(a, costs[a]);
            time[a] += costs[a];
        }
        let total: f64 = time.iter().sum();
        for (t, w) in time.iter().zip(weights) {
            assert!((t / total - w).abs() < 0.01,
                    "time shares {time:?} vs weights {weights:?}");
        }
    }

    #[test]
    fn rebase_is_a_noop_below_threshold_and_keeps_gaps_above_it() {
        let mut gov = GpuGovernor::new(2);
        gov.set_weights(&[0.5, 0.5]);
        gov.charge(0, 10.0); // pass 20
        gov.charge(1, 30.0); // pass 60
        gov.rebase(); // min pass far below 1e6: untouched
        assert_eq!(gov.pick(&[true, true]), Some(0));
        // Push both passes past the re-anchor threshold with agent 1 now
        // behind; rebase must preserve that relative ordering too.
        gov.charge(0, 2e7); // pass 20 + 4e7
        gov.charge(1, 1e7); // pass 60 + 2e7
        gov.rebase();
        assert_eq!(gov.pick(&[true, true]), Some(1));
    }

    #[test]
    fn wakeup_does_not_starve_the_newly_backlogged_agent() {
        // The forward snap exists to stop catch-up monopoly, but it must
        // leave the woken agent fully schedulable: from the wakeup on it
        // receives its weight's share, no more and no less.
        let mut gov = GpuGovernor::new(3);
        gov.set_weights(&[0.5, 0.3, 0.2]);
        let mut backlogged = [true, true, false];
        for _ in 0..5_000 {
            let a = gov.pick(&backlogged).unwrap();
            gov.charge(a, 0.01);
        }
        backlogged[2] = true;
        gov.on_wakeup(2, &backlogged);
        let mut runs = [0usize; 3];
        for _ in 0..5_000 {
            let a = gov.pick(&backlogged).unwrap();
            runs[a] += 1;
            gov.charge(a, 0.01);
        }
        let share = runs[2] as f64 / 5_000.0;
        assert!(share > 0.15, "woken agent starved: {runs:?}");
        assert!(share < 0.30, "woken agent over-served: {runs:?}");
    }
}
