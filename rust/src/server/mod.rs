//! The serving stack: request router, per-agent queues, dynamic batcher,
//! and the weighted-share GPU governor driven by the allocation policy.
//!
//! Architecture (no async runtime — the image is offline, and a dedicated
//! serving thread models the serialized GPU command queue faithfully):
//!
//! ```text
//!  client threads ──submit()──► per-agent FIFO queues (Mutex+Condvar)
//!                                        │
//!                        serving thread (owns InferenceEngine):
//!                          1. window stats → AllocationPolicy → g_i
//!                          2. GpuGovernor (stride scheduling over g_i)
//!                             picks the next agent with backlog
//!                          3. dynamic batcher pops ≤ max-variant requests
//!                          4. PJRT execute; per-request latency recorded
//!                          5. responses delivered via channels
//! ```
//!
//! The GPU fraction `g_i` the paper's allocator produces is enforced as a
//! *compute-time share*: the governor charges each agent's virtual clock
//! `elapsed / g_i` per executed batch, so over any window the GPU time an
//! agent receives converges to its allocated fraction (DESIGN.md §4,
//! hardware adaptation of MIG/time-slicing).

mod batcher;
mod governor;
#[allow(clippy::module_inception)]
mod server;

pub use batcher::{AgentQueue, QueuedRequest};
pub use governor::GpuGovernor;
pub use server::{AgentServer, CompletedRequest, ServerConfig, ServerStats};
