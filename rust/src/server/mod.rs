//! The serving stack: request router, per-agent queues, dynamic batcher,
//! and the clock-abstracted scheduling core shared by the threaded PJRT
//! server and the deterministic serving simulator.
//!
//! Architecture (no async runtime — the image is offline, and a dedicated
//! serving thread models the serialized GPU command queue faithfully).
//! Since the core/shell split, every scheduling decision lives in
//! [`ServingCore`]; the two drivers differ only in their [`Clock`] and
//! [`Executor`]:
//!
//! ```text
//!                    ┌──────────────── ServingCore ────────────────┐
//!                    │ 1. window stats → AllocationPolicy → g_i    │
//!                    │ 2. GpuGovernor stride pick (wakeup snaps)   │
//!                    │ 3. per-batch governor charge + stats        │
//!                    │    (latency histograms, batches, GPU time)  │
//!                    │ 4. degradation: on_batch_failure consults   │
//!                    │    RetryPolicy — Some(backoff) = retry the  │
//!                    │    batch, None = retries exhausted, count   │
//!                    │    errors (both shells share this failure   │
//!                    │    semantic); AdmissionControl bounds the   │
//!                    │    queues, shedding by ShedPolicy (newest / │
//!                    │    priority / deadline) instead of queueing │
//!                    │    unboundedly — ResilienceReport surfaces  │
//!                    │    what the faults cost                     │
//!                    └──────────────▲───────────────▲──────────────┘
//!   threaded shell (AgentServer)   │               │   virtual-time shell
//!                                  │               │   (ServingSimulator)
//!  client threads ──submit()──►    │               │
//!    per-agent FIFO queues         │               │  workload generator /
//!      (Mutex+Condvar)             │               │  recorded Trace →
//!  WallClock Instants ─────────────┘               │  arrival stream
//!  PJRT EngineExecutor                             │  VirtualClock f64 now
//!  (measured execute time)              CostModelExecutor
//!  responses via channels               (service time from AgentProfile
//!                                        + batch size; no artifacts)
//! ```
//!
//! The GPU fraction `g_i` the paper's allocator produces is enforced as a
//! *compute-time share*: the governor charges each agent's virtual clock
//! `elapsed / g_i` per executed batch, so over any window the GPU time an
//! agent receives converges to its allocated fraction (DESIGN.md §4,
//! hardware adaptation of MIG/time-slicing). Both shells inherit this
//! from the shared core, which is what lets the sweep engine replay the
//! serving queue path deterministically
//! ([`SweepCell::Serving`](crate::sim::batch::SweepCell)) — and, with a
//! seeded [`ServingFaults`](crate::sim::fault::ServingFaults) config
//! (injected dispatch failures + bounded queues), replay degradation
//! deterministically too ([`SweepCell::Fault`](crate::sim::batch::SweepCell)).

mod batcher;
pub mod core;
mod governor;
#[allow(clippy::module_inception)]
mod server;
pub mod sim;

pub use batcher::{AgentQueue, QueuedRequest};
pub use governor::GpuGovernor;
pub use self::core::{AgentStat, Clock, Executor, ServingCore,
                     VirtualClock, WallClock};
pub use self::server::{AgentServer, CompletedRequest, ServerConfig,
                       ServerStats};
pub use self::sim::{CostModelExecutor, ServingArena, ServingConfig,
                    ServingResult, ServingSimulator};
