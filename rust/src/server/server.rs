//! The multi-agent inference server: the threaded shell around
//! [`ServingCore`], driving it with wall-clock instants and the PJRT
//! engine.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::agents::AgentRegistry;
use crate::allocator::{policy_by_name, AllocationPolicy};
use crate::error::{Error, Result};
use crate::runtime::{InferenceEngine, InferenceOutput, Manifest};
use crate::server::core::{AgentStat, Executor, ServingCore, WallClock};
use crate::server::{AgentQueue, QueuedRequest};
use crate::sim::fault::RetryPolicy;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Directory holding `manifest.json` + HLO + params artifacts.
    pub artifacts_dir: PathBuf,
    /// Allocation policy name (see [`crate::allocator::policy_by_name`]).
    pub policy: String,
    /// How often the allocator re-runs over windowed arrival stats.
    pub alloc_window: Duration,
    /// Total GPU capacity handed to the policy (paper: 1.0).
    pub capacity: f64,
    /// Retry policy for failed batch executions: transient failures are
    /// re-dispatched after a backoff through the same
    /// [`ServingCore::on_batch_failure`] path the deterministic
    /// simulator uses, so both shells share one failure semantic.
    pub retry: RetryPolicy,
    /// `Some(dt)` records every submitted request's enqueue time
    /// (wall seconds since server start) through the core's
    /// [`TraceRecorder`](crate::workload::TraceRecorder), dumpable via
    /// [`AgentServer::dump_trace`] as a binary trace with step duration
    /// `dt` — the live timeline then replays deterministically through
    /// [`ServingSimulator::run_source`](crate::server::ServingSimulator::run_source).
    /// `None` (the default) costs nothing on the submit path.
    pub record_trace_dt: Option<f64>,
}

impl ServerConfig {
    /// Defaults: `artifacts/`, adaptive policy, 100 ms window, bounded
    /// retry (3 attempts).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            policy: "adaptive".into(),
            alloc_window: Duration::from_millis(100),
            capacity: 1.0,
            retry: RetryPolicy::bounded(),
            record_trace_dt: None,
        }
    }
}

/// A finished request, delivered on the submit channel.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    /// Agent that served the request.
    pub agent: String,
    /// Greedy next-token prediction.
    pub next_token: i32,
    /// Enqueue → completion wall time.
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Snapshot of server statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Per-agent rows (completion counts, latency quantiles, batching,
    /// GPU share).
    pub per_agent: Vec<AgentStat>,
    /// Total completed requests.
    pub total_completed: u64,
    /// Total failed requests.
    pub total_errors: u64,
    /// Sum of PJRT execute time (seconds).
    pub gpu_busy_seconds: f64,
    /// Latest allocation the policy produced.
    pub last_allocation: Vec<f64>,
}

/// The wall-clock instantiation of the core the serving thread drives.
type WallCore = ServingCore<WallClock, Box<dyn AllocationPolicy>>;

struct Shared {
    queues: Mutex<Vec<AgentQueue>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// The scheduling core. Lock order: `queues` before `core` (the
    /// stats snapshot takes `core` alone, so no cycle exists).
    core: Mutex<WallCore>,
}

/// Multi-agent inference server. `submit` is thread-safe; one serving
/// thread owns the PJRT engine and enforces the allocator's GPU shares
/// via the core's stride scheduling.
pub struct AgentServer {
    shared: Arc<Shared>,
    registry: AgentRegistry,
    seq_len: usize,
    vocab: Vec<usize>,
    handle: Option<JoinHandle<()>>,
    started: Instant,
    recording: bool,
}

impl AgentServer {
    /// Load artifacts, start the serving thread, return the handle.
    pub fn start(cfg: ServerConfig) -> Result<AgentServer> {
        // Parse the manifest on the caller thread so submit() can validate
        // without waiting for compilation to finish.
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let registry = AgentRegistry::new(manifest.profiles())?;
        let seq_len = manifest.seq_len;
        let vocab = manifest.agents.iter().map(|a| a.vocab).collect();
        let n = registry.len();

        let policy = policy_by_name(&cfg.policy).ok_or_else(
            || Error::Config(format!("unknown policy '{}'", cfg.policy)))?;
        let max_batches: Vec<usize> = registry.profiles().iter().map(|p| {
            manifest.agent(&p.name).map_or(1, |a| a.max_batch())
        }).collect();
        let mut core = ServingCore::<WallClock, _>::new(
            registry.clone(), policy, cfg.alloc_window.as_secs_f64(),
            cfg.capacity, max_batches, false);
        core.set_retry(cfg.retry.clone());
        let recording = match cfg.record_trace_dt {
            Some(dt) => {
                if !(dt > 0.0) || !dt.is_finite() {
                    return Err(Error::Config(format!(
                        "record_trace_dt must be positive and finite, \
                         got {dt}")));
                }
                core.enable_recorder(dt);
                true
            }
            None => false,
        };

        let shared = Arc::new(Shared {
            queues: Mutex::new((0..n).map(|_| AgentQueue::new()).collect()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            core: Mutex::new(core),
        });

        // The engine is built *inside* the serving thread (PJRT handles
        // are not Send). Compilation errors are reported through a
        // one-shot channel so start() fails loudly.
        let (init_tx, init_rx) = channel::<Result<()>>();
        let thread_shared = Arc::clone(&shared);
        let thread_registry = registry.clone();
        let handle = std::thread::Builder::new()
            .name("agentsrv-gpu".into())
            .spawn(move || {
                let mut engine = match InferenceEngine::load(
                    &cfg.artifacts_dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                serve_loop(&thread_shared, &thread_registry, &mut engine,
                           cfg.alloc_window);
            })
            .map_err(|e| Error::Serving(format!("spawn: {e}")))?;

        match init_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => {
                let _ = handle.join();
                return Err(Error::Serving(
                    "serving thread died during init".into()));
            }
        }

        Ok(AgentServer {
            shared,
            registry,
            seq_len,
            vocab,
            handle: Some(handle),
            started: Instant::now(),
            recording,
        })
    }

    /// The agent registry being served.
    pub fn registry(&self) -> &AgentRegistry {
        &self.registry
    }

    /// Context window length of the compiled models.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Submit a request; returns a channel that yields the completion.
    pub fn submit(&self, agent: &str, tokens: Vec<i32>)
                  -> Result<Receiver<Result<CompletedRequest>>> {
        let id = self.registry.id_of(agent).ok_or_else(
            || Error::Serving(format!("unknown agent '{agent}'")))?;
        if tokens.len() != self.seq_len {
            return Err(Error::Serving(format!(
                "expected {} tokens, got {}", self.seq_len, tokens.len())));
        }
        let vocab = self.vocab[id] as i32;
        if tokens.iter().any(|t| *t < 0 || *t >= vocab) {
            return Err(Error::Serving(format!(
                "token id out of range [0, {vocab})")));
        }
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(Error::Serving("server shutting down".into()));
        }
        let (tx, rx) = channel();
        let enqueued = Instant::now();
        {
            let mut queues = self.shared.queues.lock().expect("queues lock");
            queues[id].push(QueuedRequest {
                tokens,
                enqueued,
                reply: tx,
            });
        }
        if self.recording {
            // Recorder order is irrelevant (the dump sorts), so the
            // core lock is taken outside the queue lock.
            let t_s = enqueued.duration_since(self.started).as_secs_f64();
            self.shared.core.lock().expect("core lock")
                .record_enqueue(id, t_s);
        }
        self.shared.work_cv.notify_one();
        Ok(rx)
    }

    /// Submit and wait for the result.
    pub fn submit_blocking(&self, agent: &str, tokens: Vec<i32>)
                           -> Result<CompletedRequest> {
        let rx = self.submit(agent, tokens)?;
        rx.recv().map_err(|_| Error::Serving(
            "serving thread dropped the request".into()))?
    }

    /// Snapshot of server statistics.
    pub fn stats(&self) -> ServerStats {
        let core = self.shared.core.lock().expect("core lock");
        ServerStats {
            per_agent: core.agent_stats(),
            total_completed: core.total_completed(),
            total_errors: core.total_errors(),
            gpu_busy_seconds: core.gpu_busy_seconds(),
            last_allocation: core.last_allocation().to_vec(),
        }
    }

    /// Dump the live queue timeline recorded since start as a
    /// burst-encoded binary trace at `path` (requires
    /// `record_trace_dt` in the config; recording stops). The dump
    /// covers every wall-clock step elapsed so far, and replays
    /// deterministically through
    /// [`ServingSimulator::run_source`](crate::server::ServingSimulator::run_source)
    /// or `agentsrv trace convert`.
    pub fn dump_trace(&self, path: &std::path::Path) -> Result<()> {
        let recorder = self.shared.core.lock().expect("core lock")
            .take_recorder();
        let recorder = recorder.ok_or_else(|| Error::Serving(
            "trace recording was not enabled \
             (set ServerConfig::record_trace_dt)".into()))?;
        let elapsed = self.started.elapsed().as_secs_f64();
        let steps = (elapsed / recorder.dt()).ceil().max(1.0) as u64;
        recorder.save(path, steps)
    }

    /// Drain outstanding work and stop the serving thread.
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AgentServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The hardware executor: PJRT execution timed with the wall clock.
struct EngineExecutor<'a> {
    engine: &'a mut InferenceEngine,
    names: Vec<String>,
}

impl Executor for EngineExecutor<'_> {
    type Request = QueuedRequest;
    type Output = InferenceOutput;

    fn execute(&mut self, agent: usize, batch: &[QueuedRequest])
               -> (f64, Result<InferenceOutput>) {
        let rows: Vec<&[i32]> =
            batch.iter().map(|r| r.tokens.as_slice()).collect();
        let start = Instant::now();
        let result = self.engine.infer_rows(&self.names[agent], &rows);
        (start.elapsed().as_secs_f64(), result)
    }
}

/// The serving loop: the threaded shell around the core — wait for work,
/// let the core allocate and pick, execute via PJRT outside the locks,
/// feed the accounting back.
fn serve_loop(shared: &Shared, registry: &AgentRegistry,
              engine: &mut InferenceEngine, alloc_window: Duration) {
    let n = registry.len();
    let mut executor = EngineExecutor {
        engine,
        names: registry.profiles().iter()
            .map(|p| p.name.clone()).collect(),
    };
    let mut arrivals = vec![0u64; n];
    let mut depths = vec![0.0f64; n];
    let mut backlogged = vec![false; n];

    loop {
        // Decide one batch under the queue lock.
        let (agent_id, batch) = {
            let mut queues = shared.queues.lock().expect("queues lock");
            loop {
                let shutting_down = shared.shutdown.load(Ordering::Acquire);
                let any = queues.iter().any(|q| !q.is_empty());
                if any {
                    break;
                }
                if shutting_down {
                    return; // drained + shutdown
                }
                let (q, _timeout) = shared.work_cv
                    .wait_timeout(queues, alloc_window)
                    .expect("cv wait");
                queues = q;
            }

            let now = Instant::now();
            let mut core = shared.core.lock().expect("core lock");
            if core.window_due(now) {
                for (i, q) in queues.iter_mut().enumerate() {
                    arrivals[i] = q.take_window_arrivals();
                    depths[i] = q.len() as f64;
                }
                core.reallocate(now, &arrivals, &depths);
            }
            for (i, q) in queues.iter().enumerate() {
                backlogged[i] = !q.is_empty();
            }
            let Some(agent_id) = core.pick(&backlogged) else {
                continue;
            };
            let batch = queues[agent_id].pop_batch(core.max_batch(agent_id));
            (agent_id, batch)
        };
        if batch.is_empty() {
            continue;
        }

        // Execute outside the locks so submitters are never blocked on
        // PJRT. Transient failures re-dispatch after the core's backoff
        // until the retry budget runs out.
        let name = &registry.profile(agent_id).name;
        let mut attempt = 0u32;
        loop {
            let (service_s, result) = executor.execute(agent_id, &batch);
            match result {
                Ok(out) => {
                    let mut core = shared.core.lock().expect("core lock");
                    core.record_batch(agent_id, batch.len(), service_s);
                    let batch_size = out.next_tokens.len();
                    for (i, req) in batch.into_iter().enumerate() {
                        let latency = req.enqueued.elapsed();
                        core.record_completion(agent_id,
                                               latency.as_secs_f64());
                        let _ = req.reply.send(Ok(CompletedRequest {
                            agent: name.clone(),
                            next_token: out.next_tokens[i],
                            latency,
                            batch_size,
                        }));
                    }
                    break;
                }
                Err(e) => {
                    let backoff = {
                        let mut core =
                            shared.core.lock().expect("core lock");
                        core.on_batch_failure(agent_id, batch.len(),
                                              service_s, attempt)
                    };
                    match backoff {
                        Some(backoff_s) => {
                            std::thread::sleep(
                                Duration::from_secs_f64(backoff_s));
                            attempt += 1;
                        }
                        None => {
                            for req in batch {
                                let _ = req.reply.send(Err(Error::Serving(
                                    format!("execution failed: {e}"))));
                            }
                            break;
                        }
                    }
                }
            }
        }
    }
}
