//! The multi-agent inference server.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::agents::AgentRegistry;
use crate::allocator::{policy_by_name, AllocContext};
use crate::error::{Error, Result};
use crate::metrics::Histogram;
use crate::runtime::{InferenceEngine, Manifest};
use crate::server::{AgentQueue, GpuGovernor, QueuedRequest};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Directory holding `manifest.json` + HLO + params artifacts.
    pub artifacts_dir: PathBuf,
    /// Allocation policy name (see [`crate::allocator::policy_by_name`]).
    pub policy: String,
    /// How often the allocator re-runs over windowed arrival stats.
    pub alloc_window: Duration,
    /// Total GPU capacity handed to the policy (paper: 1.0).
    pub capacity: f64,
}

impl ServerConfig {
    /// Defaults: `artifacts/`, adaptive policy, 100 ms window.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            policy: "adaptive".into(),
            alloc_window: Duration::from_millis(100),
            capacity: 1.0,
        }
    }
}

/// A finished request, delivered on the submit channel.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    /// Agent that served the request.
    pub agent: String,
    /// Greedy next-token prediction.
    pub next_token: i32,
    /// Enqueue → completion wall time.
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

#[derive(Debug)]
struct AgentStatsInner {
    completed: u64,
    errors: u64,
    latency: Histogram,
    batch_sum: u64,
    batches: u64,
    gpu_seconds: f64,
}

impl AgentStatsInner {
    fn new() -> Self {
        AgentStatsInner {
            completed: 0,
            errors: 0,
            latency: Histogram::latency_seconds(),
            batch_sum: 0,
            batches: 0,
            gpu_seconds: 0.0,
        }
    }
}

/// Snapshot of server statistics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Per agent: (name, completed, p50 s, p99 s, mean batch, gpu share).
    pub per_agent: Vec<(String, u64, f64, f64, f64, f64)>,
    /// Total completed requests.
    pub total_completed: u64,
    /// Total failed requests.
    pub total_errors: u64,
    /// Sum of PJRT execute time (seconds).
    pub gpu_busy_seconds: f64,
    /// Latest allocation the policy produced.
    pub last_allocation: Vec<f64>,
}

struct Shared {
    queues: Mutex<Vec<AgentQueue>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    stats: Mutex<Vec<AgentStatsInner>>,
    last_alloc: Mutex<Vec<f64>>,
}

/// Multi-agent inference server. `submit` is thread-safe; one serving
/// thread owns the PJRT engine and enforces the allocator's GPU shares
/// via stride scheduling.
pub struct AgentServer {
    shared: Arc<Shared>,
    registry: AgentRegistry,
    seq_len: usize,
    vocab: Vec<usize>,
    handle: Option<JoinHandle<()>>,
}

impl AgentServer {
    /// Load artifacts, start the serving thread, return the handle.
    pub fn start(cfg: ServerConfig) -> Result<AgentServer> {
        // Parse the manifest on the caller thread so submit() can validate
        // without waiting for compilation to finish.
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let registry = AgentRegistry::new(manifest.profiles())?;
        let seq_len = manifest.seq_len;
        let vocab = manifest.agents.iter().map(|a| a.vocab).collect();
        let n = registry.len();

        let shared = Arc::new(Shared {
            queues: Mutex::new((0..n).map(|_| AgentQueue::new()).collect()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            stats: Mutex::new((0..n).map(|_| AgentStatsInner::new())
                              .collect()),
            last_alloc: Mutex::new(vec![0.0; n]),
        });

        let mut policy = policy_by_name(&cfg.policy).ok_or_else(
            || Error::Config(format!("unknown policy '{}'", cfg.policy)))?;

        // The engine is built *inside* the serving thread (PJRT handles
        // are not Send). Compilation errors are reported through a
        // one-shot channel so start() fails loudly.
        let (init_tx, init_rx) = channel::<Result<()>>();
        let thread_shared = Arc::clone(&shared);
        let thread_registry = registry.clone();
        let handle = std::thread::Builder::new()
            .name("agentsrv-gpu".into())
            .spawn(move || {
                let mut engine = match InferenceEngine::load(
                    &cfg.artifacts_dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                serve_loop(&thread_shared, &thread_registry, &mut engine,
                           policy.as_mut(), &cfg);
            })
            .map_err(|e| Error::Serving(format!("spawn: {e}")))?;

        match init_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => {
                let _ = handle.join();
                return Err(Error::Serving(
                    "serving thread died during init".into()));
            }
        }

        Ok(AgentServer {
            shared,
            registry,
            seq_len,
            vocab,
            handle: Some(handle),
        })
    }

    /// The agent registry being served.
    pub fn registry(&self) -> &AgentRegistry {
        &self.registry
    }

    /// Context window length of the compiled models.
    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Submit a request; returns a channel that yields the completion.
    pub fn submit(&self, agent: &str, tokens: Vec<i32>)
                  -> Result<Receiver<Result<CompletedRequest>>> {
        let id = self.registry.id_of(agent).ok_or_else(
            || Error::Serving(format!("unknown agent '{agent}'")))?;
        if tokens.len() != self.seq_len {
            return Err(Error::Serving(format!(
                "expected {} tokens, got {}", self.seq_len, tokens.len())));
        }
        let vocab = self.vocab[id] as i32;
        if tokens.iter().any(|t| *t < 0 || *t >= vocab) {
            return Err(Error::Serving(format!(
                "token id out of range [0, {vocab})")));
        }
        if self.shared.shutdown.load(Ordering::Acquire) {
            return Err(Error::Serving("server shutting down".into()));
        }
        let (tx, rx) = channel();
        {
            let mut queues = self.shared.queues.lock().expect("queues lock");
            queues[id].push(QueuedRequest {
                tokens,
                enqueued: Instant::now(),
                reply: tx,
            });
        }
        self.shared.work_cv.notify_one();
        Ok(rx)
    }

    /// Submit and wait for the result.
    pub fn submit_blocking(&self, agent: &str, tokens: Vec<i32>)
                           -> Result<CompletedRequest> {
        let rx = self.submit(agent, tokens)?;
        rx.recv().map_err(|_| Error::Serving(
            "serving thread dropped the request".into()))?
    }

    /// Snapshot of server statistics.
    pub fn stats(&self) -> ServerStats {
        let stats = self.shared.stats.lock().expect("stats lock");
        let total_gpu: f64 =
            stats.iter().map(|s| s.gpu_seconds).sum::<f64>().max(1e-12);
        let per_agent = stats.iter().enumerate().map(|(i, s)| {
            (
                self.registry.profile(i).name.clone(),
                s.completed,
                s.latency.p50(),
                s.latency.p99(),
                if s.batches == 0 {
                    0.0
                } else {
                    s.batch_sum as f64 / s.batches as f64
                },
                s.gpu_seconds / total_gpu,
            )
        }).collect();
        ServerStats {
            per_agent,
            total_completed: stats.iter().map(|s| s.completed).sum(),
            total_errors: stats.iter().map(|s| s.errors).sum(),
            gpu_busy_seconds: stats.iter().map(|s| s.gpu_seconds).sum(),
            last_allocation:
                self.shared.last_alloc.lock().expect("alloc lock").clone(),
        }
    }

    /// Drain outstanding work and stop the serving thread.
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AgentServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The serving loop: allocate → pick → batch → execute → respond.
fn serve_loop(shared: &Shared, registry: &AgentRegistry,
              engine: &mut InferenceEngine,
              policy: &mut dyn crate::allocator::AllocationPolicy,
              cfg: &ServerConfig) {
    let n = registry.len();
    let mut governor = GpuGovernor::new(n);
    let mut alloc = vec![1.0 / n as f64; n];
    let mut rates = vec![0.0f64; n];
    let mut depths = vec![0.0f64; n];
    let mut backlogged = vec![false; n];
    let mut prev_backlogged = vec![false; n];
    let mut window_start = Instant::now();
    let mut step: u64 = 0;
    let max_batches: Vec<usize> = registry.profiles().iter().map(|p| {
        engine.manifest().agent(&p.name).map_or(1, |a| a.max_batch())
    }).collect();

    loop {
        // Collect a batch under the queue lock.
        let (agent_id, batch) = {
            let mut queues = shared.queues.lock().expect("queues lock");
            loop {
                let shutting_down = shared.shutdown.load(Ordering::Acquire);
                let any = queues.iter().any(|q| !q.is_empty());
                if any {
                    break;
                }
                if shutting_down {
                    return; // drained + shutdown
                }
                let (q, _timeout) = shared.work_cv
                    .wait_timeout(queues, cfg.alloc_window)
                    .expect("cv wait");
                queues = q;
            }

            // Window rollover: feed the allocator observed rates + depths.
            let elapsed = window_start.elapsed();
            if elapsed >= cfg.alloc_window {
                let secs = elapsed.as_secs_f64().max(1e-9);
                for (i, q) in queues.iter_mut().enumerate() {
                    rates[i] = q.take_window_arrivals() as f64 / secs;
                    depths[i] = q.len() as f64;
                }
                let ctx = AllocContext {
                    registry,
                    arrival_rates: &rates,
                    queue_depths: &depths,
                    step,
                    capacity: cfg.capacity,
                };
                policy.allocate(&ctx, &mut alloc);
                governor.set_weights(&alloc);
                governor.rebase();
                *shared.last_alloc.lock().expect("alloc lock") =
                    alloc.clone();
                window_start = Instant::now();
                step += 1;
            }

            for (i, q) in queues.iter().enumerate() {
                backlogged[i] = !q.is_empty();
                if backlogged[i] && !prev_backlogged[i] {
                    governor.on_wakeup(i, &backlogged);
                }
            }
            prev_backlogged.copy_from_slice(&backlogged);

            let Some(agent_id) = governor.pick(&backlogged) else {
                continue;
            };
            let batch = queues[agent_id].pop_batch(max_batches[agent_id]);
            (agent_id, batch)
        };
        if batch.is_empty() {
            continue;
        }

        // Execute outside the lock so submitters are never blocked on
        // PJRT.
        let name = &registry.profile(agent_id).name;
        let rows: Vec<&[i32]> =
            batch.iter().map(|r| r.tokens.as_slice()).collect();
        let start = Instant::now();
        let result = engine.infer_rows(name, &rows);
        let elapsed = start.elapsed().as_secs_f64();
        governor.charge(agent_id, elapsed);

        let mut stats = shared.stats.lock().expect("stats lock");
        let st = &mut stats[agent_id];
        match result {
            Ok(out) => {
                st.batches += 1;
                st.batch_sum += batch.len() as u64;
                st.gpu_seconds += elapsed;
                for (i, req) in batch.into_iter().enumerate() {
                    let latency = req.enqueued.elapsed();
                    st.completed += 1;
                    st.latency.record(latency.as_secs_f64());
                    let _ = req.reply.send(Ok(CompletedRequest {
                        agent: name.clone(),
                        next_token: out.next_tokens[i],
                        latency,
                        batch_size: out.next_tokens.len(),
                    }));
                }
            }
            Err(e) => {
                st.errors += batch.len() as u64;
                for req in batch {
                    let _ = req.reply.send(Err(Error::Serving(
                        format!("execution failed: {e}"))));
                }
            }
        }
    }
}
