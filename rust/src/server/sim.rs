//! Deterministic serving-layer simulator: the same [`ServingCore`] the
//! threaded server drives, run in virtual time with a profile-derived
//! cost-model executor — no artifacts, no threads, bit-reproducible.
//!
//! Where the fluid-model simulator ([`crate::sim::Simulator`]) moves
//! *request mass* (`min(queue, g·T·dt)` per step), this one serves
//! *individual requests* through the real queue path: per-agent FIFO
//! queues, windowed allocator re-runs, stride-scheduled batch picks,
//! dynamic batching up to a cap, and a serialized GPU whose virtual now
//! advances by each batch's service time. That granularity is where
//! batching and queueing effects actually differentiate policies; the
//! sweep engine replays these runs as
//! [`SweepCell::Serving`](crate::sim::batch::SweepCell) cells.

use std::collections::VecDeque;

use crate::agents::{AgentProfile, AgentRegistry};
use crate::allocator::AllocationPolicy;
use crate::metrics::Histogram;
use crate::server::core::{AgentStat, Executor, ServingCore, VirtualClock};
use crate::sim::fault::{ResilienceReport, ServingFaultCursor,
                        ServingFaults, ShedPolicy};
use crate::workload::trace::Trace;
use crate::workload::{ArrivalProcess, BinTrace, BurstEvent, TraceRecorder,
                      TraceSource, WorkflowStats, WorkflowWorkload,
                      WorkloadGenerator, WorkloadKind};

/// Configuration of one serving-layer simulation run.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Allocator re-run window in virtual seconds (paper: 100 ms).
    pub alloc_window_s: f64,
    /// Total GPU capacity handed to the policy (paper: 1.0).
    pub capacity: f64,
    /// Dynamic-batch cap per agent (largest compiled variant stand-in).
    pub max_batch: usize,
    /// Fixed per-batch dispatch overhead (seconds) — what dynamic
    /// batching amortizes.
    pub dispatch_overhead_s: f64,
    /// Tick length for drawing workload arrival counts (seconds);
    /// requests are spaced evenly inside each tick.
    pub arrival_dt_s: f64,
    /// Virtual duration over which arrivals are generated (seconds); the
    /// run itself continues until every queue drains.
    pub duration_s: f64,
    /// Mean arrival rate per agent (rps), in agent-id order.
    pub arrival_rates: Vec<f64>,
    /// Arrival schedule shape (steady / scaled / spike / ...).
    pub workload_kind: WorkloadKind,
    /// Deterministic or Poisson arrivals.
    pub arrival_process: ArrivalProcess,
    /// RNG seed for the arrival stream.
    pub seed: u64,
    /// Serving-layer fault injection ([`ServingFaults`]): transient
    /// dispatch failures during fault windows (absorbed by the core's
    /// bounded retry-with-backoff) and an optional admission-control
    /// policy that sheds load when the total queue depth exceeds its
    /// bound. `None` (and inert configs) cost nothing: the run is
    /// bit-identical to a build without the fault layer.
    pub faults: Option<ServingFaults>,
    /// Workflow-DAG workload. When set it *replaces* the independent
    /// per-agent arrival streams: the arrival process releases whole
    /// workflow instances instead, each stage becomes `ceil(work)`
    /// queued requests on its agent, and a stage's requests only
    /// enqueue once every upstream stage has fully completed (at the
    /// completing batch's virtual `now`). The run is open-loop:
    /// admission control is not applied to workflow runs (transient
    /// fault injection and retry still are). Trace replays ignore this
    /// field — a recorded per-agent trace is itself the workload.
    pub workflow: Option<WorkflowWorkload>,
}

impl ServingConfig {
    /// The paper's serving setup over the §IV.A workload: 100 ms
    /// allocation window, batch cap 8, Poisson arrivals at the Table I
    /// rates for 10 virtual seconds.
    pub fn paper() -> Self {
        ServingConfig {
            alloc_window_s: 0.1,
            capacity: 1.0,
            max_batch: 8,
            dispatch_overhead_s: 0.002,
            arrival_dt_s: 0.1,
            duration_s: 10.0,
            arrival_rates: AgentProfile::paper_arrival_rates(),
            workload_kind: WorkloadKind::Steady,
            arrival_process: ArrivalProcess::Poisson,
            seed: 42,
            faults: None,
            workflow: None,
        }
    }
}

/// The simulator's executor: service time from the agent profile and the
/// batch size — `overhead + batch / T_i` seconds, the proportional-
/// throughput model of §IV.A at batch granularity.
#[derive(Debug, Clone)]
pub struct CostModelExecutor {
    per_request_s: Vec<f64>,
    dispatch_overhead_s: f64,
}

impl CostModelExecutor {
    /// Build from a registry's base throughputs.
    pub fn new(registry: &AgentRegistry, dispatch_overhead_s: f64) -> Self {
        CostModelExecutor {
            per_request_s:
                registry.base_tput().iter().map(|t| 1.0 / t).collect(),
            dispatch_overhead_s,
        }
    }
}

impl Executor for CostModelExecutor {
    /// A queued request is its enqueue time (virtual seconds).
    type Request = f64;
    type Output = ();

    fn execute(&mut self, agent: usize, batch: &[f64])
               -> (f64, crate::error::Result<()>) {
        let service = self.dispatch_overhead_s
            + batch.len() as f64 * self.per_request_s[agent];
        (service, Ok(()))
    }
}

/// Arrival-count source for the materialization loop: per-tick counts
/// plus the skip-idle window oracle (the serving twin of the fluid
/// engine's private source trait).
trait ArrivalStream {
    /// Fill `rates`/`counts` for `step`.
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]);

    /// Sparse [`ArrivalStream::next`]: fill `rates`/`counts` only for
    /// the agents in `support` (sorted ascending). Callers pass exactly
    /// the set returned by [`ArrivalStream::support`]; every agent
    /// outside it draws rate and count `0.0` at every tick without
    /// consuming RNG state, so eliding those writes leaves both buffers
    /// (zeroed at arena reset, never overwritten since) and the RNG
    /// stream bit-identical to the dense call.
    fn next_support(&mut self, step: u64, dt: f64, support: &[usize],
                    rates: &mut [f64], counts: &mut [f64]);

    /// `Some(until)` promises every tick in `[step, until)` produces
    /// zero counts for every agent without consuming RNG state
    /// (see [`WorkloadGenerator::idle_until`]); `None` means the
    /// current tick may be active.
    fn idle_until(&mut self, step: u64) -> Option<u64>;

    /// Agents that may ever produce a nonzero count (sorted ascending)
    /// — the active-set tier's materialization oracle. `None` means the
    /// stream cannot bound its support and materialization stays dense.
    fn support(&self) -> Option<Vec<usize>>;

    /// Exact intra-tick arrival microstructure for `step`, when the
    /// stream records it. Returning `true` means `out` holds *every*
    /// arrival of the tick as recorded `(timestamp, agent, count)`
    /// events, replacing the even-spacing carry walk for this tick —
    /// the recorded timestamps are injected verbatim. `false` (the
    /// default, and the answer for every generated or CSV-backed
    /// stream) keeps the carry-based materialization. This is data
    /// semantics, not a fast path: the dense reference run consumes
    /// bursts identically.
    fn bursts(&mut self, step: u64, out: &mut Vec<BurstEvent>) -> bool {
        let _ = (step, out);
        false
    }
}

/// Live schedule: the workload generator drives both hooks.
struct GeneratorStream(WorkloadGenerator);

impl ArrivalStream for GeneratorStream {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        self.0.step(step, dt, rates, counts);
    }

    fn next_support(&mut self, step: u64, dt: f64, support: &[usize],
                    rates: &mut [f64], counts: &mut [f64]) {
        self.0.step_active(step, dt, support, rates, counts);
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        self.0.idle_until(step)
    }

    fn support(&self) -> Option<Vec<usize>> {
        Some(self.0.support())
    }
}

/// Recorded trace: counts come off the rows; the idle oracle scans
/// forward for the next nonzero row (amortized O(rows) over a run).
struct TraceStream<'a> {
    rows: &'a [Vec<f64>],
}

impl ArrivalStream for TraceStream<'_> {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        let row = &self.rows[step as usize];
        counts.copy_from_slice(row);
        for (r, c) in rates.iter_mut().zip(row) {
            *r = c / dt;
        }
    }

    fn next_support(&mut self, step: u64, dt: f64, support: &[usize],
                    rates: &mut [f64], counts: &mut [f64]) {
        // Never reached (the trace offers no support set); delegate so
        // the contract holds regardless.
        let _ = support;
        self.next(step, dt, rates, counts);
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        if self.rows[step as usize].iter().any(|c| *c != 0.0) {
            return None;
        }
        for s in (step as usize + 1)..self.rows.len() {
            if self.rows[s].iter().any(|c| *c != 0.0) {
                return Some(s as u64);
            }
        }
        Some(u64::MAX)
    }

    fn support(&self) -> Option<Vec<usize>> {
        // A recorded trace has no closed-form schedule to reason over;
        // its replay stays row-dense (the rows are the ground truth).
        None
    }
}

/// Replay adapter over any [`TraceSource`] (the zero-copy binary
/// reader, or the in-memory `Trace` through its trait impl). Burst
/// microstructure passes through natively — the serving engine is the
/// one consumer that injects recorded timestamps instead of collapsing
/// them.
struct SourceStream<'a> {
    src: &'a dyn TraceSource,
}

impl ArrivalStream for SourceStream<'_> {
    fn next(&mut self, step: u64, dt: f64, rates: &mut [f64],
            counts: &mut [f64]) {
        self.src.fill_row(step, counts);
        for (r, c) in rates.iter_mut().zip(counts.iter()) {
            *r = c / dt;
        }
    }

    fn next_support(&mut self, step: u64, dt: f64, support: &[usize],
                    rates: &mut [f64], counts: &mut [f64]) {
        // Never reached (no support set); delegate so the contract
        // holds regardless.
        let _ = support;
        self.next(step, dt, rates, counts);
    }

    fn idle_until(&mut self, step: u64) -> Option<u64> {
        self.src.idle_until(step)
    }

    fn support(&self) -> Option<Vec<usize>> {
        None
    }

    fn bursts(&mut self, step: u64, out: &mut Vec<BurstEvent>) -> bool {
        self.src.step_bursts(step, out)
    }
}

/// Reusable buffers for serving-layer runs: a sweep worker holds one
/// and replays every [`SweepCell::Serving`](crate::sim::batch::SweepCell)
/// cell through it, reusing the *big* per-run buffers — the
/// materialized arrival stream and the per-agent queues — across cells
/// after warm-up. (Result-owned state — the per-agent histograms and
/// counters that leave the run inside [`ServingResult`] — is
/// necessarily fresh per run, exactly as `SimResult`'s per-agent series
/// are.)
#[derive(Debug, Clone, Default)]
pub struct ServingArena {
    queues: Vec<VecDeque<f64>>,
    arrivals: Vec<(f64, usize)>,
    window_arrivals: Vec<u64>,
    depths: Vec<f64>,
    backlogged: Vec<bool>,
    rates: Vec<f64>,
    counts: Vec<f64>,
    carry: Vec<f64>,
    batch: Vec<f64>,
    burst: Vec<BurstEvent>,
}

impl ServingArena {
    /// Empty arena; buffers grow on first use and are retained after.
    pub fn new() -> Self {
        ServingArena::default()
    }

    /// Size every buffer for `n` agents and reset its contents.
    fn reset(&mut self, n: usize) {
        for q in &mut self.queues {
            q.clear();
        }
        self.queues.resize_with(n, VecDeque::new);
        self.arrivals.clear();
        self.batch.clear();
        self.burst.clear();
        for buf in [&mut self.depths, &mut self.rates, &mut self.counts,
                    &mut self.carry] {
            buf.clear();
            buf.resize(n, 0.0);
        }
        self.window_arrivals.clear();
        self.window_arrivals.resize(n, 0);
        self.backlogged.clear();
        self.backlogged.resize(n, false);
    }
}

/// Result of one serving-layer simulation run. Every field is a pure
/// function of the inputs, so parallel sweep replays are bit-identical
/// to sequential ones (`PartialEq` is exact).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingResult {
    /// Policy that drove the run.
    pub policy: String,
    /// Per-agent rows (completions, p50/p99, mean batch, GPU share).
    pub per_agent: Vec<AgentStat>,
    /// Per-agent latency histograms (full distributions).
    pub latency: Vec<Histogram>,
    /// Exact per-agent mean latency (seconds).
    pub mean_latency_s: Vec<f64>,
    /// Total completed requests.
    pub total_completed: u64,
    /// Total GPU busy seconds.
    pub gpu_busy_s: f64,
    /// Virtual time at which the last queue drained.
    pub makespan_s: f64,
    /// Allocation windows closed.
    pub windows: u64,
    /// The allocation produced by the last closed window.
    pub last_allocation: Vec<f64>,
    /// One allocation vector per closed window (the reallocation
    /// trajectory the §V.B spike analysis reads).
    pub allocation_trajectory: Vec<Vec<f64>>,
    /// Requests shed by admission control, per agent (all zeros when no
    /// admission policy is configured).
    pub shed: Vec<u64>,
    /// Lost time, shed fraction, retries, and goodput under injected
    /// serving faults; present when the run's config set a non-inert
    /// [`ServingFaults`].
    pub resilience: Option<ResilienceReport>,
    /// End-to-end workflow latency stats (started/completed instances,
    /// mean/p99), present when the run's config carried a
    /// [`WorkflowWorkload`].
    pub workflow: Option<WorkflowStats>,
}

impl ServingResult {
    /// Mean of per-agent mean latencies (the Table II estimator shape,
    /// at queue granularity).
    pub fn mean_latency(&self) -> f64 {
        crate::util::mean(&self.mean_latency_s)
    }

    /// Mean of per-agent p99 latencies (seconds).
    pub fn mean_p99(&self) -> f64 {
        let p99s: Vec<f64> =
            self.per_agent.iter().map(|a| a.p99_s).collect();
        crate::util::mean(&p99s)
    }

    /// Mean executed batch size across agents that ran batches.
    pub fn mean_batch(&self) -> f64 {
        let sizes: Vec<f64> = self.per_agent.iter()
            .filter(|a| a.mean_batch > 0.0)
            .map(|a| a.mean_batch)
            .collect();
        crate::util::mean(&sizes)
    }

    /// Completed requests per virtual second.
    pub fn total_throughput(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            0.0
        } else {
            self.total_completed as f64 / self.makespan_s
        }
    }
}

/// Virtual-time serving simulator over one agent registry.
#[derive(Debug, Clone)]
pub struct ServingSimulator {
    cfg: ServingConfig,
    registry: AgentRegistry,
}

impl ServingSimulator {
    /// Build from profiles (panics on invalid profiles — use
    /// [`ServingSimulator::with_registry`] for validated registries).
    pub fn new(cfg: ServingConfig, agents: Vec<AgentProfile>) -> Self {
        let registry = AgentRegistry::new(agents).expect("valid agents");
        ServingSimulator::with_registry(cfg, registry)
    }

    /// Build from an already-validated registry.
    pub fn with_registry(cfg: ServingConfig, registry: AgentRegistry)
                         -> Self {
        assert_eq!(cfg.arrival_rates.len(), registry.len(),
                   "arrival_rates must cover every agent");
        if let Some(wf) = &cfg.workflow {
            if let Err(e) = wf.spec.validate_for(registry.len()) {
                panic!("{e}");
            }
        }
        ServingSimulator { cfg, registry }
    }

    /// The paper deployment under [`ServingConfig::paper`].
    pub fn paper() -> Self {
        ServingSimulator::with_registry(ServingConfig::paper(),
                                        AgentRegistry::paper())
    }

    /// The agent registry simulated over.
    pub fn registry(&self) -> &AgentRegistry {
        &self.registry
    }

    /// The configuration simulated under.
    pub fn config(&self) -> &ServingConfig {
        &self.cfg
    }

    /// Run one policy over the configured workload until every queue
    /// drains. Provably-idle stretches of the arrival schedule are
    /// fast-forwarded during materialization, and busy ticks draw and
    /// walk only the workload's *support set* (agents that can ever
    /// receive an arrival) — both bit-exact with
    /// [`ServingSimulator::run_dense`] (asserted by the test suite);
    /// the serving loop itself is already event-stepped.
    pub fn run<P>(&self, policy: &mut P) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_with_arena(policy, &mut ServingArena::new())
    }

    /// [`ServingSimulator::run`] with the materialization fast-forward
    /// disabled: the dense reference path for the bit-exactness
    /// properties.
    pub fn run_dense<P>(&self, policy: &mut P) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_generated(policy, &mut ServingArena::new(), false)
    }

    /// [`ServingSimulator::run`] with caller-owned buffers.
    pub fn run_with_arena<P>(&self, policy: &mut P,
                             arena: &mut ServingArena) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_generated(policy, arena, true)
    }

    fn run_generated<P>(&self, policy: &mut P, arena: &mut ServingArena,
                        skip_idle: bool) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        if let Some(wf) = &self.cfg.workflow {
            // Workflow releases are a constant-rate stream with no idle
            // windows to skip, so the dense and fast-forward paths are
            // one and the same.
            return self.run_workflow_inner(policy, wf, arena);
        }
        let mut source = GeneratorStream(WorkloadGenerator::new(
            self.cfg.arrival_rates.clone(), self.cfg.workload_kind.clone(),
            self.cfg.arrival_process, self.cfg.seed));
        let dt = self.cfg.arrival_dt_s;
        let steps = (self.cfg.duration_s / dt).round().max(1.0) as u64;
        self.run_inner(policy, &mut source, steps, dt, arena, skip_idle,
                       false).0
    }

    /// Run one policy over the configured workload while recording the
    /// live queue timeline through the core's [`TraceRecorder`], and
    /// dump the recording as a burst-encoded binary trace. Every
    /// *accepted* enqueue is captured with its materialized arrival
    /// timestamp, verbatim — replaying the returned trace through
    /// [`ServingSimulator::run_source`] under the same config and
    /// policy reproduces the run bit-identically when no admission
    /// shedding occurred (asserted by the test suite). Under shedding
    /// the recording is the *accepted* stream: replaying it yields the
    /// run the survivors saw, not the original offered load.
    ///
    /// Panics when the config carries a workflow workload (a recorded
    /// per-agent trace cannot represent stage coupling).
    pub fn run_recording<P>(&self, policy: &mut P)
                            -> (ServingResult, BinTrace)
    where
        P: AllocationPolicy + ?Sized,
    {
        assert!(self.cfg.workflow.is_none(),
                "recording requires a per-agent arrival stream \
                 (workflow runs couple stages, not streams)");
        let mut source = GeneratorStream(WorkloadGenerator::new(
            self.cfg.arrival_rates.clone(), self.cfg.workload_kind.clone(),
            self.cfg.arrival_process, self.cfg.seed));
        let dt = self.cfg.arrival_dt_s;
        let steps = (self.cfg.duration_s / dt).round().max(1.0) as u64;
        let (result, recorder) = self.run_inner(
            policy, &mut source, steps, dt, &mut ServingArena::new(),
            true, true);
        let trace = recorder
            .expect("run_inner returns the enabled recorder")
            .to_bintrace(steps)
            .expect("recorded timeline serializes");
        (result, trace)
    }

    /// Replay a recorded arrival [`Trace`] through the serving queue
    /// path. The trace's `dt` and length override the config's arrival
    /// schedule. Panics on a ragged trace (validated up front) or an
    /// agent-count mismatch.
    pub fn run_trace<P>(&self, policy: &mut P, trace: &Trace)
                        -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_with_arena(policy, trace, &mut ServingArena::new())
    }

    /// [`ServingSimulator::run_trace`] with the materialization
    /// fast-forward disabled (the dense reference path).
    pub fn run_trace_dense<P>(&self, policy: &mut P, trace: &Trace)
                              -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, &mut ServingArena::new(),
                             false)
    }

    /// [`ServingSimulator::run_trace`] with caller-owned buffers.
    pub fn run_trace_with_arena<P>(&self, policy: &mut P, trace: &Trace,
                                   arena: &mut ServingArena)
                                   -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_trace_inner(policy, trace, arena, true)
    }

    fn run_trace_inner<P>(&self, policy: &mut P, trace: &Trace,
                          arena: &mut ServingArena, skip_idle: bool)
                          -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        assert_eq!(trace.agents.len(), self.registry.len(),
                   "trace agent count must match registry");
        if let Err(e) = trace.validate() {
            panic!("{e}");
        }
        let mut source = TraceStream { rows: &trace.counts };
        self.run_inner(policy, &mut source, trace.counts.len() as u64,
                       trace.dt, arena, skip_idle, false).0
    }

    /// Replay any [`TraceSource`] — the zero-copy binary reader
    /// ([`BinTrace`]) or an in-memory [`Trace`] through its trait impl
    /// — through the serving queue path. Dense and sparse frames
    /// materialize exactly like a CSV replay (even spacing inside each
    /// tick); burst frames inject their recorded timestamps verbatim.
    /// The source's `dt` and length override the config's arrival
    /// schedule. Panics on an agent-count mismatch or a
    /// non-positive/non-finite source `dt`.
    pub fn run_source<P>(&self, policy: &mut P, source: &dyn TraceSource)
                         -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_with_arena(policy, source,
                                   &mut ServingArena::new())
    }

    /// [`ServingSimulator::run_source`] with the materialization
    /// fast-forward disabled (the dense reference path; burst frames
    /// are data, not an optimization, so they inject identically here).
    pub fn run_source_dense<P>(&self, policy: &mut P,
                               source: &dyn TraceSource) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_inner(policy, source, &mut ServingArena::new(),
                              false)
    }

    /// [`ServingSimulator::run_source`] with caller-owned buffers.
    pub fn run_source_with_arena<P>(&self, policy: &mut P,
                                    source: &dyn TraceSource,
                                    arena: &mut ServingArena)
                                    -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        self.run_source_inner(policy, source, arena, true)
    }

    fn run_source_inner<P>(&self, policy: &mut P,
                           source: &dyn TraceSource,
                           arena: &mut ServingArena, skip_idle: bool)
                           -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        assert_eq!(source.agent_names().len(), self.registry.len(),
                   "trace agent count must match registry");
        let dt = source.dt();
        assert!(dt > 0.0 && dt.is_finite(),
                "trace dt must be positive and finite, got {dt}");
        let mut stream = SourceStream { src: source };
        self.run_inner(policy, &mut stream, source.steps(), dt, arena,
                       skip_idle, false).0
    }

    fn run_inner<P>(&self, policy: &mut P,
                    source: &mut dyn ArrivalStream, steps: u64, dt: f64,
                    arena: &mut ServingArena, skip_idle: bool,
                    record: bool)
                    -> (ServingResult, Option<TraceRecorder>)
    where
        P: AllocationPolicy + ?Sized,
    {
        let n = self.registry.len();
        arena.reset(n);
        let ServingArena {
            queues, arrivals, window_arrivals, depths, backlogged, rates,
            counts, carry, batch, burst,
        } = arena;

        // Materialize the arrival stream: per tick, draw counts, carry
        // fractional remainders (deterministic mode produces fractional
        // mass), and space the requests evenly inside the tick.
        // Provably-idle stretches of the schedule are jumped instead of
        // ticked through: a zero-count tick materializes nothing, adds
        // `+0.0` to every carry (a bit-no-op), and consumes no RNG state
        // (`poisson(0.0)` returns without a draw), so the jump is
        // bit-exact with dense ticking.
        //
        // One agent's tick: fold the drawn count into the fractional
        // carry and space the whole arrivals evenly inside the tick.
        fn materialize(i: usize, t0: f64, dt: f64, carry: &mut [f64],
                       counts: &[f64], arrivals: &mut Vec<(f64, usize)>) {
            carry[i] += counts[i];
            let whole = carry[i].floor();
            carry[i] -= whole;
            let k = whole as u64;
            for j in 0..k {
                arrivals.push((t0 + dt * j as f64 / k as f64, i));
            }
        }
        // The active-set tier at materialization granularity: when the
        // stream can bound its support, each busy tick draws and walks
        // only those agents. Everyone outside the support draws count
        // `0.0` at every tick, so its carry cell stays exactly `+0.0`
        // and materializes nothing — bit-for-bit what the dense walk
        // computes for it.
        let support = if skip_idle { source.support() } else { None };
        let mut step = 0u64;
        while step < steps {
            if skip_idle {
                if let Some(until) = source.idle_until(step) {
                    let until = until.min(steps);
                    if until > step {
                        step = until;
                        continue;
                    }
                }
            }
            // Recorded burst microstructure replaces the carry walk for
            // this tick: the events *are* the tick's arrivals, injected
            // at their recorded timestamps (count copies each — the
            // writer coalesces identical arrivals).
            if source.bursts(step, burst) {
                for e in burst.iter() {
                    for _ in 0..(e.count as u64) {
                        arrivals.push((e.t_s, e.agent as usize));
                    }
                }
                step += 1;
                continue;
            }
            let t0 = step as f64 * dt;
            match &support {
                Some(sup) => {
                    source.next_support(step, dt, sup, &mut rates[..],
                                        &mut counts[..]);
                    for &i in sup.iter() {
                        materialize(i, t0, dt, carry, counts, arrivals);
                    }
                }
                None => {
                    source.next(step, dt, &mut rates[..],
                                &mut counts[..]);
                    for i in 0..n {
                        materialize(i, t0, dt, carry, counts, arrivals);
                    }
                }
            }
            step += 1;
        }
        arrivals.sort_unstable_by(|a, b| {
            a.0.partial_cmp(&b.0).expect("finite arrival times")
                .then(a.1.cmp(&b.1))
        });

        let mut executor = CostModelExecutor::new(
            &self.registry, self.cfg.dispatch_overhead_s);
        let mut core = ServingCore::<VirtualClock, _>::new(
            self.registry.clone(), policy, self.cfg.alloc_window_s,
            self.cfg.capacity, vec![self.cfg.max_batch.max(1); n], true);
        if record {
            core.enable_recorder(dt);
        }

        // Fault layer: inert configs are dropped at construction so the
        // no-fault path stays bit-identical (same branches taken, no
        // extra float op or draw).
        let faults = self.cfg.faults.as_ref().filter(|f| !f.is_inert());
        if let Some(f) = faults {
            core.set_retry(f.retry.clone());
        }
        // Per-dispatch fault checks drive a monotone-time cursor (the
        // serving `now` never decreases) instead of rescanning the whole
        // plan on every attempt; answers are identical to
        // `ServingFaults::fails_at`.
        let mut fault_cursor = faults.map(ServingFaultCursor::new);
        let admission = faults.and_then(|f| f.admission.as_ref());
        let weights: Vec<f64> = if admission.is_some() {
            self.registry.profiles().iter()
                .map(|p| p.priority.weight()).collect()
        } else {
            Vec::new()
        };
        let mut shed = vec![0u64; n];
        let mut lost_s = 0.0f64;
        let mut failed = 0u64;
        let offered = arrivals.len() as u64;

        let mut now = 0.0f64;
        let mut next = 0usize;
        core.window_due(now); // anchor the first window at t = 0

        loop {
            // 1. Inject every arrival due by `now`, through admission
            //    control when one is configured.
            while next < arrivals.len() && arrivals[next].0 <= now {
                let (t, agent) = arrivals[next];
                next += 1;
                if let Some(ac) = admission {
                    let total: usize = queues.iter().map(|q| q.len()).sum();
                    if total >= ac.max_queued {
                        match ac.policy {
                            ShedPolicy::DropNewest => {
                                shed[agent] += 1;
                                continue;
                            }
                            ShedPolicy::DropByPriority => {
                                // Shed from the worst-weight backlog
                                // (Low=3 before Medium=2 before High=1);
                                // ties favor shedding the incoming
                                // request, then the longer queue, then
                                // the lowest agent id. A queued victim
                                // loses its newest request so the
                                // incoming one is admitted.
                                let mut victim = agent;
                                let mut vw = weights[agent];
                                let mut vlen = queues[agent].len() + 1;
                                for i in 0..n {
                                    if queues[i].is_empty() {
                                        continue;
                                    }
                                    let better = weights[i] > vw
                                        || (weights[i] == vw
                                            && queues[i].len() > vlen);
                                    if better {
                                        victim = i;
                                        vw = weights[i];
                                        vlen = queues[i].len();
                                    }
                                }
                                shed[victim] += 1;
                                if victim == agent {
                                    continue;
                                }
                                queues[victim].pop_back();
                            }
                            ShedPolicy::DeadlineAware => {
                                // Expire queue heads already older than
                                // the deadline; if nothing is stale the
                                // incoming request is shed instead.
                                let cutoff = now - ac.deadline_s;
                                let mut freed = 0u64;
                                for (i, q) in queues.iter_mut()
                                    .enumerate()
                                {
                                    while q.front()
                                        .is_some_and(|e| *e < cutoff)
                                    {
                                        q.pop_front();
                                        shed[i] += 1;
                                        freed += 1;
                                    }
                                }
                                if freed == 0 {
                                    shed[agent] += 1;
                                    continue;
                                }
                            }
                        }
                    }
                }
                queues[agent].push_back(t);
                window_arrivals[agent] += 1;
                core.record_enqueue(agent, t);
            }

            // 2. Allocation-window rollover, exactly as the threaded
            //    shell does it between batches.
            if core.window_due(now) {
                for i in 0..n {
                    depths[i] = queues[i].len() as f64;
                }
                core.reallocate(now, &window_arrivals[..], &depths[..]);
                for w in window_arrivals.iter_mut() {
                    *w = 0;
                }
            }

            // 3. Pick a backlogged agent; idle GPU fast-forwards to the
            //    next arrival.
            let mut any = false;
            for i in 0..n {
                backlogged[i] = !queues[i].is_empty();
                any |= backlogged[i];
            }
            if !any {
                if next < arrivals.len() {
                    now = now.max(arrivals[next].0);
                    continue;
                }
                break; // drained: the run is over
            }
            let agent = core.pick(&backlogged[..])
                .expect("backlog implies a pick");

            // 4. Dynamic batch pop + cost-model execution; the serialized
            //    GPU advances virtual time by the service span.
            let b = queues[agent].len().min(core.max_batch(agent));
            batch.clear();
            for _ in 0..b {
                batch.push(queues[agent].pop_front().expect("b <= len"));
            }
            // A dispatch landing inside a fault window fails
            // transiently; the core's shared retry/backoff semantic
            // (the same one the threaded server routes failures
            // through) decides whether to re-dispatch or give up.
            let mut attempt = 0u32;
            loop {
                let injected = fault_cursor.as_mut()
                    .is_some_and(|c| c.fails_at(now, agent));
                let (service_s, result) = executor.execute(agent,
                                                           &batch[..]);
                now += service_s;
                if !injected && result.is_ok() {
                    core.record_batch(agent, b, service_s);
                    for t_enq in batch.iter() {
                        core.record_completion(agent, now - t_enq);
                    }
                    break;
                }
                match core.on_batch_failure(agent, b, service_s, attempt) {
                    Some(backoff_s) => {
                        lost_s += service_s + backoff_s;
                        now += backoff_s;
                        attempt += 1;
                    }
                    None => {
                        lost_s += service_s;
                        failed += b as u64;
                        break;
                    }
                }
            }
        }

        let resilience = faults.map(|_| {
            let shed_total: u64 = shed.iter().sum();
            let frac = |x: u64| {
                if offered > 0 { x as f64 / offered as f64 } else { 0.0 }
            };
            ResilienceReport {
                recovery_time_s: lost_s,
                shed_fraction: frac(shed_total),
                retried: core.retried_batches(),
                goodput: core.total_completed() as f64 / now.max(1e-9),
                disruption: frac(failed),
            }
        });
        let recorder = core.take_recorder();
        (ServingResult {
            policy: core.policy_name().to_string(),
            per_agent: core.agent_stats(),
            latency: core.latency_histograms(),
            mean_latency_s: core.mean_latencies(),
            total_completed: core.total_completed(),
            gpu_busy_s: core.gpu_busy_seconds(),
            makespan_s: now,
            windows: core.windows_closed(),
            last_allocation: core.last_allocation().to_vec(),
            allocation_trajectory: core.take_trajectory(),
            shed,
            resilience,
            workflow: None,
        }, recorder)
    }

    /// Native DAG execution in virtual time: releases become root-stage
    /// requests, a completing batch's virtual `now` is the enqueue time
    /// of any stage it unblocks, and end-to-end instance latency lands
    /// in [`WorkflowStats`]. Same queue path as [`run_inner`]: windowed
    /// allocator re-runs, stride picks, dynamic batching, fault
    /// injection with bounded retry (permanent failures strand the
    /// instance — started, never completed). Open loop by design, so
    /// admission control does not apply here.
    ///
    /// [`run_inner`]: ServingSimulator::run_inner
    fn run_workflow_inner<P>(&self, policy: &mut P,
                             wf: &WorkflowWorkload,
                             arena: &mut ServingArena) -> ServingResult
    where
        P: AllocationPolicy + ?Sized,
    {
        let n = self.registry.len();
        arena.reset(n);
        let ServingArena {
            queues, window_arrivals, depths, backlogged, batch, ..
        } = arena;

        let dt = self.cfg.arrival_dt_s;
        let steps = (self.cfg.duration_s / dt).round().max(1.0) as u64;
        let releases = wf.release_times(
            self.cfg.arrival_process, self.cfg.seed, steps, dt);

        let spec = &wf.spec;
        let k = spec.stages().len();
        // Discrete request count per stage: `ceil(work)`, at least one.
        let stage_requests: Vec<u32> = spec.stages().iter()
            .map(|s| (s.work.ceil() as u32).max(1))
            .collect();
        let unmet0: Vec<u32> = spec.stages().iter()
            .map(|s| s.deps.len() as u32)
            .collect();
        // Per-instance ledger: requests left per stage, unmet deps per
        // stage, live stage count.
        struct WfJob {
            release_s: f64,
            left: Vec<u32>,
            unmet: Vec<u32>,
            live: usize,
        }
        let mut jobs: Vec<WfJob> = releases.iter()
            .map(|&t| WfJob {
                release_s: t,
                left: stage_requests.clone(),
                unmet: unmet0.clone(),
                live: k,
            })
            .collect();
        let mut stats = WorkflowStats::new();
        stats.started = jobs.len() as u64;

        // (job, stage) meta per queued request, in lockstep with the
        // arena's per-agent FIFO queues.
        let mut meta: Vec<VecDeque<(usize, usize)>> =
            vec![VecDeque::new(); n];
        let mut batch_meta: Vec<(usize, usize)> = Vec::new();

        let mut executor = CostModelExecutor::new(
            &self.registry, self.cfg.dispatch_overhead_s);
        let mut core = ServingCore::<VirtualClock, _>::new(
            self.registry.clone(), policy, self.cfg.alloc_window_s,
            self.cfg.capacity, vec![self.cfg.max_batch.max(1); n], true);

        let faults = self.cfg.faults.as_ref().filter(|f| !f.is_inert());
        if let Some(f) = faults {
            core.set_retry(f.retry.clone());
        }
        let mut fault_cursor = faults.map(ServingFaultCursor::new);
        let mut offered = 0u64;
        let mut lost_s = 0.0f64;
        let mut failed = 0u64;

        let mut now = 0.0f64;
        let mut next = 0usize;
        core.window_due(now); // anchor the first window at t = 0

        loop {
            // 1. Release every instance due by `now`: its root stages'
            //    requests enqueue at the release time.
            while next < jobs.len() && jobs[next].release_s <= now {
                let t = jobs[next].release_s;
                for (s, stage) in spec.stages().iter().enumerate() {
                    if stage.deps.is_empty() {
                        for _ in 0..stage_requests[s] {
                            queues[stage.agent].push_back(t);
                            meta[stage.agent].push_back((next, s));
                            window_arrivals[stage.agent] += 1;
                            offered += 1;
                        }
                    }
                }
                next += 1;
            }

            // 2. Allocation-window rollover, as in the plain path.
            if core.window_due(now) {
                for i in 0..n {
                    depths[i] = queues[i].len() as f64;
                }
                core.reallocate(now, &window_arrivals[..], &depths[..]);
                for w in window_arrivals.iter_mut() {
                    *w = 0;
                }
            }

            // 3. Pick a backlogged agent; an idle GPU fast-forwards to
            //    the next instance release.
            let mut any = false;
            for i in 0..n {
                backlogged[i] = !queues[i].is_empty();
                any |= backlogged[i];
            }
            if !any {
                if next < jobs.len() {
                    now = now.max(jobs[next].release_s);
                    continue;
                }
                break; // no queued work, no future releases: done
            }
            let agent = core.pick(&backlogged[..])
                .expect("backlog implies a pick");

            // 4. Dynamic batch pop + cost-model execution; a successful
            //    batch advances the DAG bookkeeping.
            let b = queues[agent].len().min(core.max_batch(agent));
            batch.clear();
            batch_meta.clear();
            for _ in 0..b {
                batch.push(queues[agent].pop_front().expect("b <= len"));
                batch_meta.push(meta[agent].pop_front()
                    .expect("meta in lockstep"));
            }
            let mut attempt = 0u32;
            loop {
                let injected = fault_cursor.as_mut()
                    .is_some_and(|c| c.fails_at(now, agent));
                let (service_s, result) = executor.execute(agent,
                                                           &batch[..]);
                now += service_s;
                if !injected && result.is_ok() {
                    core.record_batch(agent, b, service_s);
                    for t_enq in batch.iter() {
                        core.record_completion(agent, now - t_enq);
                    }
                    for &(j, s) in batch_meta.iter() {
                        jobs[j].left[s] -= 1;
                        if jobs[j].left[s] > 0 {
                            continue;
                        }
                        // Stage complete: finish the instance or unblock
                        // successors at this batch's virtual `now`.
                        jobs[j].live -= 1;
                        if jobs[j].live == 0 {
                            stats.record(now - jobs[j].release_s);
                            continue;
                        }
                        for (s2, st2) in spec.stages().iter().enumerate()
                            .skip(s + 1)
                        {
                            if !st2.deps.contains(&s) {
                                continue;
                            }
                            jobs[j].unmet[s2] -= 1;
                            if jobs[j].unmet[s2] == 0 {
                                for _ in 0..stage_requests[s2] {
                                    queues[st2.agent].push_back(now);
                                    meta[st2.agent].push_back((j, s2));
                                    window_arrivals[st2.agent] += 1;
                                    offered += 1;
                                }
                            }
                        }
                    }
                    break;
                }
                match core.on_batch_failure(agent, b, service_s, attempt) {
                    Some(backoff_s) => {
                        lost_s += service_s + backoff_s;
                        now += backoff_s;
                        attempt += 1;
                    }
                    None => {
                        // Dropped for good: the stage never completes,
                        // so the instance stays started-not-completed.
                        lost_s += service_s;
                        failed += b as u64;
                        break;
                    }
                }
            }
        }

        let resilience = faults.map(|_| {
            let frac = |x: u64| {
                if offered > 0 { x as f64 / offered as f64 } else { 0.0 }
            };
            ResilienceReport {
                recovery_time_s: lost_s,
                shed_fraction: 0.0,
                retried: core.retried_batches(),
                goodput: core.total_completed() as f64 / now.max(1e-9),
                disruption: frac(failed),
            }
        });
        ServingResult {
            policy: core.policy_name().to_string(),
            per_agent: core.agent_stats(),
            latency: core.latency_histograms(),
            mean_latency_s: core.mean_latencies(),
            total_completed: core.total_completed(),
            gpu_busy_s: core.gpu_busy_seconds(),
            makespan_s: now,
            windows: core.windows_closed(),
            last_allocation: core.last_allocation().to_vec(),
            allocation_trajectory: core.take_trajectory(),
            shed: vec![0; n],
            resilience,
            workflow: Some(stats),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocator::{AdaptivePolicy, PolicyKind};

    fn light_cfg() -> ServingConfig {
        // Under-loaded so queues drain fast and the run stays tiny.
        let mut cfg = ServingConfig::paper();
        cfg.arrival_rates = vec![20.0, 10.0, 10.0, 5.0];
        cfg.duration_s = 2.0;
        cfg
    }

    #[test]
    fn serves_every_request_exactly_once() {
        let sim = ServingSimulator::with_registry(light_cfg(),
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        // Poisson at these rates over 2 s: roughly 90 arrivals.
        assert!(r.total_completed > 40, "{}", r.total_completed);
        assert_eq!(r.total_completed,
                   r.per_agent.iter().map(|a| a.completed).sum::<u64>());
        for (a, h) in r.per_agent.iter().zip(&r.latency) {
            assert_eq!(a.completed, h.count(), "{}", a.name);
            if a.completed > 0 {
                assert!(a.p99_s >= a.p50_s, "{}", a.name);
                assert!(a.p50_s > 0.0, "{}", a.name);
            }
        }
        assert!(r.makespan_s > 0.0 && r.gpu_busy_s > 0.0);
        assert!(r.windows > 0, "allocator never ran");
        assert_eq!(r.allocation_trajectory.len(), r.windows as usize);
        let shares: f64 = r.per_agent.iter().map(|a| a.gpu_share).sum();
        assert!((shares - 1.0).abs() < 1e-6, "gpu shares sum to {shares}");
    }

    #[test]
    fn runs_are_bit_reproducible_and_arena_pure() {
        let sim = ServingSimulator::with_registry(light_cfg(),
                                                  AgentRegistry::paper());
        let mut arena = ServingArena::new();
        let fresh = sim.run(&mut AdaptivePolicy::default());
        for _ in 0..3 {
            let again =
                sim.run_with_arena(&mut AdaptivePolicy::default(),
                                   &mut arena);
            assert_eq!(again, fresh);
        }
        // A different-shaped run through the same arena leaves no state.
        let mut other_cfg = light_cfg();
        other_cfg.arrival_rates.truncate(2);
        other_cfg.seed = 7;
        let mut agents = crate::agents::AgentProfile::paper_agents();
        agents.truncate(2);
        let other = ServingSimulator::new(other_cfg, agents);
        let _ = other.run_with_arena(&mut AdaptivePolicy::default(),
                                     &mut arena);
        let again = sim.run_with_arena(&mut AdaptivePolicy::default(),
                                       &mut arena);
        assert_eq!(again, fresh);
    }

    #[test]
    fn trace_replay_matches_generated_run_of_same_stream() {
        // Recording the generator's stream and replaying it must serve
        // the same requests (same totals; timing identical because the
        // trace preserves dt and counts).
        let mut cfg = light_cfg();
        cfg.arrival_dt_s = 0.1;
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  AgentRegistry::paper());
        let generated = sim.run(&mut AdaptivePolicy::default());

        let names: Vec<String> = AgentRegistry::paper().profiles().iter()
            .map(|p| p.name.clone()).collect();
        let mut gen = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        let trace = Trace::record(&mut gen, names, 20, 0.1);
        let replayed =
            sim.run_trace(&mut AdaptivePolicy::default(), &trace);
        assert_eq!(replayed, generated);
    }

    #[test]
    fn binary_replay_is_bit_identical_to_csv_replay() {
        let cfg = light_cfg();
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  AgentRegistry::paper());
        let names: Vec<String> = AgentRegistry::paper().profiles().iter()
            .map(|p| p.name.clone()).collect();
        let mut gen = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        let trace = Trace::record(&mut gen, names, 20, 0.1);
        let bin = BinTrace::from_bytes(
            crate::workload::bintrace::trace_to_bytes(&trace).unwrap())
            .unwrap();
        let csv = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
        let binary = sim.run_source(&mut AdaptivePolicy::default(), &bin);
        assert_eq!(binary, csv);
        // The in-memory trace replays identically through the trait
        // path, and the dense reference agrees with the fast-forward.
        let via_trait =
            sim.run_source(&mut AdaptivePolicy::default(), &trace);
        assert_eq!(via_trait, csv);
        let dense =
            sim.run_source_dense(&mut AdaptivePolicy::default(), &bin);
        assert_eq!(dense, csv);
    }

    #[test]
    fn recorded_run_replays_bit_identically() {
        // The closure property: record a live run's queue timeline,
        // replay the dump, get the same run back — timestamps are
        // stored verbatim, so this is exact equality, not tolerance.
        let sim = ServingSimulator::with_registry(light_cfg(),
                                                  AgentRegistry::paper());
        let (original, recorded) =
            sim.run_recording(&mut AdaptivePolicy::default());
        assert_eq!(original, sim.run(&mut AdaptivePolicy::default()),
                   "recording must not perturb the run");
        assert_eq!(recorded.total_arrivals() as u64,
                   original.total_completed);
        let replayed =
            sim.run_source(&mut AdaptivePolicy::default(), &recorded);
        assert_eq!(replayed, original);
        let dense = sim.run_source_dense(&mut AdaptivePolicy::default(),
                                         &recorded);
        assert_eq!(dense, original);
    }

    #[test]
    fn batching_cap_one_pays_more_dispatch_overhead() {
        let mut cfg = light_cfg();
        cfg.max_batch = 1;
        let unbatched = ServingSimulator::with_registry(
            cfg.clone(), AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        cfg.max_batch = 8;
        let batched = ServingSimulator::with_registry(
            cfg, AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        assert_eq!(unbatched.total_completed, batched.total_completed);
        for a in &unbatched.per_agent {
            assert!(a.mean_batch <= 1.0 + 1e-12, "{}", a.name);
        }
        // Same requests, more dispatches → more GPU time consumed.
        assert!(unbatched.gpu_busy_s > batched.gpu_busy_s,
                "{} vs {}", unbatched.gpu_busy_s, batched.gpu_busy_s);
    }

    #[test]
    fn transient_single_failure_retries_to_zero_failed() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RetryPolicy};
        // A short eviction window at t = 0 fails the first dispatches;
        // bounded retry with 50 ms backoff escapes the 20 ms window, so
        // every request still completes and nothing counts as an error.
        // Deterministic arrivals guarantee a dispatch at t = 0.
        let mut cfg = light_cfg();
        cfg.arrival_process = ArrivalProcess::Deterministic;
        let plan = FaultPlan::new(vec![FaultEvent::GpuEviction {
            t: 0.0, gpu: 0, duration: 0.02,
        }]);
        cfg.faults = Some(ServingFaults::new(plan).with_retry(
            RetryPolicy { max_attempts: 4, backoff_s: 0.05,
                          backoff_multiplier: 2.0 }));
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        let rep = r.resilience.as_ref().expect("faults configured");
        assert!(rep.retried >= 1, "the fault window was never hit");
        assert_eq!(rep.disruption, 0.0, "no batch exhausted its retries");
        assert!(rep.recovery_time_s > 0.0);
        // Same offered load as the fault-free run, all of it served.
        cfg.faults = None;
        let clean = ServingSimulator::with_registry(
            cfg, AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        assert_eq!(r.total_completed, clean.total_completed);
    }

    #[test]
    fn shed_by_priority_never_sheds_high_before_lower() {
        use crate::sim::fault::{AdmissionControl, FaultPlan};
        // Overload driven by the Medium-priority agents; the High tiers
        // (coordinator, reasoning) must keep their requests.
        let mut cfg = ServingConfig::paper();
        cfg.arrival_rates = vec![5.0, 200.0, 200.0, 5.0];
        cfg.duration_s = 2.0;
        cfg.faults = Some(ServingFaults::new(FaultPlan::empty())
            .with_admission(AdmissionControl::new(
                32, ShedPolicy::DropByPriority)));
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        let rep = r.resilience.as_ref().expect("admission configured");
        assert!(rep.shed_fraction > 0.0, "overload never tripped the cap");
        assert!(r.shed[1] + r.shed[2] > 0, "mediums were never shed");
        assert_eq!(r.shed[0], 0, "High-priority coordinator was shed");
        assert_eq!(r.shed[3], 0, "High-priority reasoning was shed");
    }

    #[test]
    fn drop_newest_with_zero_budget_sheds_everything() {
        use crate::sim::fault::{AdmissionControl, FaultPlan};
        let mut cfg = light_cfg();
        cfg.faults = Some(ServingFaults::new(FaultPlan::empty())
            .with_admission(AdmissionControl::new(
                0, ShedPolicy::DropNewest)));
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(r.total_completed, 0);
        let rep = r.resilience.as_ref().expect("admission configured");
        assert!((rep.shed_fraction - 1.0).abs() < 1e-12,
                "{}", rep.shed_fraction);
        assert_eq!(rep.goodput, 0.0);
    }

    #[test]
    fn deadline_aware_sheds_stale_heads_for_fresh_arrivals() {
        use crate::sim::fault::{AdmissionControl, FaultPlan};
        // Tight queue bound + overload: stale queue heads expire in
        // favor of fresh arrivals, so completions still happen and the
        // shed mass lands on whoever went stale — strictly fewer
        // completions than the unbounded run, but not zero.
        let mut cfg = ServingConfig::paper();
        cfg.duration_s = 2.0;
        let mut adm = AdmissionControl::new(16, ShedPolicy::DeadlineAware);
        adm.deadline_s = 0.05;
        cfg.faults = Some(ServingFaults::new(FaultPlan::empty())
            .with_admission(adm));
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        let rep = r.resilience.as_ref().expect("admission configured");
        assert!(rep.shed_fraction > 0.0);
        assert!(r.total_completed > 0, "everything was shed");
    }

    #[test]
    fn zero_fault_serving_is_bit_identical_to_plain() {
        use crate::sim::fault::FaultPlan;
        let mut cfg = light_cfg();
        cfg.faults = Some(ServingFaults::new(FaultPlan::empty()));
        let faulted = ServingSimulator::with_registry(
            cfg.clone(), AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        cfg.faults = None;
        let plain = ServingSimulator::with_registry(
            cfg, AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        assert_eq!(faulted, plain, "inert fault config changed the run");
        assert!(faulted.resilience.is_none());
    }

    /// Burst-only schedule: all traffic is a mid-run burst by agents 1
    /// and 3, so the materialization loop has real idle stretches to
    /// fast-forward.
    fn burst_cfg() -> ServingConfig {
        let mut cfg = ServingConfig::paper();
        cfg.arrival_rates = vec![0.0, 20.0, 0.0, 10.0];
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![1, 3], start: 5, end: 10,
        };
        cfg.duration_s = 2.0;
        cfg
    }

    #[test]
    fn skip_idle_materialization_is_bit_exact_with_dense() {
        // Deterministic and Poisson arrivals, several policies:
        // run() (fast-forward on) must equal run_dense() exactly.
        for process in [ArrivalProcess::Deterministic,
                        ArrivalProcess::Poisson] {
            let mut cfg = burst_cfg();
            cfg.arrival_process = process;
            let sim = ServingSimulator::with_registry(
                cfg, AgentRegistry::paper());
            for make in [PolicyKind::adaptive, PolicyKind::static_equal] {
                let skip = sim.run(&mut make());
                let dense = sim.run_dense(&mut make());
                assert_eq!(skip, dense, "{process:?} {}", skip.policy);
                assert!(skip.total_completed > 0, "burst never served");
            }
        }
        // All-zero schedule: nothing arrives, nothing runs, still equal.
        let mut cfg = burst_cfg();
        cfg.arrival_rates = vec![0.0; 4];
        cfg.workload_kind = WorkloadKind::Steady;
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let skip = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(skip, sim.run_dense(&mut AdaptivePolicy::default()));
        assert_eq!(skip.total_completed, 0);
    }

    #[test]
    fn skip_idle_materialization_is_bit_exact_under_faults() {
        use crate::sim::fault::{FaultEvent, FaultPlan};
        // A fault window inside the burst: the monotone fault cursor and
        // the fast-forward must both leave the run bit-identical to the
        // dense path.
        let mut cfg = burst_cfg();
        cfg.faults = Some(ServingFaults::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 0.55, gpu: 0, duration: 0.02 },
        ])));
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let skip = sim.run(&mut AdaptivePolicy::default());
        assert_eq!(skip, sim.run_dense(&mut AdaptivePolicy::default()));
        assert!(skip.resilience.is_some());
    }

    /// Wide sparse deployment: `n` agents, arrivals only ever on `hot`
    /// — the support-set materialization walk covers `hot` alone.
    fn sparse_serving(n: usize, hot: &[usize])
                      -> (ServingConfig, AgentRegistry) {
        use crate::agents::Priority;
        let profiles: Vec<AgentProfile> = (0..n)
            .map(|i| AgentProfile {
                name: format!("a{i}"),
                model_mb: 800,
                base_tput: 40.0 + (i % 3) as f64 * 10.0,
                min_gpu: 0.0,
                priority: Priority::Medium,
            })
            .collect();
        let registry = AgentRegistry::new(profiles).unwrap();
        let mut cfg = ServingConfig::paper();
        cfg.arrival_rates = vec![0.0; n];
        for &i in hot {
            cfg.arrival_rates[i] = 10.0;
        }
        cfg.duration_s = 2.0;
        (cfg, registry)
    }

    #[test]
    fn support_set_materialization_is_bit_exact_with_dense() {
        // Steady sparse load: no idle windows to jump, so every tick is
        // busy and only the support walk separates run() from
        // run_dense(). Both processes, two policies.
        for process in [ArrivalProcess::Deterministic,
                        ArrivalProcess::Poisson] {
            let (mut cfg, reg) = sparse_serving(16, &[3, 11]);
            cfg.arrival_process = process;
            let sim = ServingSimulator::with_registry(cfg, reg);
            for make in [PolicyKind::adaptive, PolicyKind::static_equal] {
                let sparse = sim.run(&mut make());
                let dense = sim.run_dense(&mut make());
                assert_eq!(sparse, dense, "{process:?} {}", sparse.policy);
                assert!(sparse.total_completed > 0, "hot agents starved");
                assert_eq!(sparse.per_agent[0].completed, 0);
                assert_eq!(sparse.per_agent[3].completed
                               + sparse.per_agent[11].completed,
                           sparse.total_completed);
            }
        }
    }

    #[test]
    fn support_set_materialization_is_bit_exact_under_faults() {
        use crate::sim::fault::{FaultEvent, FaultPlan};
        // Support walk + idle jump + fault cursor together: a burst by
        // the two hot agents with an eviction window inside it.
        let (mut cfg, reg) = sparse_serving(16, &[3, 11]);
        cfg.workload_kind = WorkloadKind::Burst {
            agents: vec![3, 11], start: 5, end: 10,
        };
        cfg.faults = Some(ServingFaults::new(FaultPlan::new(vec![
            FaultEvent::GpuEviction { t: 0.55, gpu: 0, duration: 0.02 },
        ])));
        let sim = ServingSimulator::with_registry(cfg, reg);
        let sparse = sim.run(&mut AdaptivePolicy::default());
        let dense = sim.run_dense(&mut AdaptivePolicy::default());
        assert_eq!(sparse, dense);
        assert!(sparse.total_completed > 0, "burst never served");
        assert!(sparse.resilience.is_some());
    }

    #[test]
    fn trace_replay_matches_support_set_generated_run() {
        // Recording the sparse stream and replaying it row-dense must
        // reproduce the support-set generated run exactly — the two
        // materialization modes meet on the same arrival list.
        let (cfg, reg) = sparse_serving(8, &[2, 5]);
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  reg.clone());
        let generated = sim.run(&mut AdaptivePolicy::default());

        let names: Vec<String> = reg.profiles().iter()
            .map(|p| p.name.clone()).collect();
        let mut gen = WorkloadGenerator::new(
            cfg.arrival_rates.clone(), cfg.workload_kind.clone(),
            cfg.arrival_process, cfg.seed);
        let steps = (cfg.duration_s / cfg.arrival_dt_s).round() as u64;
        let trace = Trace::record(&mut gen, names, steps,
                                  cfg.arrival_dt_s);
        let replayed =
            sim.run_trace(&mut AdaptivePolicy::default(), &trace);
        assert_eq!(replayed, generated);
    }

    #[test]
    fn trace_replay_skip_idle_is_bit_exact_with_dense() {
        // Zero rows on both sides of a recorded active window: the trace
        // stream's idle oracle jumps them, bit-exactly.
        let zeros = vec![0.0; 4];
        let mut rows = vec![zeros.clone(); 6];
        rows.extend(vec![vec![2.0, 1.0, 0.0, 1.0]; 4]);
        rows.extend(vec![zeros; 6]);
        let names = (0..4).map(|i| format!("a{i}")).collect();
        let trace = Trace::new(names, 0.1, rows).unwrap();
        let sim = ServingSimulator::with_registry(light_cfg(),
                                                  AgentRegistry::paper());
        let skip = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
        let dense =
            sim.run_trace_dense(&mut AdaptivePolicy::default(), &trace);
        assert_eq!(skip, dense);
        assert_eq!(skip.total_completed, 16);
    }

    #[test]
    #[should_panic(expected = "trace error")]
    fn run_trace_panics_on_ragged_rows() {
        // A hand-built ragged trace must be rejected up front with the
        // labelled trace error, not die on copy_from_slice mid-run.
        let trace = Trace {
            agents: (0..4).map(|i| format!("a{i}")).collect(),
            dt: 0.1,
            counts: vec![vec![1.0; 4], vec![1.0; 3], vec![1.0; 4]],
        };
        let sim = ServingSimulator::with_registry(light_cfg(),
                                                  AgentRegistry::paper());
        let _ = sim.run_trace(&mut AdaptivePolicy::default(), &trace);
    }

    #[test]
    fn policies_differentiate_at_queue_granularity() {
        // Under overload the adaptive policy holds reasoning (high
        // priority, g ≈ 0.296) above static-equal's flat 25%, so its
        // requests drain measurably faster through the real queue path.
        let mut cfg = ServingConfig::paper();
        cfg.duration_s = 5.0;
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let adaptive = sim.run(&mut PolicyKind::adaptive());
        let stat = sim.run(&mut PolicyKind::static_equal());
        assert!(adaptive.mean_latency_s[3] < stat.mean_latency_s[3],
                "reasoning under adaptive {} vs static {}",
                adaptive.mean_latency_s[3], stat.mean_latency_s[3]);
        // And the schedules genuinely differ across the board.
        assert_ne!(adaptive.mean_latency_s, stat.mean_latency_s);
    }

    #[test]
    fn workflow_runs_natively_and_reproducibly() {
        use crate::workload::WorkflowWorkload;
        let mut cfg = ServingConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::paper());
        let sim = ServingSimulator::with_registry(cfg.clone(),
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        let wf = r.workflow.as_ref().expect("workflow configured");
        assert!(wf.started > 0, "no instances released");
        assert!(wf.completed > 0, "open-loop run must drain every DAG");
        assert!(wf.mean_s() > 0.0);
        assert!(wf.p99_s() >= wf.mean_s() - 1e-9);
        // Completions happened through the real queue path.
        assert!(r.total_completed > 0 && r.gpu_busy_s > 0.0);
        // Bit-reproducible, and identical through run_dense (the
        // workflow path has no idle windows to skip).
        assert_eq!(r, sim.run(&mut AdaptivePolicy::default()));
        assert_eq!(r, sim.run_dense(&mut AdaptivePolicy::default()));
        // A plain run surfaces no workflow stats.
        cfg.workflow = None;
        let plain = ServingSimulator::with_registry(
            cfg, AgentRegistry::paper())
            .run(&mut AdaptivePolicy::default());
        assert!(plain.workflow.is_none());
    }

    #[test]
    fn workflow_stages_enqueue_only_after_upstream_completes() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        // chain 0 -> 1 at 0.5/s deterministic over 10 s: exactly 5
        // instances, one request per stage, and agent 1 only ever sees
        // requests unblocked by agent 0's completions.
        let mut cfg = ServingConfig::paper();
        cfg.arrival_process = ArrivalProcess::Deterministic;
        cfg.workflow = Some(WorkflowWorkload::new(
            WorkflowSpec::chain("c2", &[0, 1]), 0.5));
        let sim = ServingSimulator::with_registry(cfg,
                                                  AgentRegistry::paper());
        let r = sim.run(&mut AdaptivePolicy::default());
        let wf = r.workflow.as_ref().expect("workflow configured");
        assert_eq!(wf.started, 5);
        assert_eq!(wf.completed, 5, "every chain must finish");
        assert_eq!(r.per_agent[0].completed, 5);
        assert_eq!(r.per_agent[1].completed, 5);
        assert_eq!(r.per_agent[2].completed, 0);
        assert_eq!(r.per_agent[3].completed, 0);
        assert_eq!(r.total_completed, 10);
        // End-to-end latency covers both stages' service, so it exceeds
        // the downstream stage's own queue latency.
        assert!(wf.mean_s() > r.mean_latency_s[1]);
    }

    #[test]
    #[should_panic(expected = "config error")]
    fn workflow_spec_must_fit_the_registry() {
        use crate::workload::{WorkflowSpec, WorkflowWorkload};
        let mut cfg = ServingConfig::paper();
        cfg.workflow = Some(WorkflowWorkload::new(
            WorkflowSpec::chain("too-wide", &[0, 9]), 0.5));
        let _ = ServingSimulator::with_registry(cfg,
                                                AgentRegistry::paper());
    }
}
