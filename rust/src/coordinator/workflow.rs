//! The coordinator → specialists → coordinator workflow: the threaded
//! server's live execution of the same [`WorkflowSpec`] DAGs the
//! simulation engines sweep — [`ReasoningPipeline::run`] is a thin
//! shell that maps a [`TaskKind`] to its spec and walks the DAG level
//! by level against a running [`AgentServer`].

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::server::{AgentServer, CompletedRequest};
use crate::util::Rng;
use crate::workload::WorkflowSpec;

/// Registry-index → agent-name mapping for the paper deployment, in
/// Table I order (workflow specs address agents by index; the server
/// addresses them by name).
const PAPER_AGENT_NAMES: [&str; 4] =
    ["coordinator", "nlp", "vision", "reasoning"];

/// Per-level prompt-seed salts, preserved from the original hard-coded
/// pipeline: the plan level uses the task seed unsalted, the specialist
/// level salts with `0x5eed`, the aggregation level with `0xa99`.
/// Deeper chains keep drawing distinct deterministic salts.
fn level_salt(level: usize) -> u64 {
    match level {
        0 => 0,
        1 => 0x5eed,
        2 => 0xa99,
        l => 0xa99 ^ ((l as u64) << 16),
    }
}

/// What kind of collaborative task a request is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Route to the NLP specialist.
    Nlp,
    /// Route to the vision specialist.
    Vision,
    /// Route to the reasoning specialist.
    Reasoning,
    /// Fan out to all three specialists and aggregate.
    MultiDomain,
}

impl TaskKind {
    /// Specialists this kind involves, in execution order.
    pub fn specialists(self) -> &'static [&'static str] {
        match self {
            TaskKind::Nlp => &["nlp"],
            TaskKind::Vision => &["vision"],
            TaskKind::Reasoning => &["reasoning"],
            TaskKind::MultiDomain => &["nlp", "vision", "reasoning"],
        }
    }

    /// Deterministic task mix used by examples/benches: a realistic blend
    /// skewed toward single-specialist tasks.
    pub fn sample(rng: &mut Rng) -> TaskKind {
        match rng.below(10) {
            0..=3 => TaskKind::Nlp,
            4..=6 => TaskKind::Vision,
            7..=8 => TaskKind::Reasoning,
            _ => TaskKind::MultiDomain,
        }
    }

    /// The workflow DAG this task kind executes: a plan → fan-out →
    /// aggregate spec over the paper deployment's agent indices — the
    /// same [`WorkflowSpec`] shape the simulation engines sweep, so the
    /// threaded server and the virtual-time engines run one definition.
    pub fn spec(self) -> WorkflowSpec {
        match self {
            TaskKind::Nlp => WorkflowSpec::fan_out("nlp", 0, &[1]),
            TaskKind::Vision => WorkflowSpec::fan_out("vision", 0, &[2]),
            TaskKind::Reasoning =>
                WorkflowSpec::fan_out("reasoning", 0, &[3]),
            TaskKind::MultiDomain => WorkflowSpec::paper(),
        }
    }
}

/// One completed stage of a workflow.
#[derive(Debug, Clone)]
pub struct StageResult {
    /// Agent that ran the stage.
    pub agent: String,
    /// The stage's greedy next-token output.
    pub next_token: i32,
    /// Enqueue → completion time for this stage.
    pub latency: Duration,
    /// Batch the stage rode in.
    pub batch_size: usize,
}

/// A completed collaborative task.
#[derive(Debug, Clone)]
pub struct WorkflowResult {
    /// Task kind executed.
    pub kind: TaskKind,
    /// Per-stage results: plan, specialist(s), aggregate.
    pub stages: Vec<StageResult>,
    /// End-to-end wall time.
    pub total: Duration,
}

impl WorkflowResult {
    /// Sum of per-stage serving latencies (excludes client-side gaps).
    pub fn serving_latency(&self) -> Duration {
        self.stages.iter().map(|s| s.latency).sum()
    }

    /// The final aggregated answer token.
    pub fn answer(&self) -> i32 {
        self.stages.last().map(|s| s.next_token).unwrap_or(-1)
    }
}

/// Runs collaborative tasks through an [`AgentServer`].
#[derive(Debug)]
pub struct ReasoningPipeline {
    seq_len: usize,
    /// Per-agent vocab sizes, used to clamp tokens between stages.
    vocabs: Vec<(String, usize)>,
}

impl ReasoningPipeline {
    /// Build over a running server.
    pub fn new(server: &AgentServer, vocabs: Vec<(String, usize)>)
               -> ReasoningPipeline {
        ReasoningPipeline { seq_len: server.seq_len(), vocabs }
    }

    fn vocab_of(&self, agent: &str) -> Result<usize> {
        self.vocabs.iter().find(|(n, _)| n == agent).map(|(_, v)| *v)
            .ok_or_else(|| Error::Serving(format!(
                "agent '{agent}' missing from pipeline vocab table")))
    }

    /// Build a prompt for `agent` from a task seed plus upstream stage
    /// outputs: deterministic filler tokens with the upstream answers
    /// spliced into the tail (folded into the agent's vocab).
    pub fn prompt(&self, agent_vocab: usize, seed: u64, upstream: &[i32])
                  -> Vec<i32> {
        let mut tokens: Vec<i32> = (0..self.seq_len).map(|i| {
            ((seed.wrapping_mul(31).wrapping_add(i as u64 * 7 + 3))
             % agent_vocab as u64) as i32
        }).collect();
        let tail = self.seq_len.saturating_sub(upstream.len());
        for (slot, tok) in tokens[tail..].iter_mut().zip(upstream) {
            *slot = tok.rem_euclid(agent_vocab as i32);
        }
        tokens
    }

    /// Execute one collaborative task — coordinator plan → specialist
    /// fan-out → coordinator aggregation — by walking the kind's
    /// [`WorkflowSpec`] against the server.
    pub fn run(&self, server: &AgentServer, kind: TaskKind, seed: u64)
               -> Result<WorkflowResult> {
        let start = Instant::now();
        let stages = self.run_spec(server, &kind.spec(), seed)?;
        Ok(WorkflowResult { kind, stages, total: start.elapsed() })
    }

    /// Execute an arbitrary [`WorkflowSpec`] level by level: stages in
    /// the same dependency level fan out concurrently (submit all, then
    /// collect in stage order — the server's governor interleaves them
    /// under the allocator's shares); each level's prompts splice every
    /// completed stage's answer token into the tail, salted per level.
    /// Stage agent indices resolve through the paper deployment's
    /// Table I names.
    pub fn run_spec(&self, server: &AgentServer, spec: &WorkflowSpec,
                    seed: u64) -> Result<Vec<StageResult>> {
        let stages = spec.stages();
        // Dependency level per stage (specs are topologically ordered,
        // so every dep's level is computed before its dependents').
        let mut level = vec![0usize; stages.len()];
        for i in 0..stages.len() {
            level[i] = stages[i].deps.iter().map(|&d| level[d] + 1)
                .max().unwrap_or(0);
        }
        let n_levels = level.iter().max().map_or(0, |l| l + 1);

        let mut results = Vec::with_capacity(stages.len());
        let mut upstream: Vec<i32> = Vec::new();
        for lv in 0..n_levels {
            let salt = level_salt(lv);
            let mut pending = Vec::new();
            for (i, st) in stages.iter().enumerate() {
                if level[i] != lv {
                    continue;
                }
                let name = PAPER_AGENT_NAMES.get(st.agent).copied()
                    .ok_or_else(|| Error::Serving(format!(
                        "workflow spec '{}' stage agent {} is outside \
                         the paper deployment", spec.name(), st.agent)))?;
                let vocab = self.vocab_of(name)?;
                let prompt = self.prompt(vocab, seed ^ salt, &upstream);
                pending.push((name, server.submit(name, prompt)?));
            }
            let mut completed = Vec::with_capacity(pending.len());
            for (name, rx) in pending {
                let done = collect_stage(name, &rx)?;
                completed.push(done.next_token);
                results.push(StageResult {
                    agent: done.agent,
                    next_token: done.next_token,
                    latency: done.latency,
                    batch_size: done.batch_size,
                });
            }
            upstream.extend(completed);
        }
        Ok(results)
    }
}

/// Wait for one specialist stage. A worker that panics or shuts down
/// mid-stage drops its reply sender; that surfaces here as a labelled
/// error rather than a hang (`recv` returns immediately once the
/// sending side is gone).
fn collect_stage(name: &str, rx: &Receiver<Result<CompletedRequest>>)
                 -> Result<CompletedRequest> {
    rx.recv().map_err(|_| Error::Serving(
        format!("{name} stage dropped")))?
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_kinds_route_to_expected_specialists() {
        assert_eq!(TaskKind::Nlp.specialists(), ["nlp"]);
        assert_eq!(TaskKind::MultiDomain.specialists(),
                   ["nlp", "vision", "reasoning"]);
    }

    #[test]
    fn task_mix_is_deterministic_and_covers_all_kinds() {
        let mut rng = Rng::new(7);
        let kinds: Vec<TaskKind> =
            (0..200).map(|_| TaskKind::sample(&mut rng)).collect();
        let mut rng2 = Rng::new(7);
        let again: Vec<TaskKind> =
            (0..200).map(|_| TaskKind::sample(&mut rng2)).collect();
        assert_eq!(kinds, again);
        for kind in [TaskKind::Nlp, TaskKind::Vision, TaskKind::Reasoning,
                     TaskKind::MultiDomain] {
            assert!(kinds.contains(&kind), "{kind:?} never sampled");
        }
    }

    #[test]
    fn task_kind_specs_mirror_their_specialist_tables() {
        for kind in [TaskKind::Nlp, TaskKind::Vision, TaskKind::Reasoning,
                     TaskKind::MultiDomain] {
            let spec = kind.spec();
            let stages = spec.stages();
            // Coordinator-bracketed: plan + specialists + aggregate.
            assert_eq!(stages.len(), kind.specialists().len() + 2);
            assert_eq!(stages[0].agent, 0);
            assert_eq!(stages.last().unwrap().agent, 0);
            let mids: Vec<&str> = stages[1..stages.len() - 1].iter()
                .map(|st| PAPER_AGENT_NAMES[st.agent]).collect();
            assert_eq!(mids, kind.specialists(), "{kind:?}");
            spec.validate_for(PAPER_AGENT_NAMES.len())
                .expect("paper specs fit the deployment");
        }
    }

    #[test]
    fn level_salts_preserve_the_original_pipeline_seeds() {
        // The hard-coded pipeline salted plan/specialist/aggregate
        // prompts with exactly these values; the spec walker must keep
        // producing identical prompts for identical task seeds.
        assert_eq!(level_salt(0), 0);
        assert_eq!(level_salt(1), 0x5eed);
        assert_eq!(level_salt(2), 0xa99);
        assert_ne!(level_salt(3), level_salt(4));
    }

    #[test]
    fn prompt_respects_vocab_and_splices_upstream() {
        let p = ReasoningPipeline {
            seq_len: 16,
            vocabs: vec![("coordinator".into(), 256)],
        };
        let prompt = p.prompt(256, 42, &[1000, -3]);
        assert_eq!(prompt.len(), 16);
        assert!(prompt.iter().all(|t| (0..256).contains(t)));
        // Upstream answers occupy the tail, folded into vocab.
        assert_eq!(prompt[14], 1000 % 256);
        assert_eq!(prompt[15], (-3i32).rem_euclid(256));
    }

    #[test]
    fn prompt_is_deterministic_per_seed() {
        let p = ReasoningPipeline { seq_len: 8, vocabs: vec![] };
        assert_eq!(p.prompt(512, 1, &[5]), p.prompt(512, 1, &[5]));
        assert_ne!(p.prompt(512, 1, &[]), p.prompt(512, 2, &[]));
    }

    #[test]
    fn dropped_stage_surfaces_labelled_error_not_a_hang() {
        // A worker that panics mid-stage drops its reply sender; the
        // pipeline must turn that into an error naming the stage.
        let (tx, rx) =
            std::sync::mpsc::channel::<Result<CompletedRequest>>();
        drop(tx);
        let err = collect_stage("vision", &rx).unwrap_err();
        assert!(matches!(err, Error::Serving(_)), "{err:?}");
        assert!(err.to_string().contains("vision stage dropped"),
                "{err}");
    }

    #[test]
    fn failed_stage_error_propagates_through_collect() {
        let (tx, rx) =
            std::sync::mpsc::channel::<Result<CompletedRequest>>();
        tx.send(Err(Error::Serving("executor exhausted retries".into())))
            .unwrap();
        let err = collect_stage("nlp", &rx).unwrap_err();
        assert!(err.to_string().contains("executor exhausted retries"),
                "{err}");
    }
}
