//! The coordinator → specialists → coordinator workflow.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::server::{AgentServer, CompletedRequest};
use crate::util::Rng;

/// What kind of collaborative task a request is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Route to the NLP specialist.
    Nlp,
    /// Route to the vision specialist.
    Vision,
    /// Route to the reasoning specialist.
    Reasoning,
    /// Fan out to all three specialists and aggregate.
    MultiDomain,
}

impl TaskKind {
    /// Specialists this kind involves, in execution order.
    pub fn specialists(self) -> &'static [&'static str] {
        match self {
            TaskKind::Nlp => &["nlp"],
            TaskKind::Vision => &["vision"],
            TaskKind::Reasoning => &["reasoning"],
            TaskKind::MultiDomain => &["nlp", "vision", "reasoning"],
        }
    }

    /// Deterministic task mix used by examples/benches: a realistic blend
    /// skewed toward single-specialist tasks.
    pub fn sample(rng: &mut Rng) -> TaskKind {
        match rng.below(10) {
            0..=3 => TaskKind::Nlp,
            4..=6 => TaskKind::Vision,
            7..=8 => TaskKind::Reasoning,
            _ => TaskKind::MultiDomain,
        }
    }
}

/// One completed stage of a workflow.
#[derive(Debug, Clone)]
pub struct StageResult {
    /// Agent that ran the stage.
    pub agent: String,
    /// The stage's greedy next-token output.
    pub next_token: i32,
    /// Enqueue → completion time for this stage.
    pub latency: Duration,
    /// Batch the stage rode in.
    pub batch_size: usize,
}

/// A completed collaborative task.
#[derive(Debug, Clone)]
pub struct WorkflowResult {
    /// Task kind executed.
    pub kind: TaskKind,
    /// Per-stage results: plan, specialist(s), aggregate.
    pub stages: Vec<StageResult>,
    /// End-to-end wall time.
    pub total: Duration,
}

impl WorkflowResult {
    /// Sum of per-stage serving latencies (excludes client-side gaps).
    pub fn serving_latency(&self) -> Duration {
        self.stages.iter().map(|s| s.latency).sum()
    }

    /// The final aggregated answer token.
    pub fn answer(&self) -> i32 {
        self.stages.last().map(|s| s.next_token).unwrap_or(-1)
    }
}

/// Runs collaborative tasks through an [`AgentServer`].
#[derive(Debug)]
pub struct ReasoningPipeline {
    seq_len: usize,
    /// Per-agent vocab sizes, used to clamp tokens between stages.
    vocabs: Vec<(String, usize)>,
}

impl ReasoningPipeline {
    /// Build over a running server.
    pub fn new(server: &AgentServer, vocabs: Vec<(String, usize)>)
               -> ReasoningPipeline {
        ReasoningPipeline { seq_len: server.seq_len(), vocabs }
    }

    fn vocab_of(&self, agent: &str) -> Result<usize> {
        self.vocabs.iter().find(|(n, _)| n == agent).map(|(_, v)| *v)
            .ok_or_else(|| Error::Serving(format!(
                "agent '{agent}' missing from pipeline vocab table")))
    }

    /// Build a prompt for `agent` from a task seed plus upstream stage
    /// outputs: deterministic filler tokens with the upstream answers
    /// spliced into the tail (folded into the agent's vocab).
    pub fn prompt(&self, agent_vocab: usize, seed: u64, upstream: &[i32])
                  -> Vec<i32> {
        let mut tokens: Vec<i32> = (0..self.seq_len).map(|i| {
            ((seed.wrapping_mul(31).wrapping_add(i as u64 * 7 + 3))
             % agent_vocab as u64) as i32
        }).collect();
        let tail = self.seq_len.saturating_sub(upstream.len());
        for (slot, tok) in tokens[tail..].iter_mut().zip(upstream) {
            *slot = tok.rem_euclid(agent_vocab as i32);
        }
        tokens
    }

    /// Execute one collaborative task: coordinator plan → specialist
    /// fan-out → coordinator aggregation.
    pub fn run(&self, server: &AgentServer, kind: TaskKind, seed: u64)
               -> Result<WorkflowResult> {
        let start = Instant::now();
        let mut stages = Vec::with_capacity(kind.specialists().len() + 2);

        // Stage 1: the coordinator plans.
        let coord_vocab = self.vocab_of("coordinator")?;
        let plan_prompt = self.prompt(coord_vocab, seed, &[]);
        let plan = server.submit_blocking("coordinator", plan_prompt)?;
        let plan_token = plan.next_token;
        stages.push(StageResult {
            agent: plan.agent,
            next_token: plan_token,
            latency: plan.latency,
            batch_size: plan.batch_size,
        });

        // Stage 2: specialists solve. Fan out concurrently: submit all,
        // then collect (the server's governor interleaves them under the
        // allocator's shares).
        let mut pending = Vec::new();
        for name in kind.specialists() {
            let vocab = self.vocab_of(name)?;
            let prompt = self.prompt(vocab, seed ^ 0x5eed, &[plan_token]);
            pending.push((name, server.submit(name, prompt)?));
        }
        let mut specialist_tokens = Vec::with_capacity(pending.len());
        for (name, rx) in pending {
            let done = collect_stage(name, &rx)?;
            specialist_tokens.push(done.next_token);
            stages.push(StageResult {
                agent: done.agent,
                next_token: done.next_token,
                latency: done.latency,
                batch_size: done.batch_size,
            });
        }

        // Stage 3: the coordinator aggregates specialist answers.
        let mut upstream = vec![plan_token];
        upstream.extend(&specialist_tokens);
        let agg_prompt = self.prompt(coord_vocab, seed ^ 0xa99, &upstream);
        let agg = server.submit_blocking("coordinator", agg_prompt)?;
        stages.push(StageResult {
            agent: agg.agent,
            next_token: agg.next_token,
            latency: agg.latency,
            batch_size: agg.batch_size,
        });

        Ok(WorkflowResult { kind, stages, total: start.elapsed() })
    }
}

/// Wait for one specialist stage. A worker that panics or shuts down
/// mid-stage drops its reply sender; that surfaces here as a labelled
/// error rather than a hang (`recv` returns immediately once the
/// sending side is gone).
fn collect_stage(name: &str, rx: &Receiver<Result<CompletedRequest>>)
                 -> Result<CompletedRequest> {
    rx.recv().map_err(|_| Error::Serving(
        format!("{name} stage dropped")))?
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_kinds_route_to_expected_specialists() {
        assert_eq!(TaskKind::Nlp.specialists(), ["nlp"]);
        assert_eq!(TaskKind::MultiDomain.specialists(),
                   ["nlp", "vision", "reasoning"]);
    }

    #[test]
    fn task_mix_is_deterministic_and_covers_all_kinds() {
        let mut rng = Rng::new(7);
        let kinds: Vec<TaskKind> =
            (0..200).map(|_| TaskKind::sample(&mut rng)).collect();
        let mut rng2 = Rng::new(7);
        let again: Vec<TaskKind> =
            (0..200).map(|_| TaskKind::sample(&mut rng2)).collect();
        assert_eq!(kinds, again);
        for kind in [TaskKind::Nlp, TaskKind::Vision, TaskKind::Reasoning,
                     TaskKind::MultiDomain] {
            assert!(kinds.contains(&kind), "{kind:?} never sampled");
        }
    }

    #[test]
    fn prompt_respects_vocab_and_splices_upstream() {
        let p = ReasoningPipeline {
            seq_len: 16,
            vocabs: vec![("coordinator".into(), 256)],
        };
        let prompt = p.prompt(256, 42, &[1000, -3]);
        assert_eq!(prompt.len(), 16);
        assert!(prompt.iter().all(|t| (0..256).contains(t)));
        // Upstream answers occupy the tail, folded into vocab.
        assert_eq!(prompt[14], 1000 % 256);
        assert_eq!(prompt[15], (-3i32).rem_euclid(256));
    }

    #[test]
    fn prompt_is_deterministic_per_seed() {
        let p = ReasoningPipeline { seq_len: 8, vocabs: vec![] };
        assert_eq!(p.prompt(512, 1, &[5]), p.prompt(512, 1, &[5]));
        assert_ne!(p.prompt(512, 1, &[]), p.prompt(512, 2, &[]));
    }

    #[test]
    fn dropped_stage_surfaces_labelled_error_not_a_hang() {
        // A worker that panics mid-stage drops its reply sender; the
        // pipeline must turn that into an error naming the stage.
        let (tx, rx) =
            std::sync::mpsc::channel::<Result<CompletedRequest>>();
        drop(tx);
        let err = collect_stage("vision", &rx).unwrap_err();
        assert!(matches!(err, Error::Serving(_)), "{err:?}");
        assert!(err.to_string().contains("vision stage dropped"),
                "{err}");
    }

    #[test]
    fn failed_stage_error_propagates_through_collect() {
        let (tx, rx) =
            std::sync::mpsc::channel::<Result<CompletedRequest>>();
        tx.send(Err(Error::Serving("executor exhausted retries".into())))
            .unwrap();
        let err = collect_stage("nlp", &rx).unwrap_err();
        assert!(err.to_string().contains("executor exhausted retries"),
                "{err}");
    }
}
