//! Multi-agent collaborative reasoning on top of the serving stack.
//!
//! The paper's motivating workload (§I): a lightweight coordinator
//! orchestrates domain specialists. [`ReasoningPipeline`] implements that
//! workflow as a three-stage DAG per task —
//!
//! ```text
//!   coordinator (plan) ──► specialist(s) (solve, fan-out) ──► coordinator
//!                                                             (aggregate)
//! ```
//!
//! — where every stage is a real PJRT inference through [`crate::server`].
//! Rapid agent interaction is exactly why the paper's round-robin baseline
//! collapses: each hop waits for its agent's turn. The serving bench
//! measures this end-to-end.

mod workflow;

pub use workflow::{ReasoningPipeline, StageResult, TaskKind,
                   WorkflowResult};
